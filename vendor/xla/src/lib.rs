//! Stub of the `xla` crate's PJRT API surface.
//!
//! The real `xla` crate links the native XLA/PJRT runtime, which is not
//! available in the offline build environment. This stub provides the
//! exact types and signatures `polca::runtime` compiles against, so the
//! whole crate (simulator, fleet planner, CLI) builds and tests without
//! the native toolchain. Every entry point that would execute compiled
//! code returns an "unavailable" error at runtime; the serving path
//! (`polca serve`, `examples/serve_polca.rs`) reports it cleanly.
//!
//! To serve real models, replace this path dependency with the actual
//! `xla` crate — the signatures below match the subset used.

use std::fmt;

/// Stub error type (matches the real crate's `Error: std::error::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable in this build (vendor/xla is a stub; \
         swap in the real `xla` crate to run compiled artifacts)"
    ))
}

/// Host tensor literal (stub: shape/data are not retained).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        Err(unavailable("Literal::to_tuple3"))
    }
}

/// PJRT client handle. The stub constructor always errors, so no code
/// path past client creation can be reached at runtime.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_constructors_are_usable() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
