//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no crates.io), so the subset
//! of `anyhow` this repository actually uses is implemented in-tree:
//! [`Result`], [`Error`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! [`std::error::Error`]: that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! standard error type) coherent.

use std::error::Error as StdError;
use std::fmt;

/// An error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// Context on an already-`anyhow` Result (adding an outer message to an
// existing chain). Coherent next to the `E: StdError` blanket impl
// because `Error` itself does not implement `StdError` (see the module
// docs) — the same reasoning that makes the blanket `From` impl legal.
impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_cause(), "missing");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
    }

    #[test]
    fn context_stacks_on_anyhow_results_too() {
        fn inner() -> Result<()> {
            bail!("root problem")
        }
        let e = inner().with_context(|| "outer step").unwrap_err();
        assert_eq!(format!("{e}"), "outer step");
        assert_eq!(format!("{e:#}"), "outer step: root problem");
        let e = inner().context("labelled").unwrap_err();
        assert_eq!(format!("{e:#}"), "labelled: root problem");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("empty").is_err());
        fn bailer(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert!(bailer(3).is_ok());
        assert_eq!(format!("{}", bailer(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", bailer(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }
}
