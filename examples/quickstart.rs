//! Quickstart: the 60-second tour of the POLCA reproduction.
//!
//! 1. Load the AOT-compiled GPT artifacts and generate a few tokens from
//!    Rust (no Python on this path).
//! 2. Show the two-phase power structure the paper characterizes
//!    (prompt spike vs token plateau) for BLOOM-176B.
//! 3. Run a one-day cluster simulation with POLCA at +30% servers.
//!
//! Run with: cargo run --release --example quickstart

use polca::characterize::catalog::find;
use polca::cluster::hierarchy::Priority;
use polca::coordinator::{Coordinator, Request};
use polca::policy::engine::PolicyKind;
use polca::runtime::Engine;
use polca::simulation::{run_with_impact, SimConfig};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    // --- 1. real compute through the PJRT runtime ------------------------
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        println!("## 1. serving a real (small) GPT from Rust via PJRT");
        let engine = Engine::load(&dir)?;
        println!(
            "   model: {} params, {} layers, d={}, {} KV slots, prompt buckets {:?}",
            engine.manifest.model.num_params,
            engine.manifest.model.n_layers,
            engine.manifest.model.d_model,
            engine.manifest.model.batch_slots,
            engine.buckets(),
        );
        let mut coord = Coordinator::new(engine)?;
        coord.submit(Request {
            id: 0,
            prompt: vec![11, 42, 7, 100, 3],
            max_new_tokens: 8,
            priority: Priority::High,
        });
        let done = coord.run_to_completion()?;
        println!(
            "   generated: {:?} (prefill {:.1} ms, decode {:.1} ms)",
            &done[0].tokens[5..],
            done[0].prefill_s * 1e3,
            done[0].decode_s * 1e3
        );
    } else {
        println!("## 1. [skipped] run `make artifacts` to enable the serving demo");
    }

    // --- 2. the phase asymmetry (paper Fig 4) ----------------------------
    println!("\n## 2. BLOOM-176B power phases (paper §2.3)");
    let bloom = find("BLOOM-176B").unwrap();
    let prompt_peak = bloom.power.prompt_peak_frac(2048.0);
    let token_mean = bloom.power.token_mean_frac(1.0);
    println!(
        "   prompt spike: {:.0}% of GPU TDP for {:.2}s | token phase: {:.0}% for {:.1}s",
        prompt_peak * 100.0,
        bloom.prompt_time_s(2048.0, 1.0),
        token_mean * 100.0,
        bloom.token_time_s(256.0, 1.0),
    );
    println!("   -> spikes are short and uncorrelated across servers: rows have headroom");

    // --- 3. POLCA at +30% servers ----------------------------------------
    println!("\n## 3. one simulated day: POLCA at +30% servers on a 40-server budget");
    let mut cfg = SimConfig::default();
    cfg.weeks = 1.0 / 7.0;
    cfg.policy_kind = PolicyKind::Polca;
    cfg.deployed_servers = 52;
    cfg.exp.seed = 7;
    let (mut report, impact) = run_with_impact(&cfg);
    println!("   {}", report.summary());
    println!(
        "   impact vs uncapped: HP p99 {:.2}%, LP p99 {:.2}%, brakes {}",
        impact.hp_p99 * 100.0,
        impact.lp_p99 * 100.0,
        impact.brake_events
    );
    println!(
        "   SLO (Table 5): {}",
        if impact.meets_slo(&cfg.exp.slo) { "OK" } else { "VIOLATED" }
    );
    Ok(())
}
