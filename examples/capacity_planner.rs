//! Capacity planner: given a site's substation budget and workload mix,
//! report how many servers each policy can safely deploy — the
//! operator-facing use of POLCA's result (more servers per datacenter,
//! fewer datacenters), lifted to the site level via `polca::fleet`.
//!
//! The site is heterogeneous (A100, H100, and mixed-generation clusters
//! with staggered diurnal peaks); the planner binary-searches the max
//! added-server fraction per policy such that every cluster holds its
//! Table-5 SLOs with zero powerbrakes and the composed site trace stays
//! under every feed and the substation budget.
//!
//! Run with: cargo run --release --example capacity_planner [n_clusters]

use polca::fleet::planner::{plan_all, PlannerConfig};
use polca::fleet::site::SiteSpec;

fn main() {
    let n_clusters: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let site = SiteSpec::demo(n_clusters);
    let mut pc = PlannerConfig::default();
    pc.weeks = 0.1;
    pc.step_pct = 5;

    println!(
        "# capacity planning for site '{}': {} clusters, {} baseline servers, \
         {:.0} kW substation budget",
        site.name,
        site.clusters.len(),
        site.baseline_servers(),
        site.substation_budget_w / 1e3
    );
    for c in &site.clusters {
        println!(
            "#   {:<16} {:<10} {:>3} servers  {:>7.0} kW  +{:.0}h diurnal phase",
            c.name,
            c.sku.name,
            c.baseline_servers,
            c.budget_w() / 1e3,
            c.phase_offset_s / 3600.0
        );
    }
    println!(
        "{:<18} {:>10} {:>8} {:>10} {:>8} {:>9}",
        "policy", "deployable", "extra", "site peak", "brakes", "caps/day"
    );
    for plan in plan_all(&site, &pc) {
        println!(
            "{:<18} {:>10} {:>7.1}% {:>9.1}% {:>8} {:>9.1}",
            plan.policy.name(),
            if plan.feasible { plan.deployable_servers.to_string() } else { "—".into() },
            plan.added_pct as f64,
            plan.site_peak_w / plan.substation_budget_w * 100.0,
            plan.brake_events,
            plan.cap_events_per_day
        );
    }
    println!(
        "\nevery +10% deployable servers ≈ one datacenter avoided per ten \
         (paper §1: cost + carbon + time-to-capacity)"
    );
}
