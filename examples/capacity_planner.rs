//! Capacity planner: given a row power budget and a workload mix, report
//! how many servers each policy can safely deploy — the operator-facing
//! use of POLCA's result (more servers per datacenter, fewer datacenters).
//!
//! Run with: cargo run --release --example capacity_planner [budget_servers]

use polca::policy::engine::PolicyKind;
use polca::simulation::{run_with_impact, SimConfig};

fn deployable(kind: PolicyKind, baseline: usize, weeks: f64) -> (usize, f64) {
    // March the deployment up until SLOs (incl. zero brakes) break.
    let mut best = baseline;
    for added_pct in [0, 5, 10, 15, 20, 25, 30, 35, 40] {
        let deployed = baseline + baseline * added_pct / 100;
        let mut cfg = SimConfig::default();
        cfg.weeks = weeks;
        cfg.policy_kind = kind;
        cfg.exp.row.num_servers = baseline;
        cfg.deployed_servers = deployed;
        cfg.exp.seed = 11;
        let (_, impact) = run_with_impact(&cfg);
        if impact.meets_slo(&cfg.exp.slo) {
            best = deployed;
        } else {
            break;
        }
    }
    (best, best as f64 / baseline as f64 - 1.0)
}

fn main() {
    let baseline: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    let weeks = 0.3;
    println!("# capacity planning for a {baseline}-server power budget (Table-4 mix, BLOOM-176B)");
    println!("{:<18} {:>10} {:>12}", "policy", "deployable", "extra");
    for kind in PolicyKind::all() {
        let (n, extra) = deployable(kind, baseline, weeks);
        println!("{:<18} {:>10} {:>11.1}%", kind.name(), n, extra * 100.0);
    }
    println!(
        "\nevery +10% deployable servers ≈ one datacenter avoided per ten \
         (paper §1: cost + carbon + time-to-capacity)"
    );
}
