//! Fig-13-style oversubscription study on a fresh synthetic trace:
//! sweep added-server levels under POLCA and find where SLOs break,
//! then compare the T1-T2 combinations the paper examines.
//!
//! Run with: cargo run --release --example oversubscribe_study [weeks]

use polca::policy::tuner::{evaluate_point, tune_thresholds};
use polca::simulation::SimConfig;

fn main() {
    let weeks: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let mut base = SimConfig::default();
    base.weeks = weeks;
    base.exp.seed = 2026;

    println!("# oversubscription frontier (POLCA, T1=80 T2=89, {weeks} weeks)");
    println!("{:<8} {:>8} {:>8} {:>8} {:>8} {:>7}  SLO", "added", "HP p99", "LP p50", "LP p99", "LP thr", "brakes");
    for added in [0.0, 0.10, 0.20, 0.30, 0.40, 0.50] {
        let p = evaluate_point(&base, 0.80, 0.89, added, &base.exp.slo);
        println!(
            "{:<8} {:>7.2}% {:>7.2}% {:>7.2}% {:>8} {:>7}  {}",
            format!("+{:.0}%", added * 100.0),
            p.hp_p99 * 100.0,
            p.lp_p50 * 100.0,
            p.lp_p99 * 100.0,
            "-",
            p.brakes,
            if p.meets_slo { "ok" } else { "VIOLATED" }
        );
    }

    println!("\n# threshold combinations (paper Fig 13)");
    let combos = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)];
    let outcome = tune_thresholds(&base, &combos, &[0.25, 0.30, 0.35], &base.exp.slo);
    for p in &outcome.points {
        println!(
            "T1-T2 {:.0}-{:.0} +{:>4.1}% | LP p99 {:>6.2}% | brakes {} | {}",
            p.t1 * 100.0,
            p.t2 * 100.0,
            p.added_frac * 100.0,
            p.lp_p99 * 100.0,
            p.brakes,
            if p.meets_slo { "ok" } else { "VIOLATED" }
        );
    }
    if let Some((t1, t2, added)) = outcome.best {
        println!(
            "\nbest: T1={:.0}% T2={:.0}% supports +{:.0}% servers within SLOs \
             (paper: 80-89 at +30%)",
            t1 * 100.0,
            t2 * 100.0,
            added * 100.0
        );
    }
}
