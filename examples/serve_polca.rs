//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//!   L1/L2 (build time): Pallas flash-attention + decode kernels inside a
//!       JAX GPT, AOT-lowered to HLO text (`make artifacts`).
//!   L3 (this binary): the Rust coordinator loads the artifacts via PJRT,
//!       routes a mixed-priority request stream through the continuous
//!       batcher (KV slots, prompt buckets), and runs the POLCA policy
//!       engine over the modeled power of a replicated row — caps,
//!       escalations, and brake decisions included.
//!
//! Reported: real serving latency/throughput per priority, the executed
//! phase timeline, the row power trace, and POLCA's cap decisions at
//! several oversubscription levels. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run with: cargo run --release --example serve_polca

use polca::cluster::hierarchy::Priority;
use polca::config::PolicyConfig;
use polca::coordinator::{run_policy_over_row, timeline_power, Coordinator, Request};
use polca::power::server::ServerPowerModel;
use polca::runtime::Engine;
use polca::util::rng::Rng;
use polca::util::stats::Percentiles;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);

    println!("# POLCA end-to-end driver");
    let t_load = std::time::Instant::now();
    let engine = Engine::load(&dir)?;
    println!(
        "loaded {} executables ({} params) in {:.1}s",
        engine.buckets().len() + 1,
        engine.manifest.model.num_params,
        t_load.elapsed().as_secs_f64()
    );
    let max_seq = engine.manifest.model.max_seq;
    let mut coord = Coordinator::new(engine)?;

    // A mixed-priority stream with Table-4-shaped length asymmetry
    // (scaled to the small model): Summarize = long prompt/short output
    // (LP), Search = short prompt/long output (HP), Chat = mixed.
    let mut rng = Rng::new(42);
    let mut offered = Vec::new();
    for id in 0..n_requests as u64 {
        let (p_lo, p_hi, o_lo, o_hi, pri) = match rng.below(4) {
            0 => (24usize, 60usize, 4usize, 8usize, Priority::Low), // summarize
            1 => (4, 12, 16, 28, Priority::High),                   // search
            _ => {
                let pri = if rng.bool(0.5) { Priority::High } else { Priority::Low };
                (12, 40, 6, 20, pri) // chat
            }
        };
        let plen = rng.range_usize(p_lo, p_hi);
        let out = rng.range_usize(o_lo, o_hi).min(max_seq - plen - 1);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(512) as i32).collect();
        offered.push(Request { id, prompt, max_new_tokens: out, priority: pri });
    }

    let t0 = std::time::Instant::now();
    for req in offered {
        coord.submit(req);
        // interleave: drive a couple of scheduler steps per arrival
        coord.step()?;
        coord.step()?;
    }
    let done = coord.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    // --- serving report ---------------------------------------------------
    let mut hp_lat = Percentiles::new();
    let mut lp_lat = Percentiles::new();
    let mut total_new = 0usize;
    for d in &done {
        let l = d.queue_s + d.prefill_s + d.decode_s;
        match d.priority {
            Priority::High => hp_lat.push(l),
            Priority::Low => lp_lat.push(l),
        }
        total_new += d.tokens.len();
    }
    println!("\n## serving (real PJRT compute)");
    println!(
        "completed {}/{} requests in {wall:.2}s  |  {:.1} req/s, {:.1} tok/s",
        done.len(),
        n_requests,
        done.len() as f64 / wall,
        total_new as f64 / wall
    );
    println!(
        "latency  HP p50/p99 = {:.3}/{:.3}s   LP p50/p99 = {:.3}/{:.3}s   rejected={}",
        hp_lat.p50(),
        hp_lat.p99(),
        lp_lat.p50(),
        lp_lat.p99(),
        coord.rejected
    );
    let prefills = coord
        .timeline
        .records
        .iter()
        .filter(|r| matches!(r, polca::coordinator::PhaseRecord::Prefill(..)))
        .count();
    let decodes = coord.timeline.records.len() - prefills;
    println!("timeline: {prefills} prefill bursts, {decodes} batched decode steps");

    // --- POLCA in the loop -------------------------------------------------
    println!("\n## POLCA over a 40-replica row of this node");
    let model = ServerPowerModel::default();
    let trace = timeline_power(&coord.timeline, &model, 0.5, 50.0);
    let peak = trace.samples.iter().cloned().fold(0.0f64, f64::max);
    let mean = trace.samples.iter().sum::<f64>() / trace.samples.len() as f64;
    println!("node power (modeled from executed phases): peak {peak:.2}, mean {mean:.2} of provisioned");
    for oversub in [1.0, 1.3, 1.5] {
        let report = run_policy_over_row(
            &trace, 40, oversub, &PolicyConfig::default(), &model.calib, 0.22, 0.92,
        );
        let lp_capped = report.cap_timeline.iter().filter(|(_, lp, _, _)| lp.is_some()).count();
        let hp_capped = report.cap_timeline.iter().filter(|(_, _, hp, _)| hp.is_some()).count();
        println!(
            "  oversub {oversub:.1}x: LP capped {:>4}/{} ticks, HP capped {:>4}, brakes {}, \
             modeled stretch LP {:.3} / HP {:.3}",
            lp_capped,
            report.cap_timeline.len(),
            hp_capped,
            report.brake_events,
            report.lp_modeled_stretch,
            report.hp_modeled_stretch
        );
    }
    println!("\n(all layers composed: Pallas kernels -> JAX model -> HLO text -> PJRT -> batcher -> POLCA)");
    Ok(())
}
