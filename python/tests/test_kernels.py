"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the compute layer. hypothesis
sweeps shapes/dtypes; fixed tests pin the exact configurations that ship
in the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import flash_attention, vmem_report as prefill_report
from compile.kernels.decode import decode_attention, vmem_report as decode_report
from compile.kernels.ref import causal_attention_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- prefill --
class TestFlashAttention:
    @pytest.mark.parametrize("seq", [16, 32, 64, 128])
    @pytest.mark.parametrize("heads", [1, 4])
    def test_matches_ref(self, seq, heads):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seq * 7 + heads), 3)
        q, k, v = rand(k1, (heads, seq, 32)), rand(k2, (heads, seq, 32)), rand(k3, (heads, seq, 32))
        out = flash_attention(q, k, v)
        ref = causal_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)

    @pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 16), (16, 8), (64, 32)])
    def test_block_shape_invariance(self, block_q, block_k):
        """Output must not depend on the VMEM tiling schedule."""
        seq, heads, dh = 64, 2, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
        q, k, v = rand(k1, (heads, seq, dh)), rand(k2, (heads, seq, dh)), rand(k3, (heads, seq, dh))
        out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
        ref = causal_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)

    def test_causality(self):
        """Perturbing future tokens must not change earlier outputs."""
        seq, heads, dh = 32, 2, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = rand(k1, (heads, seq, dh)), rand(k2, (heads, seq, dh)), rand(k3, (heads, seq, dh))
        base = flash_attention(q, k, v)
        k2_, v2_ = k.at[:, seq // 2:].add(10.0), v.at[:, seq // 2:].add(-5.0)
        pert = flash_attention(q, k2_, v2_)
        np.testing.assert_allclose(
            np.asarray(base[:, : seq // 2]), np.asarray(pert[:, : seq // 2]),
            rtol=1e-6, atol=1e-6,
        )

    def test_softmax_normalization(self):
        """With v = const, attention output must be exactly that const."""
        seq, heads, dh = 32, 1, 8
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        q, k = rand(k1, (heads, seq, dh)), rand(k2, (heads, seq, dh))
        v = jnp.full((heads, seq, dh), 3.25, jnp.float32)
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)

    def test_large_logits_stable(self):
        """Online softmax must survive large score magnitudes (no inf/nan)."""
        seq, heads, dh = 32, 1, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(6), 3)
        q = rand(k1, (heads, seq, dh)) * 50.0
        k = rand(k2, (heads, seq, dh)) * 50.0
        v = rand(k3, (heads, seq, dh))
        out = flash_attention(q, k, v)
        assert np.isfinite(np.asarray(out)).all()

    def test_bad_blocks_rejected(self):
        q = jnp.zeros((1, 24, 8))
        with pytest.raises(ValueError):
            flash_attention(q, q, q, block_q=16, block_k=16)
        with pytest.raises(ValueError):
            flash_attention(jnp.zeros((1, 32, 8)), jnp.zeros((1, 32, 8)),
                            jnp.zeros((1, 32, 8)), block_q=8, block_k=16)

    @settings(max_examples=12, deadline=None)
    @given(
        heads=st.sampled_from([1, 2, 4]),
        seq_blocks=st.integers(1, 6),
        dh=st.sampled_from([8, 16, 32]),
        dtype=st.sampled_from([jnp.float32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, heads, seq_blocks, dh, dtype, seed):
        seq = 16 * seq_blocks
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        q, k, v = (rand(kk, (heads, seq, dh), dtype) for kk in (k1, k2, k3))
        out = flash_attention(q, k, v)
        ref = causal_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-5, atol=5e-5)


# ----------------------------------------------------------------- decode --
class TestDecodeAttention:
    @pytest.mark.parametrize("batch", [1, 4])
    @pytest.mark.parametrize("s_max", [32, 160])
    def test_matches_ref(self, batch, s_max):
        heads, dh = 4, 32
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(batch * 31 + s_max), 3)
        q = rand(k1, (batch, heads, dh))
        kc = rand(k2, (batch, heads, s_max, dh))
        vc = rand(k3, (batch, heads, s_max, dh))
        pos = jnp.arange(batch, dtype=jnp.int32) * 3 + 1
        out = decode_attention(q, kc, vc, pos)
        ref = decode_attention_ref(q, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)

    def test_masking_excludes_stale_cache(self):
        """Garbage beyond pos[b] must not influence the output."""
        batch, heads, s_max, dh = 2, 2, 16, 8
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
        q = rand(k1, (batch, heads, dh))
        kc = rand(k2, (batch, heads, s_max, dh))
        vc = rand(k3, (batch, heads, s_max, dh))
        pos = jnp.array([4, 7], jnp.int32)
        base = decode_attention(q, kc, vc, pos)
        kc2 = kc.at[:, :, 10:].set(1e6)
        vc2 = vc.at[:, :, 10:].set(-1e6)
        pert = decode_attention(q, kc2, vc2, pos)
        np.testing.assert_allclose(np.asarray(base), np.asarray(pert), rtol=1e-6)

    def test_pos_zero_attends_only_first(self):
        batch, heads, s_max, dh = 1, 1, 8, 4
        q = jnp.ones((batch, heads, dh))
        kc = jnp.zeros((batch, heads, s_max, dh))
        vc = jnp.arange(s_max, dtype=jnp.float32)[None, None, :, None] * jnp.ones((1, 1, 1, dh))
        out = decode_attention(q, kc, vc, jnp.array([0], jnp.int32))
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

    @settings(max_examples=12, deadline=None)
    @given(
        batch=st.integers(1, 4),
        heads=st.sampled_from([1, 2, 4]),
        s_max=st.sampled_from([8, 32, 64]),
        dh=st.sampled_from([4, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, batch, heads, s_max, dh, seed):
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = rand(k1, (batch, heads, dh))
        kc = rand(k2, (batch, heads, s_max, dh))
        vc = rand(k3, (batch, heads, s_max, dh))
        pos = jax.random.randint(k4, (batch,), 0, s_max, jnp.int32)
        out = decode_attention(q, kc, vc, pos)
        ref = decode_attention_ref(q, kc, vc, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-5, atol=5e-5)


# ------------------------------------------------------------ VMEM report --
class TestKernelReports:
    def test_prefill_fits_vmem(self):
        rep = prefill_report(seq_len=160, head_dim=32)
        assert rep["vmem_bytes_per_step"] < 16 * 1024 * 1024
        assert rep["vmem_budget_fraction"] < 0.01

    def test_prefill_intensity_exceeds_decode(self):
        """Structural check for the paper's phase asymmetry (Fig 4): the
        prompt kernel must be far more arithmetically intense than decode."""
        p = prefill_report(seq_len=160, head_dim=32)
        d = decode_report(s_max=160, head_dim=32)
        assert p["arithmetic_intensity"] > 10 * d["arithmetic_intensity"]

    def test_decode_is_bandwidth_bound(self):
        d = decode_report(s_max=160, head_dim=32)
        assert d["arithmetic_intensity"] < 1.0
