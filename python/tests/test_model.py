"""L2 model correctness: prefill/decode consistency and the KV protocol.

Verifies the exact contract the Rust coordinator relies on
(rust/src/coordinator/): slot isolation, prefill->decode continuation,
pallas-vs-ref model equivalence, and padding invariance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib

jax.config.update("jax_platform_name", "cpu")

# A miniature config so interpret-mode tests stay fast.
CFG = model_lib.ModelConfig(
    vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64,
    max_seq=48, batch_slots=3, block_q=8, block_k=8,
)


@pytest.fixture(scope="module")
def params():
    return model_lib.init_params(CFG, seed=1)


def empty_kv():
    return jnp.zeros(CFG.kv_shape(), jnp.float32), jnp.zeros(CFG.kv_shape(), jnp.float32)


def tok(key, n):
    return jax.random.randint(jax.random.PRNGKey(key), (n,), 0, CFG.vocab, jnp.int32)


class TestParamSpecs:
    def test_canonical_order_stable(self):
        names = [n for n, _ in CFG.param_specs()]
        assert names[0] == "tok_emb" and names[1] == "pos_emb"
        assert names[-2:] == ["lnf_s", "lnf_b"]
        assert len(names) == 2 + 12 * CFG.n_layers + 2

    def test_init_matches_specs(self, params):
        for (name, shape), p in zip(CFG.param_specs(), params):
            assert p.shape == shape, name

    def test_flops_monotonic(self):
        assert CFG.prefill_flops(64) > CFG.prefill_flops(16)
        assert CFG.decode_flops(4, 48) > CFG.decode_flops(1, 48)


class TestPrefill:
    def test_pallas_matches_ref_model(self, params):
        kv_k, kv_v = empty_kv()
        tokens = tok(11, 16)
        args = (params, kv_k, kv_v, tokens, jnp.int32(16), jnp.int32(0))
        lp, kp, vp = model_lib.prefill(CFG, *args, use_pallas=True)
        lr, kr, vr = model_lib.prefill(CFG, *args, use_pallas=False)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(kp), np.asarray(kr), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), rtol=2e-4, atol=2e-4)

    def test_padding_invariance(self, params):
        """Logits for a length-L prompt must not depend on pad tokens."""
        kv_k, kv_v = empty_kv()
        real = tok(12, 8)
        padded_a = jnp.concatenate([real, jnp.zeros(8, jnp.int32)])
        padded_b = jnp.concatenate([real, jnp.full((8,), 5, jnp.int32)])
        la, _, _ = model_lib.prefill(CFG, params, kv_k, kv_v, padded_a, jnp.int32(8), jnp.int32(0))
        lb, _, _ = model_lib.prefill(CFG, params, kv_k, kv_v, padded_b, jnp.int32(8), jnp.int32(0))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-5)

    def test_slot_isolation(self, params):
        """Prefill into slot 1 must leave other slots' KV untouched."""
        kv_k = jnp.full(CFG.kv_shape(), 7.0)
        kv_v = jnp.full(CFG.kv_shape(), -7.0)
        _, kk, vv = model_lib.prefill(
            CFG, params, kv_k, kv_v, tok(13, 16), jnp.int32(16), jnp.int32(1))
        np.testing.assert_array_equal(np.asarray(kk[:, 0]), 7.0)
        np.testing.assert_array_equal(np.asarray(kk[:, 2]), 7.0)
        np.testing.assert_array_equal(np.asarray(vv[:, 0]), -7.0)
        assert not np.allclose(np.asarray(kk[:, 1, :, :16]), 7.0)


class TestDecode:
    def test_pallas_matches_ref_model(self, params):
        kv_k, kv_v = empty_kv()
        # fill some KV first so decode attends over real history
        _, kv_k, kv_v = model_lib.prefill(
            CFG, params, kv_k, kv_v, tok(14, 16), jnp.int32(16), jnp.int32(0))
        tokens = jnp.array([3, 9, 1], jnp.int32)
        pos = jnp.array([16, 0, 0], jnp.int32)
        lp, kp, vp = model_lib.decode_step(CFG, params, kv_k, kv_v, tokens, pos, use_pallas=True)
        lr, kr, vr = model_lib.decode_step(CFG, params, kv_k, kv_v, tokens, pos, use_pallas=False)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(kp), np.asarray(kr), rtol=2e-4, atol=2e-4)

    def test_prefill_decode_continuation(self, params):
        """Greedy decode after prefill(S) must equal prefill(S+1)'s logits.

        This is the exact equivalence the serving path depends on: the
        next-token distribution computed incrementally via the KV cache
        must match recomputing the whole prefix from scratch.
        """
        kv_k, kv_v = empty_kv()
        full = tok(15, 9)  # 9 tokens total
        prefix, nxt = full[:8], full[8]
        pad = lambda t, s: jnp.concatenate([t, jnp.zeros(s - t.shape[0], jnp.int32)])

        # path A: prefill 8, then decode token 9 at pos 8
        _, kv_k, kv_v = model_lib.prefill(
            CFG, params, kv_k, kv_v, pad(prefix, 16), jnp.int32(8), jnp.int32(0))
        tokens = jnp.array([nxt, 0, 0], jnp.int32)
        pos = jnp.array([8, 0, 0], jnp.int32)
        logits_a, _, _ = model_lib.decode_step(CFG, params, kv_k, kv_v, tokens, pos)

        # path B: prefill all 9 from scratch
        kv_k2, kv_v2 = empty_kv()
        logits_b, _, _ = model_lib.prefill(
            CFG, params, kv_k2, kv_v2, pad(full, 16), jnp.int32(9), jnp.int32(0))
        np.testing.assert_allclose(
            np.asarray(logits_a[0]), np.asarray(logits_b), rtol=5e-4, atol=5e-4)

    def test_multi_step_decode_matches_full_prefill(self, params):
        """Three chained decode steps == one longer prefill (slot 2)."""
        full = tok(16, 11)
        pad = lambda t, s: jnp.concatenate([t, jnp.zeros(s - t.shape[0], jnp.int32)])
        kv_k, kv_v = empty_kv()
        _, kv_k, kv_v = model_lib.prefill(
            CFG, params, kv_k, kv_v, pad(full[:8], 16), jnp.int32(8), jnp.int32(2))
        logits = None
        for i in range(3):
            tokens = jnp.array([0, 0, full[8 + i]], jnp.int32)
            pos = jnp.array([0, 0, 8 + i], jnp.int32)
            logits, kv_k, kv_v = model_lib.decode_step(CFG, params, kv_k, kv_v, tokens, pos)
        kv_k2, kv_v2 = empty_kv()
        ref_logits, _, _ = model_lib.prefill(
            CFG, params, kv_k2, kv_v2, pad(full, 16), jnp.int32(11), jnp.int32(0))
        np.testing.assert_allclose(
            np.asarray(logits[2]), np.asarray(ref_logits), rtol=1e-3, atol=1e-3)

    def test_decode_writes_kv_at_pos(self, params):
        kv_k, kv_v = empty_kv()
        tokens = jnp.array([3, 9, 1], jnp.int32)
        pos = jnp.array([5, 2, 40], jnp.int32)
        _, kk, _ = model_lib.decode_step(CFG, params, kv_k, kv_v, tokens, pos)
        kk = np.asarray(kk)
        for b, p in enumerate([5, 2, 40]):
            assert np.abs(kk[:, b, :, p]).sum() > 0
            mask = np.ones(CFG.max_seq, bool)
            mask[p] = False
            assert np.abs(kk[:, b, :, mask]).sum() == 0
