"""L2: GPT-style transformer (prefill + decode) built on the Pallas kernels.

This is the *workload* layer of the POLCA reproduction: a decoder-only
transformer with the two execution phases the paper characterizes —

  * ``prefill``      — parallel prompt processing (compute-bound, the power
                       spike in Fig. 4), implemented on the flash-attention
                       Pallas kernel,
  * ``decode_step``  — autoregressive token sampling against a static-shaped
                       KV cache (memory-bound, the stable low-power phase),
                       implemented on the decode Pallas kernel.

Both functions are pure and static-shaped so ``aot.py`` can lower each to a
single HLO-text artifact that the Rust coordinator loads once and executes
for every request (Python never on the request path).

KV-cache protocol (shared with rust/src/coordinator/kv.rs):
  caches are [L, B, H, S_max, DH]; a request owns one batch *slot*.
  prefill writes positions [0, S) of its slot and returns logits for the
  last valid prompt token (``length - 1``); decode writes position
  ``pos[b]`` and attends to [0, pos[b]]. Positions beyond the valid range
  may contain stale data but are provably never attended (causal mask in
  prefill, pos mask in decode).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import attention as attn_kernel
from compile.kernels import decode as decode_kernel
from compile.kernels import ref as ref_kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model hyper-parameters (baked into each AOT artifact)."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 160
    batch_slots: int = 4  # decode batch width B (one KV slot per request)
    block_q: int = 16
    block_k: int = 16

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Canonical (name, shape) list — the wire order for artifacts."""
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.max_seq
        specs: List[Tuple[str, Tuple[int, ...]]] = [
            ("tok_emb", (v, d)),
            ("pos_emb", (s, d)),
        ]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.ln1_s", (d,)), (f"l{l}.ln1_b", (d,)),
                (f"l{l}.wq", (d, d)), (f"l{l}.wk", (d, d)),
                (f"l{l}.wv", (d, d)), (f"l{l}.wo", (d, d)),
                (f"l{l}.ln2_s", (d,)), (f"l{l}.ln2_b", (d,)),
                (f"l{l}.w1", (d, f)), (f"l{l}.b1", (f,)),
                (f"l{l}.w2", (f, d)), (f"l{l}.b2", (d,)),
            ]
        specs += [("lnf_s", (d,)), ("lnf_b", (d,))]
        return specs

    def num_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())

    def kv_shape(self) -> Tuple[int, int, int, int, int]:
        return (self.n_layers, self.batch_slots, self.n_heads, self.max_seq, self.d_head)

    # --- analytic FLOPs (consumed by the Rust power/perf models) ---------
    def prefill_flops(self, seq: int) -> int:
        d, f, h = self.d_model, self.d_ff, self.n_heads
        per_tok = 2 * d * (4 * d + 2 * f)           # qkvo projections + MLP
        attn = 2 * 2 * h * seq * seq * self.d_head  # scores + weighted sum
        return self.n_layers * (seq * per_tok + attn) + 2 * seq * d * self.vocab

    def decode_flops(self, batch: int, ctx: int) -> int:
        d, f, h = self.d_model, self.d_ff, self.n_heads
        per_tok = 2 * d * (4 * d + 2 * f)
        attn = 2 * 2 * h * ctx * self.d_head
        return self.n_layers * batch * (per_tok + attn) + 2 * batch * d * self.vocab


# Small, deterministic init — quality of the language model is irrelevant
# here; what matters is real compute with the right phase structure.
def init_params(config: ModelConfig, seed: int = 0) -> List[jax.Array]:
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in config.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("_s",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", ".b1", ".b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(jnp.float32(fan_in))
            )
    return params


class _P:
    """Name-addressed view over the flat parameter list."""

    def __init__(self, config: ModelConfig, flat: Sequence[jax.Array]):
        names = [n for n, _ in config.param_specs()]
        assert len(names) == len(flat), (len(names), len(flat))
        self._d = dict(zip(names, flat))

    def __getitem__(self, name: str) -> jax.Array:
        return self._d[name]


def _layer_norm(x, scale, bias, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def prefill(
    config: ModelConfig,
    params: Sequence[jax.Array],
    kv_k: jax.Array,
    kv_v: jax.Array,
    tokens: jax.Array,   # [S] int32, padded to the artifact's bucket size
    length: jax.Array,   # scalar int32, number of valid tokens (<= S)
    slot: jax.Array,     # scalar int32, KV batch slot owned by this request
    *,
    use_pallas: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Process a prompt; returns (next-token logits [V], kv_k', kv_v')."""
    p = _P(config, params)
    seq = tokens.shape[0]
    h, dh = config.n_heads, config.d_head

    x = p["tok_emb"][tokens] + p["pos_emb"][:seq]
    for l in range(config.n_layers):
        y = _layer_norm(x, p[f"l{l}.ln1_s"], p[f"l{l}.ln1_b"])
        q = (y @ p[f"l{l}.wq"]).reshape(seq, h, dh).transpose(1, 0, 2)
        k = (y @ p[f"l{l}.wk"]).reshape(seq, h, dh).transpose(1, 0, 2)
        v = (y @ p[f"l{l}.wv"]).reshape(seq, h, dh).transpose(1, 0, 2)
        if use_pallas:
            o = attn_kernel.flash_attention(
                q, k, v, block_q=config.block_q, block_k=config.block_k
            )
        else:
            o = ref_kernel.causal_attention_ref(q, k, v)
        x = x + o.transpose(1, 0, 2).reshape(seq, config.d_model) @ p[f"l{l}.wo"]
        y = _layer_norm(x, p[f"l{l}.ln2_s"], p[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(y @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]
        # Persist this layer's KV into the request's slot, positions [0, S).
        kv_k = jax.lax.dynamic_update_slice(kv_k, k[None, None], (l, slot, 0, 0, 0))
        kv_v = jax.lax.dynamic_update_slice(kv_v, v[None, None], (l, slot, 0, 0, 0))

    x_last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, config.d_model))[0]
    x_last = _layer_norm(x_last, p["lnf_s"], p["lnf_b"])
    logits = x_last @ p["tok_emb"].T
    return logits, kv_k, kv_v


def _write_kv_slot(cache_l: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new [B,H,DH] into cache_l [B,H,S,DH] at per-sequence positions."""
    def one(c, kb, pp):  # c [H,S,DH], kb [H,DH]
        return jax.lax.dynamic_update_slice(c, kb[:, None, :], (0, pp, 0))
    return jax.vmap(one)(cache_l, new, pos)


def decode_step(
    config: ModelConfig,
    params: Sequence[jax.Array],
    kv_k: jax.Array,
    kv_v: jax.Array,
    tokens: jax.Array,  # [B] int32 — token generated at the previous step
    pos: jax.Array,     # [B] int32 — position this token occupies
    *,
    use_pallas: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One autoregressive step for all batch slots; returns ([B,V], kv', kv')."""
    p = _P(config, params)
    h, dh = config.n_heads, config.d_head

    x = p["tok_emb"][tokens] + p["pos_emb"][pos]  # [B, D]
    for l in range(config.n_layers):
        y = _layer_norm(x, p[f"l{l}.ln1_s"], p[f"l{l}.ln1_b"])
        q = (y @ p[f"l{l}.wq"]).reshape(-1, h, dh)
        k = (y @ p[f"l{l}.wk"]).reshape(-1, h, dh)
        v = (y @ p[f"l{l}.wv"]).reshape(-1, h, dh)
        kv_k = kv_k.at[l].set(_write_kv_slot(kv_k[l], k, pos))
        kv_v = kv_v.at[l].set(_write_kv_slot(kv_v[l], v, pos))
        if use_pallas:
            o = decode_kernel.decode_attention(q, kv_k[l], kv_v[l], pos)
        else:
            o = ref_kernel.decode_attention_ref(q, kv_k[l], kv_v[l], pos)
        x = x + o.reshape(-1, config.d_model) @ p[f"l{l}.wo"]
        y = _layer_norm(x, p[f"l{l}.ln2_s"], p[f"l{l}.ln2_b"])
        x = x + jax.nn.gelu(y @ p[f"l{l}.w1"] + p[f"l{l}.b1"]) @ p[f"l{l}.w2"] + p[f"l{l}.b2"]

    x = _layer_norm(x, p["lnf_s"], p["lnf_b"])
    logits = x @ p["tok_emb"].T  # [B, V]
    return logits, kv_k, kv_v


def make_prefill_fn(config: ModelConfig, seq: int, *, use_pallas: bool = True) -> Callable:
    """Flat-args prefill for AOT lowering: (params..., kv_k, kv_v, tokens, length, slot)."""
    n = len(config.param_specs())

    def fn(*args):
        params, (kv_k, kv_v, tokens, length, slot) = args[:n], args[n:]
        return prefill(config, params, kv_k, kv_v, tokens, length, slot,
                       use_pallas=use_pallas)

    fn.__name__ = f"prefill_s{seq}"
    return fn


def make_decode_fn(config: ModelConfig, *, use_pallas: bool = True) -> Callable:
    """Flat-args decode for AOT lowering: (params..., kv_k, kv_v, tokens, pos)."""
    n = len(config.param_specs())

    def fn(*args):
        params, (kv_k, kv_v, tokens, pos) = args[:n], args[n:]
        return decode_step(config, params, kv_k, kv_v, tokens, pos,
                           use_pallas=use_pallas)

    fn.__name__ = "decode"
    return fn
