"""Pure-jnp oracles for the Pallas kernels and the transformer blocks.

These are the CORE correctness signal: pytest asserts the Pallas kernels
(attention.py, decode.py) match these references to tight tolerances across
hypothesis-driven shape sweeps, and that the full model built on the kernels
matches the model built on these references.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def causal_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Reference causal multi-head attention over [H, S, DH]."""
    _, seq_len, head_dim = q.shape
    scale = 1.0 / math.sqrt(head_dim)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((seq_len, seq_len), bool))
    scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array
) -> jax.Array:
    """Reference batched single-token attention over the KV cache.

    q [B,H,DH], caches [B,H,S,DH], pos [B]; attends to positions <= pos[b].
    """
    _, _, s_max, head_dim = k_cache.shape
    scale = 1.0 / math.sqrt(head_dim)
    scores = jnp.einsum(
        "bhd,bhsd->bhs", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    idx = jnp.arange(s_max)[None, None, :]
    scores = jnp.where(idx <= pos[:, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", probs, v_cache.astype(jnp.float32)).astype(q.dtype)


def layer_norm_ref(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def mlp_ref(x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2
