"""L1 Pallas kernel: single-token KV-cache attention for the *decode* phase.

This is the token-sampling hot-spot (POLCA §2.3): one query vector per
sequence attends to the cached keys/values. The shape is a batched
matvec — memory-bandwidth-bound, low MXU occupancy — which is exactly why
the paper's token phase draws stable, *low* power and why frequency caps
barely hurt it (Fig. 5/7 mechanism; see DESIGN.md §Hardware-Adaptation).

The grid iterates (batch, head); each program streams the [S_max, DH] cache
rows for one (b, h) through VMEM and masks positions beyond the sequence's
current length.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale: float):
    """One (batch, head) grid step.

    q_ref: [DH] query for this (b, h).
    k_ref, v_ref: [S_max, DH] cache rows for this (b, h).
    pos_ref: [1] int32 — index of the current token; attend to [0, pos].
    o_ref: [DH] output.
    """
    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    pos = pos_ref[0]
    s_max = k.shape[0]
    scores = k @ q  # [S_max] — matvec: memory-bound, the token-phase shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (s_max,), 0)
    scores = jnp.where(idx <= pos, scores, _NEG_INF)
    m = scores.max()
    p = jnp.exp(scores - m)
    l = p.sum()
    o_ref[...] = ((p @ v) / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Batched single-token attention.

    q:        [B, H, DH]   query for the token currently being generated.
    k_cache:  [B, H, S_max, DH] keys, valid at positions <= pos[b].
    v_cache:  [B, H, S_max, DH] values.
    pos:      [B] int32 — current token index per sequence (its KV must
              already be written at this index).
    returns:  [B, H, DH] attention output.
    """
    batch, num_heads, s_max, head_dim = k_cache.shape
    scale = 1.0 / math.sqrt(head_dim)
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(batch, num_heads),
        in_specs=[
            pl.BlockSpec((None, None, head_dim), lambda b, h: (b, h, 0)),
            pl.BlockSpec((None, None, s_max, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, s_max, head_dim), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((None, None, head_dim), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, num_heads, head_dim), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, pos)


def vmem_report(s_max: int, head_dim: int, itemsize: int = 4) -> dict:
    """Static VMEM/bandwidth estimate for the decode kernel (see §Perf)."""
    kv_bytes = 2 * s_max * head_dim * itemsize
    q_bytes = head_dim * itemsize
    macs = 2 * s_max * head_dim  # k@q + p@v
    return {
        "kernel": "decode_step",
        "vmem_bytes_per_step": kv_bytes + q_bytes + s_max * 4,
        "bytes_moved_per_step": kv_bytes,
        "macs_per_grid_step": macs,
        # ~1 MAC per 4 bytes moved: firmly bandwidth-bound (vs prefill's
        # O(block) reuse) — the structural root of the paper's low token power.
        "arithmetic_intensity": macs / max(1, kv_bytes),
    }
