"""L1 Pallas kernel: blocked flash-attention for the *prefill* (prompt) phase.

This is the prompt-phase hot-spot the paper characterizes (POLCA §2.3): all
prompt tokens are processed in parallel, producing a large, MXU-saturating
matmul burst — the source of the >TDP power spikes in Fig. 4.

TPU adaptation of the classic CUDA flash-attention schedule (DESIGN.md
§Hardware-Adaptation):
  * the CUDA threadblock/SMEM tiling becomes a BlockSpec HBM->VMEM schedule:
    the grid iterates (head, q_block); each program holds one [BQ, DH] query
    tile plus streamed [BK, DH] key/value tiles in VMEM,
  * the tensor-core WMMA inner product becomes an MXU matmul (`q @ k.T`),
  * softmax is computed online (running max / normalizer) so no [S, S]
    score matrix ever materializes — VMEM footprint is O(BQ*DH + BK*DH).

Kernels are lowered with ``interpret=True``: on the CPU PJRT plugin this
becomes plain HLO (loops + dots) that the Rust runtime can execute; a real
TPU build would emit a Mosaic custom-call instead. Numerics are validated
against ``ref.py`` by pytest/hypothesis.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. On a real TPU these would be multiples of the MXU/VPU
# native tile (128 lanes); in interpret mode any divisor works and tests
# sweep several. VMEM estimate for the defaults (f32, DH=32):
#   q tile 16*32*4 = 2 KiB, k/v tiles 2*16*32*4 = 4 KiB, acc 2 KiB -> ~8 KiB
# far below the ~16 MiB VMEM budget; larger models scale BQ/BK up.
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One (head, q_block) grid step of causal flash attention.

    q_ref: [BQ, DH] VMEM tile of queries (head dim already selected).
    k_ref, v_ref: [S, DH] for the current head; streamed in [BK, DH] tiles.
    o_ref: [BQ, DH] output tile.
    """
    q = q_ref[...].astype(jnp.float32) * scale
    seq_len = k_ref.shape[0]
    block_q, head_dim = q.shape
    iq = pl.program_id(1)
    # Global positions of the query rows in this tile (column vector).
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    m0 = jnp.full((block_q, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, head_dim), jnp.float32)

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # [BQ, BK] — MXU matmul
        k_pos = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, _NEG_INF)  # causal mask
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    # Causality: the query tile iq only needs KV tiles up to its own end.
    num_k_blocks = (iq + 1) * block_q // block_k
    m, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Causal multi-head flash attention over [H, S, DH] arrays.

    Requires S % block_q == 0, S % block_k == 0 and block_q % block_k == 0
    (the causal KV-tile skip assumes query tiles cover whole KV tiles).
    """
    num_heads, seq_len, head_dim = q.shape
    if seq_len % block_q or seq_len % block_k:
        raise ValueError(f"seq_len {seq_len} not divisible by blocks ({block_q},{block_k})")
    if block_q % block_k:
        raise ValueError(f"block_q {block_q} must be a multiple of block_k {block_k}")
    scale = 1.0 / math.sqrt(head_dim)
    kernel = functools.partial(_flash_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(num_heads, seq_len // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, head_dim), lambda h, iq: (h, iq, 0)),
            pl.BlockSpec((None, seq_len, head_dim), lambda h, iq: (h, 0, 0)),
            pl.BlockSpec((None, seq_len, head_dim), lambda h, iq: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), lambda h, iq: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((num_heads, seq_len, head_dim), q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_report(seq_len: int, head_dim: int, block_q: int = DEFAULT_BLOCK_Q,
                block_k: int = DEFAULT_BLOCK_K, itemsize: int = 4) -> dict:
    """Static VMEM-footprint / MXU-work estimate for the prefill kernel.

    interpret=True gives no hardware counters, so the §Perf story for L1 is
    structural: bytes resident per grid step and MXU MAC count per step.
    """
    q_tile = block_q * head_dim * itemsize
    kv_tiles = 2 * block_k * head_dim * itemsize
    acc = block_q * head_dim * 4 + 2 * block_q * 4  # f32 accumulators + m/l
    scores = block_q * block_k * 4
    vmem_bytes = q_tile + kv_tiles + acc + scores
    # MACs per grid step: s = q@k.T and acc += p@v over all visited KV tiles.
    kv_steps = seq_len // block_k
    macs = 2 * block_q * block_k * head_dim * kv_steps
    return {
        "kernel": "flash_prefill",
        "block_q": block_q,
        "block_k": block_k,
        "vmem_bytes_per_step": vmem_bytes,
        "vmem_budget_fraction": vmem_bytes / (16 * 1024 * 1024),
        "macs_per_grid_step": macs,
        "arithmetic_intensity": macs / max(1, vmem_bytes),
    }
