"""AOT compile path: lower the L2 model (with L1 Pallas kernels) to HLO text.

Run once at build time (``make artifacts``); the Rust runtime loads the
emitted ``artifacts/*.hlo.txt`` via the `xla` crate's HLO text parser and
executes them on the PJRT CPU client. Python is never on the request path.

Why HLO *text* and not ``lowered.compile()`` / serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Emitted bundle (consumed by rust/src/runtime/artifacts.rs):
  artifacts/
    manifest.json        — model config, parameter table, artifact arg specs,
                           analytic FLOPs, L1 kernel VMEM/MXU report
    weights.bin          — all parameters, f32 little-endian, canonical order
    prefill_s{S}.hlo.txt — one prefill executable per sequence bucket
    decode.hlo.txt       — batched single-token decode executable
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as model_lib
from compile.kernels import attention as attn_kernel
from compile.kernels import decode as decode_kernel

# Prompt-length buckets compiled AOT. The coordinator pads each prompt up to
# the smallest bucket that fits (static shapes: one PJRT executable per
# bucket, mirroring production serving systems' shape bucketing).
PREFILL_BUCKETS = (16, 64)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_manifest(config: model_lib.ModelConfig, artifacts, params_table) -> dict:
    kv = config.kv_shape()
    return {
        "format_version": 1,
        "model": {
            "vocab": config.vocab,
            "d_model": config.d_model,
            "n_heads": config.n_heads,
            "n_layers": config.n_layers,
            "d_ff": config.d_ff,
            "max_seq": config.max_seq,
            "batch_slots": config.batch_slots,
            "d_head": config.d_head,
            "num_params": int(sum(p["elems"] for p in params_table)),
        },
        "kv_shape": list(kv),
        "weights_file": "weights.bin",
        "params": params_table,
        "artifacts": artifacts,
        "flops": {
            **{f"prefill_s{s}": config.prefill_flops(s) for s in PREFILL_BUCKETS},
            "decode_per_step": config.decode_flops(config.batch_slots, config.max_seq),
        },
        "kernel_report": [
            attn_kernel.vmem_report(config.max_seq, config.d_head,
                                    config.block_q, config.block_k),
            decode_kernel.vmem_report(config.max_seq, config.d_head),
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", action="store_true",
                    help="print the L1 kernel VMEM/MXU report and exit")
    args = ap.parse_args()

    config = model_lib.ModelConfig()
    if args.report:
        print(json.dumps(build_manifest(config, [], [])["kernel_report"], indent=2))
        return

    os.makedirs(args.out_dir, exist_ok=True)
    params = model_lib.init_params(config, seed=args.seed)
    specs = config.param_specs()

    # --- weights.bin + parameter table -----------------------------------
    params_table = []
    offset = 0
    with open(os.path.join(args.out_dir, "weights.bin"), "wb") as f:
        for (name, shape), value in zip(specs, params):
            raw = np.asarray(value, dtype="<f4").tobytes()
            f.write(raw)
            params_table.append({
                "name": name,
                "shape": list(shape),
                "elems": int(np.prod(shape)),
                "byte_offset": offset,
                "byte_len": len(raw),
            })
            offset += len(raw)

    kv = config.kv_shape()
    param_specs = [_spec(shape) for _, shape in specs]
    artifacts = []

    # KV-cache arguments are donated: XLA emits input_output_alias so the
    # multi-MB cache is updated in place instead of copied through every
    # dynamic-update-slice — measured ~30% off the decode step
    # (EXPERIMENTS.md §Perf). The aliasing survives the HLO-text path.
    n_params = len(specs)
    donate = (n_params, n_params + 1)

    # --- prefill, one bucket per compiled shape ---------------------------
    for seq in PREFILL_BUCKETS:
        fn = model_lib.make_prefill_fn(config, seq)
        lowered = jax.jit(fn, donate_argnums=donate).lower(
            *param_specs,
            _spec(kv), _spec(kv),
            _spec((seq,), jnp.int32),   # tokens (padded)
            _spec((), jnp.int32),       # length
            _spec((), jnp.int32),       # slot
        )
        name = f"prefill_s{seq}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        artifacts.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "kind": "prefill",
            "seq": seq,
            "extra_args": [
                {"role": "kv_k", "shape": list(kv), "dtype": "f32"},
                {"role": "kv_v", "shape": list(kv), "dtype": "f32"},
                {"role": "tokens", "shape": [seq], "dtype": "i32"},
                {"role": "length", "shape": [], "dtype": "i32"},
                {"role": "slot", "shape": [], "dtype": "i32"},
            ],
            "outputs": [
                {"role": "logits", "shape": [config.vocab], "dtype": "f32"},
                {"role": "kv_k", "shape": list(kv), "dtype": "f32"},
                {"role": "kv_v", "shape": list(kv), "dtype": "f32"},
            ],
        })
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    # --- decode ------------------------------------------------------------
    fn = model_lib.make_decode_fn(config)
    lowered = jax.jit(fn, donate_argnums=donate).lower(
        *param_specs,
        _spec(kv), _spec(kv),
        _spec((config.batch_slots,), jnp.int32),  # tokens
        _spec((config.batch_slots,), jnp.int32),  # pos
    )
    path = os.path.join(args.out_dir, "decode.hlo.txt")
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    artifacts.append({
        "name": "decode",
        "file": "decode.hlo.txt",
        "kind": "decode",
        "seq": 1,
        "extra_args": [
            {"role": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"role": "kv_v", "shape": list(kv), "dtype": "f32"},
            {"role": "tokens", "shape": [config.batch_slots], "dtype": "i32"},
            {"role": "pos", "shape": [config.batch_slots], "dtype": "i32"},
        ],
        "outputs": [
            {"role": "logits", "shape": [config.batch_slots, config.vocab], "dtype": "f32"},
            {"role": "kv_k", "shape": list(kv), "dtype": "f32"},
            {"role": "kv_v", "shape": list(kv), "dtype": "f32"},
        ],
    })
    print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    manifest = build_manifest(config, artifacts, params_table)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json "
          f"({manifest['model']['num_params']} params)", file=sys.stderr)

    # --- golden outputs: the Rust runtime asserts bit-compatible numerics
    # (within float tolerance) for one prefill + one decode step.
    golden = make_golden(config, params)
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden, f, indent=2)
    print(f"wrote {args.out_dir}/golden.json", file=sys.stderr)


def make_golden(config: model_lib.ModelConfig, params) -> dict:
    """Reference I/O pair for the Rust runtime round-trip test."""
    seq = PREFILL_BUCKETS[0]
    kv = config.kv_shape()
    rng = np.random.RandomState(1234)
    length = 10
    tokens = np.zeros(seq, dtype=np.int32)
    tokens[:length] = rng.randint(0, config.vocab, size=length)
    kv_k = jnp.zeros(kv, jnp.float32)
    kv_v = jnp.zeros(kv, jnp.float32)
    slot = 1
    logits, kv_k, kv_v = model_lib.prefill(
        config, params, kv_k, kv_v, jnp.asarray(tokens),
        jnp.int32(length), jnp.int32(slot))
    next_tok = int(jnp.argmax(logits))
    d_tokens = np.zeros(config.batch_slots, dtype=np.int32)
    d_pos = np.zeros(config.batch_slots, dtype=np.int32)
    d_tokens[slot] = next_tok
    d_pos[slot] = length
    d_logits, _, _ = model_lib.decode_step(
        config, params, kv_k, kv_v, jnp.asarray(d_tokens), jnp.asarray(d_pos))
    return {
        "prefill_bucket": seq,
        "tokens": tokens.tolist(),
        "length": length,
        "slot": slot,
        "prefill_logits_head": np.asarray(logits[:8]).astype(float).tolist(),
        "prefill_argmax": next_tok,
        "decode_tokens": d_tokens.tolist(),
        "decode_pos": d_pos.tolist(),
        "decode_logits_head": np.asarray(d_logits[slot, :8]).astype(float).tolist(),
        "decode_argmax": int(jnp.argmax(d_logits[slot])),
    }


if __name__ == "__main__":
    main()
