//! Integration tests for the fault-injection subsystem (ISSUE 3): the
//! empty-plan bit-identity property, and the cap-ignore escalation
//! guarantee — every policy must reach the brake path when its caps are
//! acknowledged but silently ignored.

use polca::faults::{FaultKind, FaultPlan};
use polca::policy::engine::PolicyKind;
use polca::simulation::run;
use polca::testing::{self, base_sim_config};

/// The acceptance property: an empty `FaultPlan` is bit-identical to
/// the baseline run — same RunReport bytes (compared via the full Debug
/// rendering, which prints every counter, percentile sample, and f64 at
/// round-trip precision) across random row sizes, seeds, and policies.
#[test]
fn property_empty_fault_plan_is_bit_identical() {
    testing::check(
        "faults-empty-plan-bit-identical",
        0xFA017,
        6,
        |rng| {
            let servers = rng.range_usize(4, 10);
            let seed = rng.next_u64();
            let policy = match rng.below(4) {
                0 => PolicyKind::Polca,
                1 => PolicyKind::NoCap,
                2 => PolicyKind::OneThreshLowPri,
                _ => PolicyKind::OneThreshAll,
            };
            // Oversubscribe sometimes so the control loop actually acts.
            let added = rng.range_usize(0, 6);
            (servers, seed, policy, added)
        },
        |&(servers, seed, policy, added)| {
            let mut a_cfg = base_sim_config(servers, 0.012, seed);
            a_cfg.policy_kind = policy;
            a_cfg.deployed_servers = servers + added;
            let mut b_cfg = a_cfg.clone();
            b_cfg.faults = Some(FaultPlan::new());
            let a = run(&a_cfg);
            let b = run(&b_cfg);
            let (da, db) = (format!("{a:?}"), format!("{b:?}"));
            if da == db {
                Ok(())
            } else {
                Err(format!("RunReport bytes diverged:\n  none: {da}\n  empty: {db}"))
            }
        },
    );
}

/// Escalation guarantee: a cap-ignore fault covering a heavily
/// oversubscribed run drives `brake_commands > 0` under *every*
/// `PolicyKind` — the capping policies because their caps visibly fail
/// to bite (containment escalation), and No-cap because the unthrottled
/// row crosses the breaker on its own.
#[test]
fn cap_ignore_drives_the_brake_path_under_every_policy() {
    for policy in PolicyKind::all() {
        let mut cfg = base_sim_config(12, 0.08, 42);
        cfg.deployed_servers = 22; // +83%: pushes past the breaker
        cfg.policy_kind = policy;
        cfg.brake_escalation_s = Some(120.0);
        let horizon = cfg.weeks * 7.0 * 86_400.0;
        cfg.faults = Some(FaultPlan::new().with(
            FaultKind::CapIgnore { server_frac: 1.0 },
            0.0,
            horizon + 1.0,
        ));
        let report = run(&cfg);
        assert!(
            report.brake_commands > 0,
            "{}: cap-ignore must force the brake path (report: {:?})",
            policy.name(),
            report.resilience
        );
        // No slow-path command changed any frequency, by construction:
        // commands were delivered/acked (counted) but every server
        // ignored them — the brake is the only thing that moved power.
        assert!(report.brake_time_s > 0.0, "{}", policy.name());
    }
}

/// Random fault plans never wedge the simulator: the run completes,
/// accounting is finite, and incidents are scored one-per-episode.
#[test]
fn random_fault_plans_are_replayable_and_scored() {
    let horizon_weeks = 0.05;
    let horizon_s = horizon_weeks * 7.0 * 86_400.0;
    for seed in [1u64, 2, 3] {
        let plan = FaultPlan::random(seed, horizon_s, 4);
        let mut cfg = base_sim_config(10, horizon_weeks, seed);
        cfg.deployed_servers = 13;
        cfg.brake_escalation_s = Some(120.0);
        cfg.faults = Some(plan.clone());
        let report = run(&cfg);
        assert_eq!(report.resilience.incidents.len(), plan.len());
        assert!(report.resilience.violation_s.is_finite());
        assert!(report.resilience.true_peak_norm > 0.0);
        // Determinism: the same plan and seed replays bit-identically.
        let again = run(&cfg);
        testing::assert_bit_identical(&report, &again, &format!("seed {seed} replay"));
    }
}
