//! Integration tests for the unified scenario layer (ISSUE 4):
//!
//! * **Property** — every built-in preset (and a population of randomly
//!   generated scenarios) round-trips through the TOML codec
//!   *bit-identically*: `Scenario::parse(&s.to_toml_string()) == s`.
//! * **Golden** — the scenario execution path reproduces the legacy
//!   per-subcommand wiring it replaced: `polca run inference-row
//!   --quick` builds the exact `SimConfig` the old `polca simulate`
//!   built, and a short run produces a bit-identical report on the same
//!   seed. The mixed-row and fault-drill presets are pinned the same
//!   way against the legacy `mixed`/`faults` wiring.
//! * **Dispatch** — `Scenario::run` routes row scenarios to the
//!   simulator, site scenarios to the fleet planner, and region
//!   scenarios to the region planner.

use polca::faults::FaultKind;
use polca::policy::engine::PolicyKind;
use polca::scenario::{preset, presets, FaultSpec, Outcome, Scenario};
use polca::simulation::{power_scale_for_row, run, MixedRowConfig, SimConfig};
use polca::testing::{full_suite, random_scenario};
use polca::util::rng::Rng;

// ---------------------------------------------------------------------------
// Property: TOML round-trips are bit-identical.
// ---------------------------------------------------------------------------

#[test]
fn every_preset_round_trips_through_toml_bit_identically() {
    for sc in presets() {
        let doc = sc.to_toml();
        let text = doc.render();
        let reparsed = polca::config::Toml::parse(&text).unwrap_or_else(|e| {
            panic!("preset '{}' rendered unparseable TOML: {e:#}\n{text}", sc.name)
        });
        assert_eq!(reparsed, doc, "preset '{}' document drifted:\n{text}", sc.name);
        let back = Scenario::from_toml(&reparsed)
            .unwrap_or_else(|e| panic!("preset '{}' failed to rebuild: {e:#}", sc.name));
        assert_eq!(back, sc, "preset '{}' is not bit-identical after TOML:\n{text}", sc.name);
        // The full save-path string (with header comments) too.
        assert_eq!(Scenario::parse(&sc.to_toml_string()).unwrap(), sc, "{}", sc.name);
    }
}

#[test]
fn random_scenarios_round_trip_through_toml_bit_identically() {
    // The generator lives in `polca::testing` (shared scaffolding); it
    // covers row, site, and region shapes. Quick tier keeps the case
    // count moderate; `POLCA_TEST_FULL=1` runs the full population.
    let cases = if full_suite() { 500 } else { 200 };
    let mut rng = Rng::new(0x5CE17A210);
    for i in 0..cases {
        let sc = random_scenario(&mut rng, i);
        let text = sc.to_toml_string();
        let back = Scenario::parse(&text)
            .unwrap_or_else(|e| panic!("scenario #{i} failed to reparse: {e:#}\n{text}"));
        assert_eq!(back, sc, "scenario #{i} drifted through TOML:\n{text}");
    }
}

// ---------------------------------------------------------------------------
// Golden: the scenario path reproduces the legacy wiring it replaced.
// ---------------------------------------------------------------------------

/// What the legacy `polca simulate` built (the pre-scenario `cmd_simulate`
/// body, inlined here verbatim as the golden reference).
fn legacy_simulate_config(weeks: f64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.policy_kind = PolicyKind::Polca;
    cfg.weeks = weeks;
    cfg.exp.seed = seed;
    cfg.exp.row.num_servers = 40;
    cfg.deployed_servers = 40;
    cfg.workload_power_mult = 1.0;
    cfg
}

#[test]
fn run_inference_row_quick_matches_legacy_simulate_config() {
    // `polca run inference-row --quick` == `polca simulate --weeks 0.15`
    // at the config level, field for field.
    let sc = preset("inference-row").unwrap().quick();
    let legacy = legacy_simulate_config(sc.weeks, sc.exp.seed);
    assert_eq!(format!("{:?}", sc.sim_config()), format!("{legacy:?}"));
}

#[test]
fn run_inference_row_report_is_bit_identical_to_legacy_simulate() {
    // A short horizon keeps the paired runs fast; the configs being
    // equal plus simulator determinism is what the golden claim rests
    // on, and this pins the reports themselves end to end.
    let mut sc = preset("inference-row").unwrap();
    sc.weeks = 0.02;
    sc.exp.seed = 9;
    let legacy = legacy_simulate_config(0.02, 9);
    let a = run(&sc.sim_config());
    let b = run(&legacy);
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

#[test]
fn mixed_row_preset_matches_legacy_mixed_run_config() {
    // The pre-scenario `SweepConfig::sim_config` + `cmd_mixed run`
    // defaults: POLCA, 40 servers, +0%, 0.25 weeks, seed 1, 50% training.
    let mut legacy = SimConfig::default();
    legacy.policy_kind = PolicyKind::Polca;
    legacy.weeks = 0.25;
    legacy.exp.seed = 1;
    legacy.exp.row.num_servers = 40;
    legacy.deployed_servers = 40;
    legacy.mixed = Some(MixedRowConfig { training_fraction: 0.5, ..Default::default() });
    let sc = preset("mixed-row").unwrap();
    assert_eq!(format!("{:?}", sc.sim_config()), format!("{legacy:?}"));
}

#[test]
fn cascade_faults_preset_matches_legacy_faults_run_config() {
    // The pre-scenario `MatrixConfig::sim_config` wiring: 16 servers at
    // +30%, row-size power calibration, escalation armed, cascade plan
    // scaled to the 0.1-week horizon.
    let horizon_s = 0.1 * 7.0 * 86_400.0;
    let mut legacy = SimConfig::default();
    legacy.policy_kind = PolicyKind::Polca;
    legacy.weeks = 0.1;
    legacy.exp.seed = 1;
    legacy.exp.row.num_servers = 16;
    legacy.deployed_servers = (16.0_f64 * 1.30).round() as usize;
    legacy.power_scale = power_scale_for_row(16);
    legacy.brake_escalation_s = Some(120.0);
    legacy.faults = Some(polca::faults::FaultPlan::scenario("cascade", horizon_s).unwrap());
    let sc = preset("cascade-faults").unwrap();
    assert_eq!(format!("{:?}", sc.sim_config()), format!("{legacy:?}"));
}

// ---------------------------------------------------------------------------
// Dispatch: one run() for rows and sites.
// ---------------------------------------------------------------------------

#[test]
fn row_scenario_runs_through_the_simulator() {
    let sc = Scenario::builder("row-dispatch")
        .servers(12)
        .added(0.3)
        .weeks(0.02)
        .seed(3)
        .build();
    let mut report = sc.run().unwrap();
    let Outcome::Row(row) = &report.outcome else {
        panic!("row scenario must dispatch to the simulator");
    };
    assert!(row.report.hp.completed + row.report.lp.completed > 0);
    let text = report.render();
    assert!(text.contains("SLO:"), "{text}");
    assert!(text.contains("impact vs uncapped"), "{text}");
}

#[test]
fn faulted_row_scenario_reports_incidents() {
    let sc = Scenario::builder("fault-dispatch")
        .servers(12)
        .added(0.3)
        .weeks(0.05)
        .seed(3)
        .faults_scenario("meter-bias")
        .escalate(120.0)
        .build();
    let mut report = sc.run().unwrap();
    let Outcome::Row(row) = &report.outcome else { panic!("row scenario") };
    assert_eq!(row.report.resilience.incidents.len(), 1);
    let text = report.render();
    assert!(text.contains("incident"), "{text}");
    assert!(text.contains("containment:"), "{text}");
}

#[test]
fn site_scenario_runs_through_the_planner() {
    let sc = Scenario::builder("site-dispatch")
        .policy(PolicyKind::NoCap)
        .weeks(0.005)
        .seed(1)
        .site(1)
        .site_search(10, 10)
        .serial()
        .build();
    let mut report = sc.run().unwrap();
    let Outcome::Site(site) = &report.outcome else {
        panic!("site scenario must dispatch to the planner");
    };
    assert_eq!(site.plan.baseline_servers, 16); // demo clusters are 16-server
    assert!(site.derated.is_none());
    assert!(report.render().contains("deployable servers"));
}

#[test]
fn region_scenario_runs_through_the_region_planner() {
    let sc = Scenario::builder("region-dispatch")
        .policy(PolicyKind::NoCap)
        .weeks(0.01)
        .seed(1)
        .region(2)
        .region_clusters(1)
        .region_grid(1.0)
        .region_search(10, 10)
        .serial()
        .build();
    let mut report = sc.run().unwrap();
    let Outcome::Region(plan) = &report.outcome else {
        panic!("region scenario must dispatch to the region planner");
    };
    assert_eq!(plan.site_names.len(), 2);
    assert_eq!(plan.baseline_servers, 24); // demo region clusters are 12-server
    assert!(plan.archetype_sims > 0, "planning must fill the archetype cache");
    let text = report.render();
    assert!(text.contains("region plan:"), "{text}");
}

#[test]
fn invalid_scenarios_are_rejected_before_running() {
    let mut sc = Scenario::default();
    sc.faults = FaultSpec::Plan(
        polca::faults::FaultPlan::new().with(FaultKind::TelemetryFreeze, -5.0, 10.0),
    );
    assert!(sc.validate().is_err());
    assert!(sc.run().is_err(), "run() must refuse what validate() rejects");
}

// ---------------------------------------------------------------------------
// The shipped example files stay loadable and valid.
// ---------------------------------------------------------------------------

#[test]
fn example_scenario_files_parse_validate_and_round_trip() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/scenarios");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/scenarios/ must ship with the tree") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        seen += 1;
        let sc = Scenario::load(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        sc.validate().unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let back = Scenario::parse(&sc.to_toml_string()).unwrap();
        assert_eq!(back, sc, "{} does not round-trip", path.display());
    }
    assert!(seen >= 4, "expected several example scenarios, found {seen}");
}
