//! Integration tests for the fleet layer: parallel/serial determinism,
//! compositional aggregation invariants, heterogeneous SKU plumbing, and
//! planner structure.

use polca::fleet::parallel::{cluster_seeds, run_site, SiteRunConfig};
use polca::fleet::planner::{plan_site, PlannerConfig};
use polca::fleet::site::{ClusterSpec, Feed, SiteSpec};
use polca::fleet::sku;
use polca::policy::engine::PolicyKind;

/// A small heterogeneous site (one cluster per SKU) cheap enough for CI.
fn small_site() -> SiteSpec {
    let mut clusters: Vec<ClusterSpec> = sku::registry()
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            let mut c = ClusterSpec::new(&format!("c{i}-{}", s.name), s, 12);
            c.phase_offset_s = i as f64 * 4.0 * 3600.0;
            c
        })
        .collect();
    clusters[0].added_frac = 0.25; // one oversubscribed cluster in the mix
    let budgets: Vec<f64> = clusters.iter().map(|c| c.budget_w()).collect();
    let feeds = vec![
        Feed { name: "feed0".into(), clusters: vec![0, 1], capacity_w: budgets[0] + budgets[1] },
        Feed { name: "feed1".into(), clusters: vec![2], capacity_w: budgets[2] },
    ];
    let total: f64 = budgets.iter().sum();
    SiteSpec {
        name: "test-site".into(),
        clusters,
        feeds,
        ups_efficiency: 0.94,
        substation_budget_w: total / 0.94,
    }
}

fn quick_rc(parallel: bool) -> SiteRunConfig {
    SiteRunConfig { weeks: 0.02, seed: 11, sample_s: 120.0, parallel, ..Default::default() }
}

/// The acceptance-critical invariant: a parallel site run is
/// bit-identical to the serial one at the same seed.
#[test]
fn parallel_site_identical_to_serial() {
    let site = small_site();
    let par = run_site(&site, PolicyKind::Polca, &quick_rc(true));
    let ser = run_site(&site, PolicyKind::Polca, &quick_rc(false));
    assert_eq!(par.clusters.len(), ser.clusters.len());
    for (a, b) in par.clusters.iter().zip(&ser.clusters) {
        assert_eq!(a.seed, b.seed, "{}", a.name);
        assert_eq!(a.report.hp.completed, b.report.hp.completed, "{}", a.name);
        assert_eq!(a.report.lp.completed, b.report.lp.completed, "{}", a.name);
        assert_eq!(a.report.brake_events, b.report.brake_events, "{}", a.name);
        assert_eq!(a.report.cap_commands, b.report.cap_commands, "{}", a.name);
        assert!(
            (a.report.power_peak - b.report.power_peak).abs() == 0.0,
            "{}: {} vs {}",
            a.name,
            a.report.power_peak,
            b.report.power_peak
        );
        let (mut ra, mut rb) = (a.report.clone(), b.report.clone());
        assert!((ra.hp.latency.p99() - rb.hp.latency.p99()).abs() == 0.0, "{}", a.name);
        assert!((ra.lp.latency.p99() - rb.lp.latency.p99()).abs() == 0.0, "{}", a.name);
    }
    // The composed traces must match sample for sample, bit for bit.
    assert_eq!(par.trace.site_w, ser.trace.site_w);
    assert_eq!(par.substation_peak_w, ser.substation_peak_w);
}

/// Site trace == sum of per-cluster traces (phase offsets live in the
/// arrival clocks, so composition is a plain sample-wise sum), and each
/// cluster's trace is its own simulated series in watts.
#[test]
fn site_trace_is_sum_of_cluster_traces() {
    let site = small_site();
    let o = run_site(&site, PolicyKind::NoCap, &quick_rc(false));
    assert!(!o.trace.site_w.is_empty());
    let n = o.trace.site_w.len();
    for j in 0..n {
        let sum: f64 = (0..site.clusters.len()).map(|i| o.trace.cluster_w[i][j]).sum();
        assert_eq!(o.trace.site_w[j], sum, "sample {j}");
    }
    // Cluster trace = simulated normalized series × breaker budget.
    for (i, c) in o.clusters.iter().enumerate() {
        for (j, &(_, norm)) in c.report.power_series.iter().take(n).enumerate() {
            let expect = norm * c.budget_w;
            assert!(
                (o.trace.cluster_w[i][j] - expect).abs() < 1e-9,
                "cluster {i} sample {j}: {} vs {expect}",
                o.trace.cluster_w[i][j]
            );
        }
    }
}

/// Phase offsets are physical: the same cluster phased onto its diurnal
/// peak serves measurably more traffic than one sitting in the trough
/// (the short test window starts at the overnight trough, hour 0).
#[test]
fn phase_offset_shifts_cluster_load_in_time() {
    let base = ClusterSpec::new("c-trough", sku::find("dgx-a100").unwrap(), 12);
    let mut phased = base.clone();
    phased.name = "c-peak".into();
    phased.phase_offset_s = 11.0 * 3600.0; // hours 0..3.4 see 11:00..14:24
    let make_site = |c: ClusterSpec| {
        let budget = c.budget_w();
        SiteSpec {
            name: "phase-test".into(),
            clusters: vec![c],
            feeds: vec![],
            ups_efficiency: 0.94,
            substation_budget_w: budget / 0.94,
        }
    };
    let rc = quick_rc(false);
    let at_trough = run_site(&make_site(base), PolicyKind::NoCap, &rc);
    let at_peak = run_site(&make_site(phased), PolicyKind::NoCap, &rc);
    let done = |o: &polca::fleet::parallel::SiteOutcome| {
        o.clusters[0].report.hp.completed + o.clusters[0].report.lp.completed
    };
    assert!(
        done(&at_peak) as f64 > done(&at_trough) as f64 * 1.3,
        "peak-phased {} vs trough {}",
        done(&at_peak),
        done(&at_trough)
    );
    // More load means more power through the same breaker budget.
    assert!(at_peak.trace.mean_w() > at_trough.trace.mean_w());
}

/// Heterogeneous SKUs actually differ end to end: the H100 cluster has a
/// bigger breaker budget than the A100 cluster and both draw plausibly.
#[test]
fn heterogeneous_skus_flow_through_simulation() {
    let site = small_site();
    let o = run_site(&site, PolicyKind::NoCap, &quick_rc(false));
    let a100 = &o.clusters[0];
    let h100 = &o.clusters[1];
    assert!(h100.budget_w > a100.budget_w * 1.3, "{} vs {}", h100.budget_w, a100.budget_w);
    for c in &o.clusters {
        assert!(c.report.hp.completed + c.report.lp.completed > 0, "{} served nothing", c.name);
        assert!(
            c.report.power_peak > 0.05 && c.report.power_peak < 2.0,
            "{} peak {}",
            c.name,
            c.report.power_peak
        );
    }
}

/// Per-cluster seeds are deterministic, order-stable, and distinct.
#[test]
fn cluster_seed_derivation_is_stable() {
    let a = cluster_seeds(7, 16);
    assert_eq!(a, cluster_seeds(7, 16));
    assert_eq!(&a[..3], &cluster_seeds(7, 3)[..]);
    let mut dedup = a.clone();
    dedup.sort();
    dedup.dedup();
    assert_eq!(dedup.len(), 16);
}

/// Planner structure: the result respects its own bounds and reports a
/// consistent chosen-point evaluation.
#[test]
fn planner_plan_is_consistent() {
    let mut site = small_site();
    for c in &mut site.clusters {
        c.added_frac = 0.0;
    }
    let pc = PlannerConfig {
        weeks: 0.02,
        seed: 5,
        sample_s: 120.0,
        parallel: true,
        max_added_pct: 20,
        step_pct: 10,
        ..Default::default()
    };
    let plan = plan_site(&site, PolicyKind::Polca, &pc);
    assert!(plan.added_pct <= pc.max_added_pct);
    assert_eq!(plan.baseline_servers, 36);
    assert_eq!(plan.outcome.clusters.len(), 3);
    if plan.feasible {
        assert!(plan.deployable_servers >= plan.baseline_servers);
        assert!(plan.outcome.feasible(&pc.slo));
        assert!(plan.headroom_frac >= -1e-12);
    }
    assert!(plan.site_peak_w > 0.0);
    assert_eq!(plan.substation_budget_w, site.substation_budget_w);
}
