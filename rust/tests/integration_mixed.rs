//! Integration tests for mixed-workload rows (§2.4 / §7): the
//! bit-identity regression guard for the inference-only path, the
//! row-level composition of synchronized training troughs, and the
//! stagger mitigation.

use polca::policy::engine::PolicyKind;
use polca::power::server::ServerPowerModel;
use polca::power::training::TrainingProfile;
use polca::simulation::{run, MixedRowConfig, SimConfig};
use polca::testing;

fn base_cfg(servers: usize, weeks: f64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.weeks = weeks;
    cfg.exp.row.num_servers = servers;
    cfg.deployed_servers = servers;
    cfg.exp.seed = seed;
    cfg.power_scale = 1.35; // small-row calibration (see simulation tests)
    cfg
}

/// ISSUE-2 regression guard, as a property: a mixed row at 100%
/// inference is bit-identical to the pre-refactor inference-only
/// simulator path across random row sizes, seeds, and policies —
/// same events, same completions, same power statistics, bitwise.
#[test]
fn property_pure_inference_mixed_row_is_bit_identical() {
    testing::check(
        "mixed-0pct-bit-identical",
        0xA11CE,
        6,
        |rng| {
            let servers = rng.range_usize(4, 10);
            let seed = rng.next_u64();
            let policy = match rng.below(3) {
                0 => PolicyKind::Polca,
                1 => PolicyKind::NoCap,
                _ => PolicyKind::OneThreshAll,
            };
            (servers, seed, policy)
        },
        |&(servers, seed, policy)| {
            let mut a_cfg = base_cfg(servers, 0.012, seed);
            a_cfg.policy_kind = policy;
            let mut b_cfg = a_cfg.clone();
            b_cfg.mixed = Some(MixedRowConfig::default()); // training_fraction 0.0
            let mut a = run(&a_cfg);
            let mut b = run(&b_cfg);
            let same = a.hp.completed == b.hp.completed
                && a.lp.completed == b.lp.completed
                && a.hp.dropped == b.hp.dropped
                && a.lp.dropped == b.lp.dropped
                && a.events == b.events
                && a.brake_events == b.brake_events
                && a.cap_commands == b.cap_commands
                && a.uncap_commands == b.uncap_commands
                && a.brake_commands == b.brake_commands
                && a.power_peak == b.power_peak
                && a.power_mean == b.power_mean
                && a.spike_2s == b.spike_2s
                && a.hp.latency.p99() == b.hp.latency.p99()
                && a.lp.latency.p99() == b.lp.latency.p99()
                && b.train.iters == 0;
            if same {
                Ok(())
            } else {
                Err(format!("diverged:\n  none: {}\n  some: {}", a.summary(), b.summary()))
            }
        },
    );
}

fn pure_training_run(servers_per_job: usize, stagger_s: f64) -> polca::metrics::RunReport {
    let profile = TrainingProfile::large_llm();
    let mut cfg = base_cfg(8, 0.004, 7); // ~40 simulated minutes
    cfg.policy_kind = PolicyKind::NoCap;
    cfg.series_sample_s = 0.5; // instantaneous samples, finer than any phase
    cfg.mixed = Some(MixedRowConfig {
        training_fraction: 1.0,
        servers_per_job,
        job_stagger_s: stagger_s,
        profile,
    });
    run(&cfg)
}

/// Row swing of the instantaneous power series, ignoring the warmup
/// window in which staggered jobs have not all started yet.
fn row_swing(report: &polca::metrics::RunReport, warmup_s: f64) -> f64 {
    let vals: Vec<f64> = report
        .power_series
        .iter()
        .filter(|&&(t, _)| t >= warmup_s)
        .map(|&(_, p)| p)
        .collect();
    assert!(vals.len() > 100, "series too short: {}", vals.len());
    let max = vals.iter().cloned().fold(f64::MIN, f64::max);
    let min = vals.iter().cloned().fold(f64::MAX, f64::min);
    max - min
}

/// §2.4: one synchronized job's trough composes at the row level — the
/// row's instantaneous swing equals a single server's swing fraction
/// of provisioned power, because every member hits the trough at the
/// same instant.
#[test]
fn synchronized_training_troughs_compose_at_row_level() {
    let profile = TrainingProfile::large_llm();
    let report = pure_training_run(0, 0.0);
    assert!(report.train.iters > 100, "iters={}", report.train.iters);

    let model = ServerPowerModel::default();
    let expected = (model.training_power_w(profile.peak_frac)
        - model.training_power_w(profile.sync_trough_frac))
        / model.provisioned_w();
    let swing = row_swing(&report, 2.0 * profile.iter_time_s);
    assert!(
        (swing - expected).abs() < 1e-6,
        "synchronized row swing {swing} must equal the per-server swing {expected}"
    );
    assert!(expected > 0.3, "the §2.4 swing must be material: {expected}");
}

/// §7 mitigation: staggering two half-row jobs by half an iteration
/// de-aligns their troughs, cutting the row-level swing roughly in
/// half — colocation structure, not just capping, controls the swing.
#[test]
fn staggered_jobs_shrink_the_row_swing() {
    let profile = TrainingProfile::large_llm();
    let sync = pure_training_run(0, 0.0);
    let staggered = pure_training_run(4, profile.iter_time_s / 2.0);
    let warmup = 2.0 * profile.iter_time_s;
    let s_sync = row_swing(&sync, warmup);
    let s_stag = row_swing(&staggered, warmup);
    assert!(
        s_stag < 0.7 * s_sync,
        "staggered swing {s_stag} must be well below synchronized {s_sync}"
    );
    // Both schedules do the same total work (uncapped, same horizon).
    let iter_ratio = staggered.train.iters as f64 / sync.train.iters as f64;
    assert!((iter_ratio - 1.0).abs() < 0.02, "iters {iter_ratio}");
}

/// Mixing training into an inference row raises its floor but the
/// inference side keeps serving: the §7 colocation sanity check.
#[test]
fn half_training_row_serves_and_trains() {
    let mut cfg = base_cfg(8, 0.02, 11);
    cfg.mixed = Some(MixedRowConfig { training_fraction: 0.5, ..Default::default() });
    let report = run(&cfg);
    assert!(report.train.iters > 0);
    assert!(report.hp.completed + report.lp.completed > 20);
    // Training servers are LP by §7 pinning, so any caps the policy
    // issues target them first; HP inference keeps its latency profile.
    assert!(report.power_peak < 1.05);
}
