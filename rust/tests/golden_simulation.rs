//! Golden tests pinning the layered simulator to the pre-split
//! monolith, bit for bit.
//!
//! The `reference` module below is the monolithic `Sim` exactly as it
//! shipped before the `simulation/` package was decomposed into layers
//! (ISSUE 5) — the same event loop, the same RNG fork order, the same
//! settlement arithmetic, transcribed against the crate's public API.
//! Every test runs one config through both implementations and asserts
//! full `Debug`-render equality of the reports: the strongest
//! "refactor changed nothing" claim expressible without fixture files,
//! and one that re-verifies itself on every future edit instead of
//! going stale the way a frozen snapshot would.
//!
//! The configs cover every layer the split touched: the plain row,
//! oversubscription with active capping and brakes, mixed training
//! rows (staggered multi-job), fault plans of every kind, SKU + perf
//! overrides, the Fig-17 power multiplier, diurnal phase offsets,
//! lossy OOB, containment escalation, and the unprotected baseline.

use polca::simulation::{run, MixedRowConfig, SimConfig};

/// The pre-split monolithic simulator, kept verbatim as the golden
/// reference. Do not "improve" this module: its value is that it is
/// the old wiring, byte for byte of behavior.
mod reference {
    use polca::characterize::catalog::{self, ModelSpec};
    use polca::cluster::hierarchy::{JobKind, Priority, Row};
    use polca::cluster::oob::{OobChannel, OobCommand};
    use polca::cluster::telemetry::TelemetryBuffer;
    use polca::faults::{FaultEvent, FaultKind};
    use polca::metrics::{IncidentOutcome, RunReport};
    use polca::perfmodel::{ExecPhase, RequestExec};
    use polca::policy::engine::{Action, PolicyEngine};
    use polca::power::gpu::{CapMode, Phase};
    use polca::power::training::TrainingPowerModel;
    use polca::sim::{secs, to_secs, EventQueue, SimTime};
    use polca::simulation::SimConfig;
    use polca::util::rng::Rng;
    use polca::workload::arrivals::ArrivalProcess;
    use polca::workload::spec::{assign_servers, sample_request, WorkloadSpec};

    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    enum Ev {
        Arrival { server: u32 },
        PhaseEnd { server: u32, gen: u32 },
        Telemetry,
        OobApply,
        TrainStart { job: u32 },
        TrainPhase { job: u32, gen: u32 },
        SampleSeries,
        FaultStart { fault: u32 },
        FaultEnd { fault: u32 },
        End,
    }

    #[derive(Debug, Clone)]
    struct InFlight {
        exec: RequestExec,
        arrived_s: f64,
        priority: Priority,
    }

    #[derive(Debug, Clone)]
    struct QueuedReq {
        input: f64,
        output: f64,
        arrived_s: f64,
    }

    struct ServerState {
        priority: Priority,
        kind: JobKind,
        workload_idx: usize,
        freq_cap_mhz: Option<f64>,
        current: Option<InFlight>,
        queued: Option<QueuedReq>,
        arrivals: ArrivalProcess,
        rng: Rng,
        gen: u32,
        last_advance_s: f64,
        power_w: f64,
        train_level: f64,
    }

    struct TrainJob {
        servers: Vec<usize>,
        model: TrainingPowerModel,
        start_s: f64,
        gen: u32,
        phase_idx: usize,
        iter_started_s: f64,
        iter_wall_s: f64,
    }

    /// Run one simulation through the pre-split wiring.
    pub fn run(cfg: &SimConfig) -> RunReport {
        Sim::new(cfg).run()
    }

    fn targets(cmd: &OobCommand, p: Priority) -> bool {
        match cmd {
            OobCommand::FreqCap { target, .. } | OobCommand::Uncap { target } => *target == p,
            OobCommand::PowerBrake | OobCommand::ReleaseBrake => false,
        }
    }

    struct Sim<'a> {
        cfg: &'a SimConfig,
        model: ModelSpec,
        specs: Vec<WorkloadSpec>,
        row: Row,
        servers: Vec<ServerState>,
        train_jobs: Vec<TrainJob>,
        queue: EventQueue<Ev>,
        policy: PolicyEngine,
        oob: OobChannel,
        telemetry: TelemetryBuffer,
        braked: bool,
        brake_engaged_at: f64,
        row_power_w: f64,
        energy_acc_ws: f64,
        last_power_change_s: f64,
        last_telemetry_s: f64,
        now_s: f64,
        report: RunReport,
        horizon: SimTime,
        fault_events: Vec<FaultEvent>,
        meter_bias: f64,
        budget_mult: f64,
        cap_ignore: Vec<bool>,
        acked_lp: Option<f64>,
        acked_hp: Option<f64>,
        lp_last_issue_s: f64,
        hp_last_issue_s: f64,
        cur_incident: Option<usize>,
        incident_last_violation: Vec<Option<f64>>,
    }

    impl<'a> Sim<'a> {
        fn new(cfg: &'a SimConfig) -> Self {
            let mut model = catalog::find(&cfg.model_name).expect("model not in catalog");
            if cfg.workload_power_mult != 1.0 {
                model.power.prompt_peak_at_256 *= cfg.workload_power_mult;
                model.power.prompt_peak_at_8192 *= cfg.workload_power_mult;
                model.power.token_mean_at_b1 *= cfg.workload_power_mult;
                model.power.token_mean_at_b16 *= cfg.workload_power_mult;
            }
            if cfg.perf_mult != 1.0 {
                model.prompt_tokens_per_s *= cfg.perf_mult;
                model.decode_tokens_per_s *= cfg.perf_mult;
            }
            let mut power_model = cfg.server_model.clone().unwrap_or_else(|| {
                polca::power::server::ServerPowerModel { calib: model.power, ..Default::default() }
            });
            if cfg.server_model.is_some() && cfg.workload_power_mult != 1.0 {
                let c = &mut power_model.calib;
                c.prompt_peak_at_256 *= cfg.workload_power_mult;
                c.prompt_peak_at_8192 *= cfg.workload_power_mult;
                c.token_mean_at_b1 *= cfg.workload_power_mult;
                c.token_mean_at_b16 *= cfg.workload_power_mult;
            }
            let mut root_rng = Rng::new(cfg.exp.seed ^ 0x9E3779B97F4A7C15);
            let mut row =
                Row::provision(cfg.exp.row.num_servers, cfg.deployed_servers, power_model);
            let specs = polca::workload::spec::table4();
            assign_servers(&mut row, &specs, 0, cfg.lp_fraction_override, &mut root_rng);
            let train_count = cfg
                .mixed
                .as_ref()
                .map(|m| {
                    ((m.training_fraction * row.servers.len() as f64).round() as usize)
                        .min(row.servers.len())
                })
                .unwrap_or(0);
            if train_count > 0 {
                polca::workload::spec::mark_training(&mut row, train_count);
            }

            let mut mean_service: Vec<f64> = Vec::new();
            let mut est_rng = root_rng.fork(77);
            for spec in &specs {
                let mut acc = 0.0;
                let n = 400;
                for _ in 0..n {
                    let (i, o) = sample_request(spec, &mut est_rng);
                    acc += model.request_latency_s(i, o, 1.0, 1.0);
                }
                mean_service.push(acc / n as f64);
            }

            let idle_frac = row.power_model.calib.idle_frac;
            let servers = row
                .servers
                .iter()
                .map(|s| {
                    let rate = cfg.peak_utilization / mean_service[s.workload_idx];
                    ServerState {
                        priority: s.priority,
                        kind: s.job,
                        workload_idx: s.workload_idx,
                        freq_cap_mhz: None,
                        current: None,
                        queued: None,
                        arrivals: ArrivalProcess::new(rate, root_rng.fork(1000 + s.id as u64))
                            .with_phase(cfg.diurnal_phase_s),
                        rng: root_rng.fork(2000 + s.id as u64),
                        gen: 0,
                        last_advance_s: 0.0,
                        power_w: 0.0,
                        train_level: idle_frac,
                    }
                })
                .collect();

            let mut train_jobs = Vec::new();
            if let Some(m) = &cfg.mixed {
                let train_idxs: Vec<usize> = row
                    .servers
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| s.job == JobKind::Training)
                    .map(|(i, _)| i)
                    .collect();
                if !train_idxs.is_empty() {
                    let per =
                        if m.servers_per_job == 0 { train_idxs.len() } else { m.servers_per_job };
                    for (j, chunk) in train_idxs.chunks(per.max(1)).enumerate() {
                        train_jobs.push(TrainJob {
                            servers: chunk.to_vec(),
                            model: TrainingPowerModel::with_calib(m.profile, row.power_model.calib),
                            start_s: j as f64 * m.job_stagger_s.max(0.0),
                            gen: 0,
                            phase_idx: 0,
                            iter_started_s: 0.0,
                            iter_wall_s: m.profile.iter_time_s,
                        });
                    }
                }
            }
            let mut report = RunReport::default();
            if !train_jobs.is_empty() {
                report.train.nominal_iter_s =
                    cfg.mixed.as_ref().map(|m| m.profile.iter_time_s).unwrap_or(0.0);
            }

            let mut policy = PolicyEngine::new(cfg.policy_kind, cfg.exp.policy.clone());
            policy.escalate_to_brake_after_s = cfg.brake_escalation_s;
            let fault_events = cfg
                .faults
                .as_ref()
                .map(|p| p.normalized().expect("invalid fault plan"))
                .unwrap_or_default();
            let oob = OobChannel::new(
                cfg.exp.row.oob_latency_s,
                cfg.exp.row.power_brake_latency_s,
                cfg.exp.seed ^ 0xBEEF,
            )
            .with_unreliability(cfg.oob_loss_prob, cfg.oob_jitter_frac);
            let horizon = secs(cfg.weeks * 7.0 * 86_400.0);
            let telemetry = TelemetryBuffer::new(
                cfg.exp.row.telemetry_delay_s,
                cfg.weeks * 7.0 * 86_400.0 + 1.0,
            );

            let n_servers = row.servers.len();
            let n_faults = fault_events.len();
            Sim {
                cfg,
                model,
                specs,
                row,
                servers,
                train_jobs,
                queue: EventQueue::with_capacity(1024),
                policy,
                oob,
                telemetry,
                braked: false,
                brake_engaged_at: 0.0,
                row_power_w: 0.0,
                energy_acc_ws: 0.0,
                last_power_change_s: 0.0,
                last_telemetry_s: 0.0,
                now_s: 0.0,
                report,
                horizon,
                fault_events,
                meter_bias: 1.0,
                budget_mult: 1.0,
                cap_ignore: vec![false; n_servers],
                acked_lp: None,
                acked_hp: None,
                lp_last_issue_s: f64::NEG_INFINITY,
                hp_last_issue_s: f64::NEG_INFINITY,
                cur_incident: None,
                incident_last_violation: vec![None; n_faults],
            }
        }

        fn freq_ratio(&self, idx: usize) -> f64 {
            if self.braked {
                return self.cfg.exp.policy.brake_freq_mhz / self.cfg.exp.policy.max_freq_mhz;
            }
            match self.servers[idx].freq_cap_mhz {
                Some(mhz) => mhz / self.cfg.exp.policy.max_freq_mhz,
                None => 1.0,
            }
        }

        fn cap_mode(&self, idx: usize) -> CapMode {
            if self.braked {
                CapMode::FreqCap { mhz: self.cfg.exp.policy.brake_freq_mhz }
            } else {
                match self.servers[idx].freq_cap_mhz {
                    Some(mhz) => CapMode::FreqCap { mhz },
                    None => CapMode::None,
                }
            }
        }

        fn server_phase(&self, idx: usize) -> Phase {
            match &self.servers[idx].current {
                None => Phase::Idle,
                Some(inf) => match inf.exec.phase() {
                    ExecPhase::Prompt => {
                        Phase::Prompt { total_input: inf.exec.input * inf.exec.batch }
                    }
                    ExecPhase::Token | ExecPhase::Done => Phase::Token { batch: inf.exec.batch },
                },
            }
        }

        fn settle_energy(&mut self) {
            let dt = (self.now_s - self.last_power_change_s).max(0.0);
            if dt > 0.0 {
                self.energy_acc_ws += self.row_power_w * dt;
                let scaled_w = self.cfg.power_scale * self.row_power_w;
                let budget_eff_w = self.row.budget_w * self.budget_mult;
                let r = &mut self.report.resilience;
                r.true_peak_norm = r.true_peak_norm.max(scaled_w / budget_eff_w);
                if scaled_w > budget_eff_w {
                    r.violation_s += dt;
                    r.overshoot_ws += (scaled_w - budget_eff_w) * dt;
                    r.peak_overshoot_w = r.peak_overshoot_w.max(scaled_w - budget_eff_w);
                    if let Some(i) = self.cur_incident {
                        self.incident_last_violation[i] = Some(self.now_s);
                    }
                } else if let Some(i) = self.cur_incident {
                    if self.now_s >= self.fault_events[i].end_s() {
                        self.cur_incident = None;
                    }
                }
            }
            self.last_power_change_s = self.now_s;
        }

        fn training_server_w(&self, idx: usize) -> f64 {
            let cap = self.cap_mode(idx);
            let nominal = self.servers[idx].train_level;
            let frac = self.row.power_model.calib.capped_level(nominal, cap);
            self.row.power_model.training_power_w(frac)
        }

        fn refresh_power(&mut self, idx: usize) {
            self.settle_energy();
            let w = match self.servers[idx].kind {
                JobKind::Inference => {
                    let phase = self.server_phase(idx);
                    let cap = self.cap_mode(idx);
                    self.row.power_model.server_power_w(phase, cap, false)
                }
                JobKind::Training => self.training_server_w(idx) / self.cfg.power_scale,
            };
            let s = &mut self.servers[idx];
            self.row_power_w += w - s.power_w;
            s.power_w = w;
        }

        fn averaged_row_power(&mut self) -> f64 {
            self.settle_energy();
            let window = (self.now_s - self.last_telemetry_s).max(1e-9);
            let avg_w = self.energy_acc_ws / window;
            self.energy_acc_ws = 0.0;
            self.last_telemetry_s = self.now_s;
            self.meter_bias * self.cfg.power_scale * avg_w
                / (self.row.budget_w * self.budget_mult)
        }

        fn normalized_row_power(&self) -> f64 {
            self.cfg.power_scale * self.row_power_w / self.row.budget_w
        }

        fn start_request(
            &mut self,
            idx: usize,
            input: f64,
            output: f64,
            arrived_s: f64,
            now_s: f64,
        ) {
            let exec = RequestExec::new(&self.model, input, output, 1.0);
            self.servers[idx].current = Some(InFlight {
                exec,
                arrived_s,
                priority: self.servers[idx].priority,
            });
            self.servers[idx].last_advance_s = now_s;
            self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
            self.refresh_power(idx);
            self.schedule_phase_end(idx, now_s);
        }

        fn schedule_phase_end(&mut self, idx: usize, now_s: f64) {
            let ratio = self.freq_ratio(idx);
            let wall = match &self.servers[idx].current {
                Some(inf) if inf.exec.phase() != ExecPhase::Done => {
                    inf.exec.wall_to_phase_end(&self.model, ratio)
                }
                _ => return,
            };
            let gen = self.servers[idx].gen;
            self.queue
                .schedule_at(secs(now_s + wall) + 1, Ev::PhaseEnd { server: idx as u32, gen });
        }

        fn advance_work(&mut self, idx: usize, now_s: f64) {
            let ratio = self.freq_ratio(idx);
            let last = self.servers[idx].last_advance_s;
            if let Some(inf) = &mut self.servers[idx].current {
                let dt = (now_s - last).max(0.0);
                if dt > 0.0 {
                    inf.exec.advance(&self.model, ratio, dt);
                }
            }
            self.servers[idx].last_advance_s = now_s;
        }

        fn set_server_cap(&mut self, idx: usize, cap: Option<f64>, now_s: f64) {
            if self.servers[idx].freq_cap_mhz == cap {
                return;
            }
            self.advance_work(idx, now_s);
            self.servers[idx].freq_cap_mhz = cap;
            self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
            self.refresh_power(idx);
            self.schedule_phase_end(idx, now_s);
        }

        fn set_brake(&mut self, on: bool, now_s: f64) {
            if self.braked == on {
                return;
            }
            for idx in 0..self.servers.len() {
                self.advance_work(idx, now_s);
            }
            self.braked = on;
            if on {
                self.brake_engaged_at = now_s;
            } else {
                self.report.brake_time_s += now_s - self.brake_engaged_at;
            }
            for idx in 0..self.servers.len() {
                self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
                self.refresh_power(idx);
                self.schedule_phase_end(idx, now_s);
            }
        }

        fn on_arrival(&mut self, idx: usize, now_s: f64) {
            let next = self.servers[idx].arrivals.next_after(now_s);
            self.queue.schedule_at(secs(next), Ev::Arrival { server: idx as u32 });

            let spec = &self.specs[self.servers[idx].workload_idx];
            let (input, output) = sample_request(spec, &mut self.servers[idx].rng);
            if self.servers[idx].current.is_none() {
                self.start_request(idx, input, output, now_s, now_s);
            } else if self.servers[idx].queued.is_none() {
                self.servers[idx].queued = Some(QueuedReq { input, output, arrived_s: now_s });
            } else {
                let pri = self.servers[idx].priority;
                self.report.by_priority(pri).dropped += 1;
            }
        }

        fn on_phase_end(&mut self, idx: usize, gen: u32, now_s: f64) {
            if self.servers[idx].gen != gen {
                return;
            }
            self.advance_work(idx, now_s);
            let phase = self.servers[idx].current.as_ref().map(|i| i.exec.phase());
            match phase {
                Some(ExecPhase::Token) => {
                    self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
                    self.refresh_power(idx);
                    self.schedule_phase_end(idx, now_s);
                }
                Some(ExecPhase::Done) => {
                    let inf = self.servers[idx].current.take().unwrap();
                    let actual = now_s - inf.arrived_s;
                    self.report.by_priority(inf.priority).record(
                        actual,
                        inf.exec.nominal_latency,
                        inf.exec.output,
                    );
                    self.servers[idx].gen = self.servers[idx].gen.wrapping_add(1);
                    if let Some(q) = self.servers[idx].queued.take() {
                        self.start_request(idx, q.input, q.output, q.arrived_s, now_s);
                    } else {
                        self.refresh_power(idx);
                    }
                }
                Some(ExecPhase::Prompt) | None => {
                    self.refresh_power(idx);
                    self.schedule_phase_end(idx, now_s);
                }
            }
        }

        fn on_telemetry(&mut self, now_s: f64) {
            self.queue.schedule_in(secs(self.cfg.exp.row.telemetry_period_s), Ev::Telemetry);
            let p = self.averaged_row_power();
            if now_s == 0.0 {
                return;
            }
            self.telemetry.record(now_s, p);
            if !self.cfg.protection {
                return;
            }
            let Some((_, visible)) = self.telemetry.visible_at(now_s) else {
                return;
            };
            let actions = self.policy.tick(now_s, visible);
            for act in actions {
                let cmd = match act {
                    Action::CapLp { mhz } => OobCommand::FreqCap { target: Priority::Low, mhz },
                    Action::CapHp { mhz } => OobCommand::FreqCap { target: Priority::High, mhz },
                    Action::UncapLp => OobCommand::Uncap { target: Priority::Low },
                    Action::UncapHp => OobCommand::Uncap { target: Priority::High },
                    Action::Brake => OobCommand::PowerBrake,
                    Action::ReleaseBrake => OobCommand::ReleaseBrake,
                };
                self.issue_cmd(now_s, cmd);
            }
            self.reconcile_oob(now_s);
        }

        fn issue_cmd(&mut self, now_s: f64, cmd: OobCommand) {
            match cmd {
                OobCommand::FreqCap { target: Priority::Low, .. }
                | OobCommand::Uncap { target: Priority::Low } => self.lp_last_issue_s = now_s,
                OobCommand::FreqCap { target: Priority::High, .. }
                | OobCommand::Uncap { target: Priority::High } => self.hp_last_issue_s = now_s,
                OobCommand::PowerBrake | OobCommand::ReleaseBrake => {}
            }
            if let Some(apply_at) = self.oob.issue(now_s, cmd) {
                self.queue.schedule_at(secs(apply_at), Ev::OobApply);
            }
        }

        fn reconcile_oob(&mut self, now_s: f64) {
            let timeout =
                self.cfg.exp.row.oob_latency_s * 1.5 + self.cfg.exp.row.telemetry_period_s;
            let intent = self.policy.intent();
            if intent.lp_cap_mhz != self.acked_lp
                && now_s - self.lp_last_issue_s > timeout
                && !self.oob.has_pending(|c| targets(c, Priority::Low))
            {
                self.report.resilience.reissued_commands += 1;
                let cmd = match intent.lp_cap_mhz {
                    Some(mhz) => OobCommand::FreqCap { target: Priority::Low, mhz },
                    None => OobCommand::Uncap { target: Priority::Low },
                };
                self.issue_cmd(now_s, cmd);
            }
            if intent.hp_cap_mhz != self.acked_hp
                && now_s - self.hp_last_issue_s > timeout
                && !self.oob.has_pending(|c| targets(c, Priority::High))
            {
                self.report.resilience.reissued_commands += 1;
                let cmd = match intent.hp_cap_mhz {
                    Some(mhz) => OobCommand::FreqCap { target: Priority::High, mhz },
                    None => OobCommand::Uncap { target: Priority::High },
                };
                self.issue_cmd(now_s, cmd);
            }
        }

        fn on_oob_apply(&mut self, now_s: f64) {
            for pending in self.oob.due(now_s) {
                match pending.cmd {
                    OobCommand::FreqCap { target, mhz } => {
                        self.report.cap_commands += 1;
                        self.ack(target, Some(mhz));
                        for idx in 0..self.servers.len() {
                            if self.servers[idx].priority == target && !self.cap_ignore[idx] {
                                self.set_server_cap(idx, Some(mhz), now_s);
                            }
                        }
                    }
                    OobCommand::Uncap { target } => {
                        self.report.uncap_commands += 1;
                        self.ack(target, None);
                        for idx in 0..self.servers.len() {
                            if self.servers[idx].priority == target && !self.cap_ignore[idx] {
                                self.set_server_cap(idx, None, now_s);
                            }
                        }
                    }
                    OobCommand::PowerBrake => {
                        self.report.brake_commands += 1;
                        self.set_brake(true, now_s);
                    }
                    OobCommand::ReleaseBrake => self.set_brake(false, now_s),
                }
            }
        }

        fn ack(&mut self, target: Priority, cap: Option<f64>) {
            match target {
                Priority::Low => self.acked_lp = cap,
                Priority::High => self.acked_hp = cap,
            }
        }

        fn train_cap(&self, j: usize) -> CapMode {
            self.cap_mode(self.train_jobs[j].servers[0])
        }

        fn apply_train_level(&mut self, j: usize) {
            let level =
                self.train_jobs[j].model.profile.phase_levels()[self.train_jobs[j].phase_idx];
            let members = std::mem::take(&mut self.train_jobs[j].servers);
            for &idx in &members {
                self.servers[idx].train_level = level;
                self.refresh_power(idx);
            }
            self.train_jobs[j].servers = members;
        }

        fn schedule_train_phase(&mut self, j: usize) {
            let job = &self.train_jobs[j];
            let b = job.model.profile.phase_bounds();
            let end_s = job.iter_started_s + job.iter_wall_s * b[job.phase_idx + 1];
            let gen = job.gen;
            self.queue.schedule_at(secs(end_s) + 1, Ev::TrainPhase { job: j as u32, gen });
        }

        fn start_train_iteration(&mut self, j: usize, now_s: f64) {
            let cap = self.train_cap(j);
            let job = &mut self.train_jobs[j];
            job.gen = job.gen.wrapping_add(1);
            job.phase_idx = 0;
            job.iter_started_s = now_s;
            job.iter_wall_s = job.model.iter_time_s(cap);
            self.apply_train_level(j);
            self.schedule_train_phase(j);
        }

        fn on_train_phase(&mut self, j: usize, gen: u32, now_s: f64) {
            if self.train_jobs[j].gen != gen {
                return;
            }
            if self.train_jobs[j].phase_idx + 1 >= 4 {
                let wall = now_s - self.train_jobs[j].iter_started_s;
                self.report.train.record(wall);
                self.start_train_iteration(j, now_s);
            } else {
                self.train_jobs[j].phase_idx += 1;
                self.apply_train_level(j);
                self.schedule_train_phase(j);
            }
        }

        fn on_fault_start(&mut self, i: usize, now_s: f64) {
            self.cur_incident = Some(i);
            let ev = self.fault_events[i];
            match ev.kind {
                FaultKind::TelemetryFreeze => self.telemetry.freeze(now_s, ev.end_s()),
                FaultKind::OobStorm { loss_prob, latency_mult, jitter_frac } => {
                    self.oob.set_unreliability(loss_prob, jitter_frac);
                    self.oob.set_latency_mult(latency_mult);
                }
                FaultKind::CapIgnore { server_frac } => {
                    let n = ((server_frac * self.servers.len() as f64).ceil() as usize)
                        .min(self.servers.len());
                    for idx in 0..n {
                        self.cap_ignore[idx] = true;
                    }
                }
                FaultKind::MeterBias { mult } => self.meter_bias = mult,
                FaultKind::FeedLoss { budget_frac } => {
                    self.settle_energy();
                    self.budget_mult = budget_frac.max(1e-6);
                }
            }
        }

        fn on_fault_end(&mut self, i: usize, now_s: f64) {
            let ev = self.fault_events[i];
            match ev.kind {
                FaultKind::TelemetryFreeze => {}
                FaultKind::OobStorm { .. } => {
                    self.oob.set_unreliability(self.cfg.oob_loss_prob, self.cfg.oob_jitter_frac);
                    self.oob.set_latency_mult(1.0);
                }
                FaultKind::CapIgnore { .. } => {
                    for idx in 0..self.servers.len() {
                        if !self.cap_ignore[idx] {
                            continue;
                        }
                        self.cap_ignore[idx] = false;
                        let cap = match self.servers[idx].priority {
                            Priority::Low => self.acked_lp,
                            Priority::High => self.acked_hp,
                        };
                        self.set_server_cap(idx, cap, now_s);
                    }
                }
                FaultKind::MeterBias { .. } => self.meter_bias = 1.0,
                FaultKind::FeedLoss { .. } => {
                    self.settle_energy();
                    self.budget_mult = 1.0;
                }
            }
        }

        fn finalize_incidents(&mut self) {
            let scaled_w = self.cfg.power_scale * self.row_power_w;
            let still_violating = scaled_w > self.row.budget_w * self.budget_mult;
            for (i, f) in self.fault_events.iter().enumerate() {
                let time_to_contain_s = match self.incident_last_violation[i] {
                    None => 0.0,
                    Some(_) if still_violating && self.cur_incident == Some(i) => f64::INFINITY,
                    Some(last) => (last - f.start_s).max(0.0),
                };
                self.report.resilience.incidents.push(IncidentOutcome {
                    label: f.kind.label().to_string(),
                    start_s: f.start_s,
                    end_s: f.end_s(),
                    time_to_contain_s,
                });
            }
        }

        fn run(mut self) -> RunReport {
            for idx in 0..self.servers.len() {
                self.refresh_power(idx);
            }
            for idx in 0..self.servers.len() {
                if self.servers[idx].kind == JobKind::Training {
                    continue;
                }
                let t = self.servers[idx].arrivals.next_after(0.0);
                self.queue.schedule_at(secs(t), Ev::Arrival { server: idx as u32 });
            }
            for j in 0..self.train_jobs.len() {
                let start = self.train_jobs[j].start_s;
                self.queue.schedule_at(secs(start), Ev::TrainStart { job: j as u32 });
            }
            self.queue.schedule_at(0, Ev::Telemetry);
            if self.cfg.series_sample_s > 0.0 {
                self.queue.schedule_at(0, Ev::SampleSeries);
            }
            for i in 0..self.fault_events.len() {
                let f = self.fault_events[i];
                self.queue.schedule_at(secs(f.start_s), Ev::FaultStart { fault: i as u32 });
                self.queue.schedule_at(secs(f.end_s()), Ev::FaultEnd { fault: i as u32 });
            }
            self.queue.schedule_at(self.horizon, Ev::End);

            while let Some((t, ev)) = self.queue.pop() {
                let now_s = to_secs(t);
                self.now_s = now_s;
                match ev {
                    Ev::Arrival { server } => self.on_arrival(server as usize, now_s),
                    Ev::PhaseEnd { server, gen } => {
                        self.on_phase_end(server as usize, gen, now_s)
                    }
                    Ev::Telemetry => self.on_telemetry(now_s),
                    Ev::OobApply => self.on_oob_apply(now_s),
                    Ev::TrainStart { job } => self.start_train_iteration(job as usize, now_s),
                    Ev::TrainPhase { job, gen } => self.on_train_phase(job as usize, gen, now_s),
                    Ev::SampleSeries => {
                        self.report.power_series.push((now_s, self.normalized_row_power()));
                        self.queue.schedule_in(secs(self.cfg.series_sample_s), Ev::SampleSeries);
                    }
                    Ev::FaultStart { fault } => self.on_fault_start(fault as usize, now_s),
                    Ev::FaultEnd { fault } => self.on_fault_end(fault as usize, now_s),
                    Ev::End => break,
                }
                if t >= self.horizon {
                    break;
                }
            }

            self.now_s = to_secs(self.horizon);
            self.settle_energy();
            self.finalize_incidents();
            if self.braked {
                self.report.brake_time_s += to_secs(self.horizon) - self.brake_engaged_at;
            }
            self.report.brake_events = self.policy.brake_events;
            self.report.duration_s = to_secs(self.horizon);
            self.report.events = self.queue.popped();
            let (peak, p99, mean) = self.telemetry.utilization();
            self.report.power_peak = peak;
            self.report.power_p99 = p99;
            self.report.power_mean = mean;
            let spikes = self.telemetry.spike_stats(&[2.0, 5.0, 40.0]);
            self.report.spike_2s = spikes[0].max_rise;
            self.report.spike_5s = spikes[1].max_rise;
            self.report.spike_40s = spikes[2].max_rise;
            self.report
        }
    }
}

/// Assert the layered simulator and the pre-split reference produce
/// byte-identical `Debug` renders for `cfg` (which covers every field
/// of the report: counts, percentile buffers in push order, power
/// statistics, resilience accounting, and the power series).
fn assert_bit_identical(label: &str, cfg: &SimConfig) {
    let new = run(cfg);
    let old = reference::run(cfg);
    assert_eq!(
        format!("{new:?}"),
        format!("{old:?}"),
        "layered simulator diverged from the pre-split wiring: {label}"
    );
}

fn quick(weeks: f64, servers: usize, deployed: usize, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.weeks = weeks;
    cfg.exp.row.num_servers = servers;
    cfg.deployed_servers = deployed;
    cfg.exp.seed = seed;
    cfg.power_scale = 1.35;
    cfg
}

#[test]
fn golden_plain_row() {
    assert_bit_identical("plain 12-server row", &quick(0.04, 12, 12, 42));
}

#[test]
fn golden_oversubscribed_row_with_active_capping() {
    // +50%: the policy engine caps, uncaps, and may brake — exercises
    // the control layer's full issue/ack path.
    assert_bit_identical("oversubscribed row", &quick(0.06, 12, 18, 7));
}

#[test]
fn golden_heavy_row_brakes_and_power_series() {
    let mut cfg = quick(0.05, 12, 22, 3);
    cfg.series_sample_s = 300.0; // SampleSeries events interleave
    assert_bit_identical("braked row + series", &cfg);
}

#[test]
fn golden_unprotected_baseline() {
    let cfg = quick(0.04, 12, 18, 11).baseline();
    assert_bit_identical("unprotected baseline", &cfg);
}

#[test]
fn golden_mixed_row_staggered_jobs() {
    let mut cfg = quick(0.03, 12, 14, 5);
    cfg.mixed = Some(MixedRowConfig {
        training_fraction: 0.5,
        servers_per_job: 3,
        job_stagger_s: 2.5,
        ..Default::default()
    });
    assert_bit_identical("mixed row, staggered jobs", &cfg);
}

#[test]
fn golden_pure_training_row_under_polca() {
    let mut cfg = quick(0.02, 12, 12, 9);
    cfg.mixed = Some(MixedRowConfig { training_fraction: 1.0, ..Default::default() });
    assert_bit_identical("pure training row", &cfg);
}

#[test]
fn golden_cascade_fault_plan_with_escalation() {
    let mut cfg = quick(0.06, 12, 17, 1);
    let horizon_s = cfg.weeks * 7.0 * 86_400.0;
    cfg.faults = Some(polca::faults::FaultPlan::scenario("cascade", horizon_s).unwrap());
    cfg.brake_escalation_s = Some(120.0);
    assert_bit_identical("cascade faults + escalation", &cfg);
}

#[test]
fn golden_every_named_fault_scenario() {
    // One pass over the whole built-in scenario registry: every
    // FaultKind's start/end path crosses both implementations.
    let base = quick(0.04, 12, 16, 13);
    let horizon_s = base.weeks * 7.0 * 86_400.0;
    for name in polca::faults::FaultPlan::scenario_names() {
        if *name == "none" {
            continue;
        }
        let mut cfg = base.clone();
        cfg.faults = Some(polca::faults::FaultPlan::scenario(name, horizon_s).unwrap());
        cfg.brake_escalation_s = Some(90.0);
        assert_bit_identical(&format!("fault scenario '{name}'"), &cfg);
    }
}

#[test]
fn golden_lossy_oob_and_power_mult() {
    let mut cfg = quick(0.05, 12, 18, 21);
    cfg.oob_loss_prob = 0.3;
    cfg.oob_jitter_frac = 0.2;
    cfg.workload_power_mult = 1.05;
    assert_bit_identical("lossy OOB + power mult", &cfg);
}

#[test]
fn golden_sku_override_with_phase_offset() {
    // H100 SKU: explicit server model, perf multiplier, scaled policy
    // domain — plus a diurnal phase offset (the fleet layer's knob).
    let sku = polca::fleet::sku::find("hgx-h100").unwrap();
    let base = polca::characterize::catalog::find("BLOOM-176B").unwrap().power;
    let mut cfg = quick(0.04, 12, 15, 17);
    cfg.server_model = Some(sku.server_model(base));
    cfg.perf_mult = sku.perf_mult;
    sku.scale_policy(&mut cfg.exp.policy);
    cfg.diurnal_phase_s = 3.0 * 3600.0;
    cfg.workload_power_mult = 1.05; // exercises the explicit-model rescale path
    assert_bit_identical("H100 SKU + phase offset", &cfg);
}

#[test]
fn golden_lp_fraction_override() {
    let mut cfg = quick(0.04, 12, 16, 23);
    cfg.lp_fraction_override = Some(0.25);
    assert_bit_identical("LP fraction override", &cfg);
}
