//! Integration tests for the region layer (ISSUE 7):
//!
//! * **Trace-algebra properties** — over randomized seeded trace sets:
//!   `sum` is commutative and prefix-associative *bit-exactly* (samples
//!   and summaries), `peak(sum) <= sum(peaks)` always (with exact
//!   equality when every trace peaks at the same instant — the
//!   zero-phase-offset case), and `scale`/`mix` are linear in the mean
//!   to float tolerance.
//! * **Planner scale** — a 50-site region plans from an archetype
//!   cache whose simulation count depends on the (SKU, level) alphabet,
//!   not on the number of sites or candidates.
//! * **Cross-validation tolerance** — analytic composition vs full
//!   simulation stays within `MEAN_TOLERANCE` / `PEAK_TOLERANCE` on
//!   sampled sites; quick tier checks one configuration, the full tier
//!   (`POLCA_TEST_FULL=1`) sweeps every named SKU × a grid of cluster
//!   mixes and reports the worst-offending configuration on failure.

use polca::fleet::region::{
    plan_region, plan_region_with_cache, validate_region, ArchetypeCache, RegionPlanConfig,
    RegionSpec, MEAN_TOLERANCE, PEAK_TOLERANCE,
};
use polca::fleet::site::{ClusterSpec, Feed};
use polca::fleet::sku;
use polca::fleet::trace::PowerTrace;
use polca::policy::engine::PolicyKind;
use polca::testing::{check, full_suite};
use polca::util::rng::Rng;

const PERIOD_S: f64 = 300.0;

fn random_trace(rng: &mut Rng, n: usize) -> PowerTrace {
    PowerTrace::from_samples((0..n).map(|_| rng.range_f64(0.0, 1000.0)).collect(), PERIOD_S)
}

// ---------------------------------------------------------------------------
// Trace-algebra properties (simulation-free).
// ---------------------------------------------------------------------------

#[test]
fn property_sum_commutes_bit_exactly() {
    check(
        "trace-sum-commutes",
        0x7A_CE01,
        128,
        |rng| {
            let n = rng.range_usize(4, 64);
            (random_trace(rng, n), random_trace(rng, n))
        },
        |(a, b)| {
            let ab = PowerTrace::sum(PERIOD_S, &[a.clone(), b.clone()]);
            let ba = PowerTrace::sum(PERIOD_S, &[b.clone(), a.clone()]);
            if ab.samples != ba.samples {
                return Err("sum(a,b) and sum(b,a) sample vectors differ".into());
            }
            if ab.summary() != ba.summary() {
                return Err("sum(a,b) and sum(b,a) summaries differ".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_sum_is_prefix_associative_bit_exactly() {
    // General float reassociation is NOT bit-exact, but grouping a
    // prefix is: the fold starts at 0.0 and 0.0 + x == x, so
    // sum(a, b, c) == sum(sum(a, b), c) sample for sample.
    check(
        "trace-sum-prefix-assoc",
        0x7A_CE02,
        128,
        |rng| {
            let n = rng.range_usize(4, 48);
            (random_trace(rng, n), random_trace(rng, n), random_trace(rng, n))
        },
        |(a, b, c)| {
            let flat = PowerTrace::sum(PERIOD_S, &[a.clone(), b.clone(), c.clone()]);
            let prefix = PowerTrace::sum(
                PERIOD_S,
                &[PowerTrace::sum(PERIOD_S, &[a.clone(), b.clone()]), c.clone()],
            );
            if flat.samples != prefix.samples {
                return Err("prefix grouping changed the sample vector".into());
            }
            if flat.summary() != prefix.summary() {
                return Err("prefix grouping changed the summary".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_peak_of_sum_is_subadditive() {
    // IEEE addition rounds monotonically, and both sides are the same
    // left-to-right fold shape, so the inequality is exact — no
    // epsilon.
    check(
        "trace-peak-subadditive",
        0x7A_CE03,
        128,
        |rng| {
            let n = rng.range_usize(4, 48);
            let k = rng.range_usize(2, 5);
            (0..k).map(|_| random_trace(rng, n)).collect::<Vec<_>>()
        },
        |traces| {
            let peak_of_sum = PowerTrace::sum(PERIOD_S, traces).peak_w();
            let sum_of_peaks = traces.iter().map(|t| t.peak_w()).fold(0.0, |acc, p| acc + p);
            if peak_of_sum <= sum_of_peaks {
                Ok(())
            } else {
                Err(format!("peak(sum) {peak_of_sum} > sum(peaks) {sum_of_peaks}"))
            }
        },
    );
}

#[test]
fn property_aligned_peaks_make_subadditivity_an_equality() {
    // The zero-phase-offset case: scaled copies of one base trace all
    // peak at the same instant, and peak(sum) == sum(peaks) bit-exactly
    // (both sides fold the identical per-trace peak values in the same
    // order). This is the trace-algebra face of the site invariant
    // "site trace == sum of cluster traces at zero offset".
    check(
        "trace-aligned-peak-equality",
        0x7A_CE04,
        128,
        |rng| {
            let n = rng.range_usize(4, 48);
            let mut base = random_trace(rng, n);
            // A strictly dominant spike pins a unique argmax.
            let j = rng.range_usize(0, n - 1);
            base.samples[j] = 2000.0 + rng.range_f64(0.0, 100.0);
            let k = rng.range_usize(2, 5);
            let weights: Vec<f64> = (0..k).map(|_| rng.range_f64(0.1, 3.0)).collect();
            (base, weights)
        },
        |(base, weights)| {
            let traces: Vec<PowerTrace> = weights.iter().map(|&w| base.scale(w)).collect();
            let peak_of_sum = PowerTrace::sum(PERIOD_S, &traces).peak_w();
            let sum_of_peaks = traces.iter().map(|t| t.peak_w()).fold(0.0, |acc, p| acc + p);
            if peak_of_sum == sum_of_peaks {
                Ok(())
            } else {
                Err(format!(
                    "aligned peaks must be exactly additive: {peak_of_sum} != {sum_of_peaks}"
                ))
            }
        },
    );
}

#[test]
fn property_scale_and_mix_are_linear_in_the_mean() {
    const REL_TOL: f64 = 1e-9;
    let rel = |x: f64, y: f64| (x - y).abs() / y.abs().max(1e-12);
    check(
        "trace-mean-linearity",
        0x7A_CE05,
        128,
        |rng| {
            let n = rng.range_usize(4, 64);
            let k = rng.range_f64(0.1, 5.0);
            let (wa, wb) = (rng.range_f64(0.1, 2.0), rng.range_f64(0.1, 2.0));
            (random_trace(rng, n), random_trace(rng, n), k, wa, wb)
        },
        |(a, b, k, wa, wb)| {
            if rel(a.scale(*k).mean_w(), k * a.mean_w()) > REL_TOL {
                return Err("mean(scale(t, k)) drifted from k * mean(t)".into());
            }
            let mixed = PowerTrace::mix(PERIOD_S, &[a.clone(), b.clone()], &[*wa, *wb]);
            let expect = wa * a.mean_w() + wb * b.mean_w();
            if rel(mixed.mean_w(), expect) > REL_TOL {
                return Err(format!(
                    "mean(mix) {} drifted from the weighted means {expect}",
                    mixed.mean_w()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_shift_phase_is_a_peak_preserving_rotation_group() {
    check(
        "trace-shift-rotation-group",
        0x7A_CE06,
        128,
        |rng| {
            let n = rng.range_usize(4, 48);
            let k1 = rng.range_usize(0, 2 * n) as f64;
            let k2 = rng.range_usize(0, 2 * n) as f64;
            (random_trace(rng, n), k1, k2)
        },
        |(t, k1, k2)| {
            // Rotation permutes samples: the peak (a fold of
            // comparisons, no arithmetic) is bit-identical.
            if t.shift_phase(k1 * PERIOD_S).peak_w() != t.peak_w() {
                return Err("rotation changed the peak".into());
            }
            // Whole-period shifts compose additively...
            let composed = t.shift_phase(k1 * PERIOD_S).shift_phase(k2 * PERIOD_S);
            let direct = t.shift_phase((k1 + k2) * PERIOD_S);
            if composed.samples != direct.samples {
                return Err("shift(k1) . shift(k2) != shift(k1 + k2)".into());
            }
            // ... and a full turn is the identity.
            let full = t.shift_phase(t.len() as f64 * PERIOD_S);
            if full.samples != t.samples {
                return Err("a full-period rotation must be the identity".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Planner scale: simulations track the archetype alphabet, not sites.
// ---------------------------------------------------------------------------

#[test]
fn fifty_site_region_plans_from_a_constant_archetype_alphabet() {
    let region = RegionSpec::demo(50, 2, 0.9);
    let pc = RegionPlanConfig {
        policy: PolicyKind::NoCap,
        weeks: 0.02,
        max_added_pct: 20,
        step_pct: 10,
        ..Default::default()
    };
    let plan = plan_region(&region, &pc);
    assert_eq!(plan.site_names.len(), 50);
    assert_eq!(plan.added_pct.len(), 50);
    // The demo region cycles the SKU registry, so the archetype
    // alphabet is at most |SKUs| x |levels probed| — far below one
    // simulation per (site, candidate), let alone per server.
    let skus = sku::registry().len();
    let levels = (pc.max_added_pct / pc.step_pct + 1) as usize;
    assert!(
        plan.archetype_sims <= skus * levels,
        "{} archetype sims for {} SKUs x {} levels",
        plan.archetype_sims,
        skus,
        levels
    );
    assert!(
        plan.candidate_evals >= 2,
        "the search must have evaluated several candidates ({})",
        plan.candidate_evals
    );
    // Every closed-form evaluation reused those archetypes: evals over
    // 50 sites with zero additional simulations is the tentpole claim.
    assert!(plan.archetype_sims < plan.candidate_evals * 50);
    assert_eq!(plan.baseline_servers, 50 * 2 * 12);
}

// ---------------------------------------------------------------------------
// Cross-validation: analytic composition vs full simulation.
// ---------------------------------------------------------------------------

/// A homogeneous-SKU region: `n_sites` sites x `clusters_per_site`
/// clusters of one SKU on 12-server baselines (the pinned calibration
/// anchor), staggered phases within sites and time zones across them,
/// optionally colocating a training fraction on every cluster.
fn sku_region(
    sku_name: &str,
    n_sites: usize,
    clusters_per_site: usize,
    training: f64,
) -> RegionSpec {
    let sk = sku::find(sku_name).unwrap_or_else(|| panic!("unknown sku '{sku_name}'"));
    let mut region = RegionSpec::demo(n_sites, clusters_per_site, 1.0);
    for (s, rs) in region.sites.iter_mut().enumerate() {
        let clusters: Vec<ClusterSpec> = (0..clusters_per_site)
            .map(|i| {
                let mut c = ClusterSpec::new(&format!("s{s}c{i}-{sku_name}"), sk, 12);
                c.phase_offset_s = i as f64 * 3.0 * 3600.0;
                c.training_fraction = training;
                c
            })
            .collect();
        let feeds: Vec<Feed> = clusters
            .chunks(2)
            .enumerate()
            .map(|(fi, chunk)| {
                let idxs: Vec<usize> = (fi * 2..fi * 2 + chunk.len()).collect();
                let capacity_w: f64 = chunk.iter().map(|c| c.budget_w()).sum();
                Feed { name: format!("feed{fi}"), clusters: idxs, capacity_w }
            })
            .collect();
        rs.site.substation_budget_w =
            clusters.iter().map(|c| c.budget_w()).sum::<f64>() / rs.site.ups_efficiency;
        rs.site.feeds = feeds;
        rs.site.clusters = clusters;
    }
    region.grid_budget_w =
        region.sites.iter().map(|r| r.site.substation_budget_w).sum::<f64>();
    region
}

/// Plan + validate one configuration; returns the validation and a
/// human description for failure reporting.
fn validate_config(
    sku_name: &str,
    clusters_per_site: usize,
    training: f64,
) -> (polca::fleet::region::RegionValidation, String) {
    let region = sku_region(sku_name, 3, clusters_per_site, training);
    let pc = RegionPlanConfig { max_added_pct: 20, step_pct: 10, ..Default::default() };
    let mut cache = ArchetypeCache::new(&pc);
    let plan = plan_region_with_cache(&region, &pc, &mut cache);
    let v = validate_region(&region, &plan, &pc, 2);
    let desc = format!(
        "sku={sku_name} clusters/site={clusters_per_site} training={training} \
         plan={:?}",
        plan.added_pct
    );
    (v, desc)
}

#[test]
fn analytic_composition_matches_full_simulation_within_tolerance() {
    // Quick tier: one representative configuration. Full tier
    // (POLCA_TEST_FULL=1): every named SKU x a grid of cluster mixes.
    let mut grid: Vec<(&str, usize, f64)> = vec![("dgx-a100", 2, 0.0)];
    if full_suite() {
        grid.clear();
        for sk in sku::registry() {
            for &(clusters, training) in &[(1usize, 0.0), (2, 0.0), (2, 0.5)] {
                grid.push((sk.name, clusters, training));
            }
        }
    }
    let mut failures: Vec<String> = Vec::new();
    for &(sku_name, clusters, training) in &grid {
        let (v, desc) = validate_config(sku_name, clusters, training);
        assert_eq!(v.mean_tolerance, MEAN_TOLERANCE);
        assert_eq!(v.peak_tolerance, PEAK_TOLERANCE);
        if !v.passed() {
            let worst = v.worst_site().expect("a failing validation has sites");
            failures.push(format!(
                "{desc}: worst site '{}' at +{}% — mean err {:.3}% (<= {:.0}%), \
                 peak err {:.3}% (<= {:.0}%); analytic peak {:.1} kW vs simulated {:.1} kW",
                worst.site,
                worst.added_pct,
                worst.mean_rel_err * 100.0,
                v.mean_tolerance * 100.0,
                worst.peak_rel_err * 100.0,
                v.peak_tolerance * 100.0,
                worst.analytic_peak_w / 1e3,
                worst.simulated_peak_w / 1e3,
            ));
        }
    }
    assert!(
        failures.is_empty(),
        "{} of {} configurations out of tolerance:\n{}",
        failures.len(),
        grid.len(),
        failures.join("\n")
    );
}
