//! Integration tests for the parallel scenario executor (ISSUE 5):
//!
//! * **Property** — `exec::run_batch` is bit-identical between the
//!   serial reference path and the parallel path, across randomized
//!   scenario batches (policy, oversubscription, seeds, training mixes,
//!   fault plans) and randomized worker-thread counts. Equality is the
//!   full `Debug` render of every [`RunReport`] — counts, percentile
//!   buffers in push order, power statistics, resilience accounting.
//! * **Surfaces** — the user-facing batch paths rewired onto the
//!   executor (`polca mixed sweep`, the fault matrix) agree with their
//!   serial selves end to end.
//!
//! The randomized config generator is the shared
//! [`polca::testing::random_sim_config`] (one generator, one
//! distribution, across the exec and obs suites).

use polca::exec::{item_seeds, run_batch, ExecConfig};
use polca::experiments::mixed::{sweep_training_fractions, SweepConfig};
use polca::policy::engine::PolicyKind;
use polca::simulation::{run, SimConfig};
use polca::testing::random_sim_config;
use polca::util::rng::Rng;

#[test]
fn parallel_batches_are_bit_identical_to_serial_across_thread_counts() {
    let mut rng = Rng::new(0xE8EC_CA5E);
    for case in 0..3 {
        let batch: Vec<SimConfig> =
            (0..rng.range_usize(3, 5)).map(|_| random_sim_config(&mut rng)).collect();
        let serial: Vec<String> = run_batch(&batch, &ExecConfig::serial(), |_, cfg| {
            format!("{:?}", run(cfg))
        });
        for threads in [2, 8] {
            let cfg = ExecConfig { parallel: true, threads };
            let parallel: Vec<String> =
                run_batch(&batch, &cfg, |_, c| format!("{:?}", run(c)));
            assert_eq!(
                parallel, serial,
                "case {case}: parallel(threads={threads}) diverged from serial"
            );
        }
    }
}

#[test]
fn per_item_seeds_make_parallel_batches_reproducible() {
    // The seeded-batch pattern every sweep uses: derive item seeds up
    // front, run twice in parallel, get the same reports.
    let seeds = item_seeds(7, 4);
    let configs: Vec<SimConfig> = seeds
        .iter()
        .map(|&s| {
            let mut cfg = SimConfig::default();
            cfg.exp.row.num_servers = 10;
            cfg.deployed_servers = 13;
            cfg.weeks = 0.01;
            cfg.exp.seed = s;
            cfg.power_scale = 1.35;
            cfg
        })
        .collect();
    let a: Vec<String> =
        run_batch(&configs, &ExecConfig::default(), |_, c| format!("{:?}", run(c)));
    let b: Vec<String> =
        run_batch(&configs, &ExecConfig::default(), |_, c| format!("{:?}", run(c)));
    assert_eq!(a, b);
    // Distinct seeds actually produce distinct runs (the batch is not
    // degenerate).
    assert_ne!(a[0], a[1]);
}

#[test]
fn mixed_sweep_parallel_matches_serial() {
    let mut sc = SweepConfig { weeks: 0.02, seed: 3, servers: 12, ..Default::default() };
    sc.parallel = true;
    let par = sweep_training_fractions(&[0.0, 0.5, 1.0], &sc);
    sc.parallel = false;
    let ser = sweep_training_fractions(&[0.0, 0.5, 1.0], &sc);
    assert_eq!(format!("{par:?}"), format!("{ser:?}"));
}

#[test]
fn fault_matrix_parallel_matches_serial_end_to_end() {
    use polca::faults::MatrixConfig;
    let mut mc = MatrixConfig {
        scenarios: vec!["none".into(), "cap-ignore".into()],
        policies: vec![PolicyKind::Polca, PolicyKind::NoCap],
        servers: 12,
        added: 0.4,
        weeks: 0.03,
        seed: 9,
        escalation_s: Some(120.0),
        parallel: true,
    };
    let par = polca::faults::run_matrix(&mc).unwrap();
    mc.parallel = false;
    let ser = polca::faults::run_matrix(&mc).unwrap();
    assert_eq!(format!("{:?}", par.cells), format!("{:?}", ser.cells));
    assert_eq!(par.clean_match, ser.clean_match);
    assert!(par.clean_match, "the executor must not perturb the clean column");
}
