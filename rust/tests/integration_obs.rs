//! Integration tests for the observability layer (ISSUE 6):
//!
//! * **Passivity property** — attaching a [`Recorder`] never perturbs
//!   a simulation: the full `Debug` render of the [`RunReport`] is
//!   bit-identical between `run` (NoopObserver) and `run_observed`
//!   across randomized configs (policies, oversubscription, training
//!   mixes, fault plans) and across every row preset. The observer is
//!   threaded as a generic with all emission sites behind
//!   `O::ENABLED`, so this is the test that proves those sites only
//!   *read* simulation state.
//! * **Lifecycle coverage** — a traced faulted run actually records
//!   the streams the trace schema promises: fault start/end pairs,
//!   telemetry events, every built-in series, and at least one control
//!   action; the incident-timeline deriver finds every injected
//!   episode in the records.

use polca::faults::FaultPlan;
use polca::obs::{Recorder, RecorderConfig};
use polca::scenario::presets;
use polca::simulation::{run, run_observed, SimConfig};
use polca::testing::random_sim_config;
use polca::util::rng::Rng;

#[test]
fn recording_never_perturbs_a_run() {
    let mut rng = Rng::new(0x0B5E_77ED);
    for case in 0..6 {
        let cfg = random_sim_config(&mut rng);
        let plain = format!("{:?}", run(&cfg));
        let mut rec = Recorder::new(RecorderConfig::default());
        let observed = format!("{:?}", run_observed(&cfg, &mut rec));
        assert_eq!(observed, plain, "case {case}: observation perturbed the run");
        // ... and the recorder did actually observe something: the
        // end-of-run counters are always emitted.
        let trace = rec.into_trace("case");
        assert!(
            trace.counters.iter().any(|(n, _)| n == "events-dispatched"),
            "case {case}: no dispatch counter in {:?}",
            trace.counters
        );
    }
}

#[test]
fn every_row_preset_is_passivity_clean() {
    for mut sc in presets() {
        if sc.site.is_some() || sc.region.is_some() {
            continue; // site/region planning sweeps have no single run to trace
        }
        sc.weeks = sc.weeks.min(0.02);
        let plain = sc.run().unwrap();
        let mut rec = Recorder::new(RecorderConfig::default());
        let observed = sc.run_observed(&mut rec).unwrap();
        assert_eq!(
            format!("{:?}", observed.outcome),
            format!("{:?}", plain.outcome),
            "preset '{}': observation perturbed the report",
            sc.name
        );
    }
}

#[test]
fn traced_faulted_run_covers_the_lifecycle() {
    let mut cfg = SimConfig::default();
    cfg.exp.row.num_servers = 12;
    cfg.deployed_servers = 16;
    cfg.weeks = 0.03;
    cfg.exp.seed = 5;
    cfg.power_scale = 1.35;
    cfg.brake_escalation_s = Some(120.0);
    let horizon_s = cfg.weeks * 7.0 * 86_400.0;
    let plan = FaultPlan::scenario("cascade", horizon_s).unwrap();
    let episodes = plan.len();
    cfg.faults = Some(plan);

    let mut rec = Recorder::new(RecorderConfig::default());
    let report = run_observed(&cfg, &mut rec);
    let trace = rec.into_trace("lifecycle");
    let labels: Vec<&str> = trace.events.iter().map(|e| e.kind.label()).collect();

    for need in ["fault-start", "fault-end", "telemetry"] {
        assert!(labels.contains(&need), "missing '{need}' events");
    }
    let starts = labels.iter().filter(|&&l| l == "fault-start").count();
    let ends = labels.iter().filter(|&&l| l == "fault-end").count();
    assert_eq!(starts, episodes, "one fault-start per injected episode");
    assert_eq!(ends, episodes, "one fault-end per injected episode");
    assert!(
        ["cap-issued", "brake-issued", "violation-start"].iter().any(|l| labels.contains(l)),
        "an oversubscribed faulted row must record some control action: {labels:?}"
    );
    // Every built-in series got samples, stamped inside the horizon.
    for s in &trace.series {
        assert!(!s.points.is_empty(), "series '{}' recorded nothing", s.name);
        assert!(
            s.points.iter().all(|&(t, _)| (0.0..=horizon_s).contains(&t)),
            "series '{}' has out-of-horizon timestamps",
            s.name
        );
    }
    // The timeline deriver reconstructs every injected episode from the
    // serialized records, and the renderer has something to say.
    let records = trace.records();
    let timelines = polca::obs::export::incident_timeline(&records);
    assert_eq!(timelines.len(), episodes, "one incident window per episode");
    let rendered = polca::obs::export::render_timeline(&timelines);
    assert!(rendered.contains("incident 1:"), "{rendered}");
    // Sanity: the run itself saw the faults too (events flowed from
    // the same lifecycle the report accounted).
    assert_eq!(report.resilience.incidents.len(), episodes);
}
