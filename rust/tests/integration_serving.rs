//! Integration tests for the real serving path: runtime + coordinator +
//! power adapter over the AOT artifacts. Skips gracefully when
//! artifacts/ has not been built.

use polca::cluster::hierarchy::Priority;
use polca::config::PolicyConfig;
use polca::coordinator::{run_policy_over_row, timeline_power, Coordinator, Request};
use polca::power::server::ServerPowerModel;
use polca::runtime::Engine;
use polca::util::rng::Rng;
use std::path::PathBuf;

fn engine() -> Option<Engine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load(&dir).unwrap())
}

/// Greedy generation is deterministic end to end: two identical request
/// streams produce identical token sequences.
#[test]
#[ignore = "environment-dependent: needs AOT artifacts (`make artifacts`) and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
fn serving_is_deterministic() {
    let Some(e1) = engine() else { return };
    let Some(e2) = engine() else { return };
    let make = |engine: Engine| -> Vec<Vec<i32>> {
        let mut c = Coordinator::new(engine).unwrap();
        let mut rng = Rng::new(99);
        for id in 0..6u64 {
            let len = rng.range_usize(4, 12);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
            c.submit(Request { id, prompt, max_new_tokens: 5, priority: Priority::Low });
        }
        let mut done = c.run_to_completion().unwrap();
        done.sort_by_key(|d| d.id);
        done.into_iter().map(|d| d.tokens).collect()
    };
    assert_eq!(make(e1), make(e2));
}

/// Requests interleaved across slots must not contaminate each other:
/// the same request served alone and served alongside others produces
/// the same tokens (KV slot isolation at the serving level).
#[test]
#[ignore = "environment-dependent: needs AOT artifacts (`make artifacts`) and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
fn slot_isolation_under_batching() {
    let Some(e_alone) = engine() else { return };
    let probe = Request {
        id: 0,
        prompt: vec![17, 300, 45, 9, 222, 8],
        max_new_tokens: 6,
        priority: Priority::High,
    };
    let mut c = Coordinator::new(e_alone).unwrap();
    c.submit(probe.clone());
    let alone = c.run_to_completion().unwrap()[0].tokens.clone();

    let Some(e_batch) = engine() else { return };
    let mut c = Coordinator::new(e_batch).unwrap();
    let mut rng = Rng::new(5);
    c.submit(probe);
    for id in 1..5u64 {
        let len = rng.range_usize(4, 12);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        c.submit(Request { id, prompt, max_new_tokens: 7, priority: Priority::Low });
    }
    let mut done = c.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_eq!(done[0].tokens, alone, "batching changed request 0's output");
}

/// The executed timeline drives POLCA sensibly: more oversubscription
/// can only increase capped time, never decrease it.
#[test]
#[ignore = "environment-dependent: needs AOT artifacts (`make artifacts`) and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
fn policy_monotone_in_oversubscription() {
    let Some(engine) = engine() else { return };
    let mut c = Coordinator::new(engine).unwrap();
    let mut rng = Rng::new(7);
    for id in 0..10u64 {
        let len = rng.range_usize(8, 14);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        c.submit(Request { id, prompt, max_new_tokens: 8, priority: Priority::Low });
    }
    c.run_to_completion().unwrap();
    let model = ServerPowerModel::default();
    let trace = timeline_power(&c.timeline, &model, 0.5, 50.0);
    let mut last_capped = 0usize;
    for oversub in [1.0, 1.4, 1.8, 2.2] {
        let report = run_policy_over_row(
            &trace, 40, oversub, &PolicyConfig::default(), &model.calib, 0.22, 0.92,
        );
        let capped = report.cap_timeline.iter().filter(|(_, lp, _, _)| lp.is_some()).count();
        assert!(
            capped >= last_capped,
            "capped ticks decreased: {capped} < {last_capped} at {oversub}"
        );
        last_capped = capped;
    }
    assert!(last_capped > 0, "extreme oversubscription must cap");
}
