//! Gateway integration: real-TCP round trips against an in-process
//! daemon on an ephemeral port.
//!
//! The load-bearing properties pinned here:
//!
//! * a report fetched over HTTP is **byte-identical** to a direct
//!   in-process `Scenario::run()` of the same scenario (the passivity
//!   contract of the broadcast observer plus the shared
//!   `ScenarioReport::to_json` serialization);
//! * ≥ 8 concurrent clients can submit simultaneously with zero
//!   dropped runs, each getting its own correct deterministic report;
//! * the SSE stream parses back record-by-record exactly like a
//!   recorded JSONL trace (`obs::export::parse_jsonl`), framed by a
//!   `meta` record and a terminal `status` record;
//! * the daemon boots on an ephemeral port, answers `/healthz` and
//!   `/metrics` on a kept-alive connection, and shuts down gracefully
//!   through the shutdown endpoint with every thread joined.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use polca::gateway::http::{request_once, sse_collect, Client};
use polca::gateway::{Gateway, GatewayConfig};
use polca::obs::export::parse_jsonl;
use polca::scenario::preset;
use polca::util::json::{parse as parse_json, Json};

fn boot(run_workers: usize) -> Gateway {
    let cfg = GatewayConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 12,
        run_workers,
        time_warp: 0.0,
        queue_depth: 64,
        accept_queue: 64,
    };
    Gateway::start(&cfg).expect("gateway must boot on an ephemeral port")
}

/// Poll `GET /runs/:id` until the terminal report document appears.
fn await_report(addr: SocketAddr, id: &str) -> String {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (code, body) =
            request_once(addr, "GET", &format!("/runs/{id}"), None, b"").expect("GET /runs/:id");
        match code {
            200 if body.contains("\"outcome\"") => return body,
            200 => {} // still queued/running
            500 => panic!("run {id} failed: {body}"),
            other => panic!("unexpected status {other} for {id}: {body}"),
        }
        assert!(Instant::now() < deadline, "run {id} did not finish in time");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn submit(addr: SocketAddr, envelope: &str) -> String {
    let (code, body) =
        request_once(addr, "POST", "/scenarios", Some("application/json"), envelope.as_bytes())
            .expect("POST /scenarios");
    assert_eq!(code, 202, "submission rejected: {body}");
    parse_json(&body)
        .expect("submission response must be JSON")
        .get("id")
        .and_then(Json::as_str)
        .expect("submission response carries an id")
        .to_string()
}

#[test]
fn report_over_tcp_is_byte_identical_to_in_process_run() {
    let gw = boot(2);
    let addr = gw.local_addr();

    let id = submit(addr, "{\"preset\": \"oversubscribed-row\", \"weeks\": 0.02}");
    assert_eq!(id, "run-000001", "run ids are deterministic");
    let via_http = await_report(addr, &id);

    let mut sc = preset("oversubscribed-row").unwrap();
    sc.weeks = 0.02;
    let mut report = sc.run().unwrap();
    let in_process = format!("{}\n", report.to_json().to_pretty());

    assert_eq!(via_http, in_process, "gateway report must be byte-identical");

    gw.trigger_shutdown();
    gw.join();
}

#[test]
fn eight_concurrent_clients_all_complete_with_correct_reports() {
    let gw = boot(4);
    let addr = gw.local_addr();
    const CLIENTS: usize = 8;

    // Expected reports, computed in-process per seed before any load.
    let mut expected = Vec::new();
    for seed in 1..=CLIENTS as u64 {
        let mut sc = preset("inference-row").unwrap();
        sc.weeks = 0.01;
        sc.exp.seed = seed;
        let mut report = sc.run().unwrap();
        expected.push(format!("{}\n", report.to_json().to_pretty()));
    }

    let got: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = (1..=CLIENTS as u64)
            .map(|seed| {
                scope.spawn(move || {
                    let envelope = format!(
                        "{{\"preset\": \"inference-row\", \"weeks\": 0.01, \"seed\": {seed}}}"
                    );
                    let id = submit(addr, &envelope);
                    await_report(addr, &id)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // Zero dropped runs, and every client saw its own seed's report.
    assert_eq!(got.len(), CLIENTS);
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(g, e, "client {} got a wrong or shared report", i + 1);
    }

    let (_, metrics_text) = request_once(addr, "GET", "/metrics", None, b"").unwrap();
    assert!(metrics_text.contains("polca_runs_done_total 8"), "{metrics_text}");
    assert!(metrics_text.contains("polca_runs_rejected_total 0"), "{metrics_text}");

    gw.trigger_shutdown();
    gw.join();
}

#[test]
fn sse_stream_parses_like_a_jsonl_trace() {
    let gw = boot(2);
    let addr = gw.local_addr();

    // 0.005 weeks ≈ 3000 sim-seconds: long enough for telemetry events,
    // series samples, and fault activity, short enough that the whole
    // stream fits the replay backlog (BACKLOG_CAP) — so the assertions
    // below hold even when the unpaced run finishes before we connect.
    let id = submit(addr, "{\"preset\": \"cascade-faults\", \"weeks\": 0.005}");
    let payloads = sse_collect(
        addr,
        &format!("/runs/{id}/events"),
        1_000_000,
        Duration::from_secs(120),
    )
    .expect("SSE stream");
    assert!(!payloads.is_empty(), "SSE stream carried no records");

    // Every payload line must parse exactly like a JSONL trace.
    let jsonl = payloads.join("\n");
    let records = parse_jsonl(&jsonl).expect("SSE payloads must be valid JSONL records");
    assert_eq!(records.len(), payloads.len());

    let kind = |r: &Json| r.get("type").and_then(Json::as_str).unwrap_or("?").to_string();
    assert_eq!(kind(&records[0]), "meta", "stream must open with the meta record");
    assert_eq!(
        kind(records.last().unwrap()),
        "status",
        "stream must end with the terminal status record"
    );
    assert_eq!(
        records.last().unwrap().get("status").and_then(Json::as_str),
        Some("done")
    );
    let kinds: Vec<String> = records.iter().map(kind).collect();
    assert!(kinds.contains(&"event".to_string()), "no control-loop events in the stream");
    assert!(kinds.contains(&"sample".to_string()), "no series samples in the stream");
    // Events carry numeric timestamps, like trace records.
    for r in &records {
        if kind(r) == "event" || kind(r) == "sample" {
            assert!(r.get("t_s").and_then(Json::as_f64).is_some(), "record without t_s: {r:?}");
        }
    }

    // A late subscriber replays the finished run's backlog.
    await_report(addr, &id);
    let replay =
        sse_collect(addr, &format!("/runs/{id}/events"), 1_000_000, Duration::from_secs(30))
            .expect("replay stream");
    assert!(!replay.is_empty(), "finished runs must replay their stream");
    assert_eq!(replay.first(), payloads.first());

    gw.trigger_shutdown();
    gw.join();
}

#[test]
fn health_metrics_keepalive_and_graceful_shutdown_endpoint() {
    let gw = boot(1);
    let addr = gw.local_addr();

    // Several requests over one kept-alive connection.
    let mut client = Client::connect(addr).unwrap();
    let (code, body) = client.request("GET", "/healthz", None, b"").unwrap();
    assert_eq!(code, 200);
    let health = parse_json(&body).unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    let (code, body) = client.request("GET", "/metrics", None, b"").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("polca_http_requests_total"), "{body}");
    let (code, _) = client.request("GET", "/no-such-endpoint", None, b"").unwrap();
    assert_eq!(code, 404);
    let (code, _) = client.request("GET", "/runs/run-999999", None, b"").unwrap();
    assert_eq!(code, 404);
    let (code, body) = client.request("POST", "/scenarios", None, b"not = valid").unwrap();
    assert_eq!(code, 400, "{body}");

    // Graceful stop via the endpoint: acknowledged, then every thread
    // joins (join() would hang forever if a worker leaked).
    let (code, body) = request_once(addr, "POST", "/shutdown", None, b"").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("shutting-down"), "{body}");
    gw.join();
}
