//! Integration tests for the adaptive oversubscription controller
//! (ISSUE 8): the provisioning→runtime loop closed online.
//!
//! * **Disabled bit-identity property** — `adapt: None` must be
//!   bit-identical to a pre-adapt build: across randomized configs
//!   (policies, oversubscription, training mixes, fault plans) the
//!   full `Debug` render of the [`RunReport`] matches a run of the
//!   identical config, and the report carries no `adapt` block. The
//!   controller schedules no `RetuneCheck` events when off, so this is
//!   the test that proves every one of its hooks is behind the
//!   `Option`.
//! * **Determinism property** — same seed + config ⇒ the identical
//!   retune decision sequence, whether the batch runs on the serial
//!   reference path or fans out across threads.
//! * **Long-horizon drift regression** — on the growth-ramp scenario
//!   the adaptive row must *dominate* its matched static baseline:
//!   violation seconds no worse AND mean added-server level no lower
//!   at equal SLO. One configuration on the quick CI tier; the full
//!   drift grid behind `POLCA_TEST_FULL=1`.

use polca::exec::{run_batch, ExecConfig};
use polca::experiments::adapt::{drift_verdict, run_drift_study, DriftStudy};
use polca::policy::adapt::AdaptConfig;
use polca::simulation::{run, SimConfig};
use polca::testing::{assert_bit_identical, full_suite, random_sim_config};
use polca::util::rng::Rng;

// ---- disabled-controller bit-identity ---------------------------------

#[test]
fn disabled_controller_is_bit_identical_across_random_configs() {
    let mut rng = Rng::new(0xADA7_CAFE);
    for case in 0..6 {
        let cfg = random_sim_config(&mut rng);
        assert!(cfg.adapt.is_none(), "generator must not arm the controller");
        let a = run(&cfg);
        let b = run(&cfg);
        assert_bit_identical(&a, &b, &format!("case {case}: same config diverged"));
        assert!(
            a.adapt.is_none(),
            "case {case}: report carries an adapt block with the controller off"
        );
        // The Debug render must not even mention the adapt field's
        // contents beyond `None` — i.e. a disabled run's report is
        // indistinguishable from one produced before the controller
        // existed except for the literal `adapt: None`.
        assert!(format!("{a:?}").contains("adapt: None"), "case {case}");
    }
}

#[test]
fn inert_controller_costs_exactly_one_event_per_window() {
    // Pin the controller so it can only ever Hold: no spare racked
    // servers (deployed == baseline), a level range collapsed to zero,
    // and a raise margin no window can clear. The armed run must then
    // replay the disabled run exactly, plus one dispatched event per
    // RetuneCheck window — the observability analogue of the
    // zero-cost-when-off contract, one layer up.
    let mut base = SimConfig::default();
    base.exp.row.num_servers = 10;
    base.deployed_servers = 10;
    base.weeks = 0.02;
    base.exp.seed = 11;
    base.power_scale = 1.35;
    let off = run(&base);

    let mut armed = base.clone();
    armed.adapt = Some(AdaptConfig {
        window_s: 1800.0,
        min_added: 0.0,
        initial_added: 0.0,
        max_added: 0.0,
        raise_margin: 1.0,
        ..Default::default()
    });
    let on = run(&armed);
    let a = on.adapt.as_ref().expect("armed controller must report");
    assert!(a.evals > 0, "no windows evaluated over the horizon");
    assert_eq!(a.applies, 0, "a pinned controller moved a knob: {a:?}");
    assert_eq!(a.requests_shed, 0, "nothing is inactive, nothing may shed");
    assert_eq!(
        on.events,
        off.events + a.evals,
        "each retune window must cost exactly one extra dispatched event"
    );
    assert_eq!(on.power_peak, off.power_peak, "an all-Hold controller perturbed the row");
}

// ---- determinism: serial vs parallel decision sequences ---------------

#[test]
fn retune_decision_sequence_is_identical_serial_and_parallel() {
    // A small grid of adaptive configs; the decision sequence (and the
    // whole report) must not depend on executor scheduling.
    let grid: Vec<SimConfig> = (0..4)
        .map(|i| {
            let mut cfg = SimConfig::default();
            cfg.exp.row.num_servers = 10;
            cfg.deployed_servers = 14;
            cfg.weeks = 0.02;
            cfg.exp.seed = 100 + i;
            cfg.power_scale = 1.35;
            cfg.adapt = Some(AdaptConfig {
                window_s: 1800.0,
                hold_windows: 1 + (i as u32 % 2),
                ..Default::default()
            });
            cfg
        })
        .collect();
    let serial: Vec<String> =
        run_batch(&grid, &ExecConfig::serial(), |_, cfg| format!("{:?}", run(cfg).adapt));
    let parallel: Vec<String> =
        run_batch(&grid, &ExecConfig::default(), |_, cfg| format!("{:?}", run(cfg).adapt));
    assert_eq!(serial, parallel, "decision sequences depend on executor scheduling");
    for (i, rendered) in serial.iter().enumerate() {
        assert!(rendered.starts_with("Some"), "grid item {i} reported no adapt block");
        assert!(rendered.contains("decisions"), "grid item {i}: {rendered}");
    }
}

// ---- long-horizon drift regression ------------------------------------

fn assert_dominates(study: &DriftStudy, ctx: &str) {
    let points = run_drift_study(study);
    let v = drift_verdict(&points);
    assert!(
        v.adaptive_violation_s <= v.static_violation_s + 1e-9,
        "{ctx}: adaptive violation {:.1}s worse than static {:.1}s\n{points:#?}",
        v.adaptive_violation_s,
        v.static_violation_s
    );
    assert!(
        v.adaptive_mean_added >= v.static_mean_added - 1e-9,
        "{ctx}: adaptive mean added {:.3} below static {:.3}\n{points:#?}",
        v.adaptive_mean_added,
        v.static_mean_added
    );
    assert!(v.slo_ok_both, "{ctx}: an arm broke the Table-5 SLOs\n{points:#?}");
    let adaptive = points.last().unwrap();
    assert!(adaptive.retunes.0 > 0, "{ctx}: the controller never evaluated a window");
}

#[test]
fn adaptive_row_dominates_static_on_the_quick_drift_scenario() {
    let study = DriftStudy {
        weeks: 0.1,
        seed: 7,
        servers: 12,
        static_levels: vec![0.10],
        window_s: 1800.0,
        power_scale: Some(1.35),
        ..Default::default()
    };
    assert_dominates(&study, "quick drift tier");
}

#[test]
fn adaptive_row_dominates_static_across_the_full_drift_grid() {
    if !full_suite() {
        eprintln!("skipping full drift grid (set POLCA_TEST_FULL=1)");
        return;
    }
    for &growth in &[0.0, 0.025, 0.05] {
        for &amp in &[0.0, 0.15, 0.30] {
            for &seed in &[1, 7] {
                let study = DriftStudy {
                    weeks: 0.25,
                    seed,
                    servers: 12,
                    static_levels: vec![0.10],
                    window_s: 3600.0,
                    growth_per_week: growth,
                    season_amp: amp,
                    power_scale: Some(1.35),
                    ..Default::default()
                };
                assert_dominates(
                    &study,
                    &format!("grid growth={growth} amp={amp} seed={seed}"),
                );
            }
        }
    }
}

// ---- safety clamp visible end to end ----------------------------------

#[test]
fn every_decision_is_recorded_and_bounded() {
    // The per-window decision log must cover every eval, stay inside
    // the configured level range, and only ever use tuner-grid rungs.
    let mut cfg = SimConfig::default();
    cfg.exp.row.num_servers = 10;
    cfg.deployed_servers = 14;
    cfg.weeks = 0.03;
    cfg.exp.seed = 3;
    cfg.power_scale = 1.35;
    cfg.adapt = Some(AdaptConfig {
        window_s: 1800.0,
        min_added: 0.0,
        initial_added: 0.10,
        max_added: 0.40,
        ..Default::default()
    });
    let report = run(&cfg);
    let a = report.adapt.expect("armed controller must report");
    assert_eq!(a.evals as usize, a.decisions.len(), "one logged decision per eval");
    // The layer clamps the ceiling to what is racked: 14/10 - 1 = 40%.
    for d in &a.decisions {
        assert!(
            (0.0..=0.40 + 1e-9).contains(&d.added),
            "level {d:?} outside the configured range"
        );
        assert!(
            polca::policy::adapt::LADDER.contains(&(d.t1, d.t2)),
            "thresholds {d:?} off the tuner grid"
        );
    }
    assert!(
        (a.mean_added - 0.10).abs() < 0.40,
        "mean level {} not anchored near the start",
        a.mean_added
    );
}
