//! Differential property suite for the event-queue rewrite (ISSUE 10).
//!
//! The 4-ary implicit-heap [`EventQueue`] replaced the original
//! `BinaryHeap<Reverse<Entry>>` queue, which is retained verbatim as
//! [`ReferenceQueue`] to serve as the oracle here. Because every entry's
//! (time, insertion-seq) key is unique, the engine's ordering is total
//! and a correct queue has exactly one legal pop sequence — so the
//! property is the strongest possible: element-wise identity, not just
//! "both sorted".
//!
//! Properties:
//! * **Randomized interleaving** — many seeds; each drives both queues
//!   through an identical random schedule/pop interleave (clustered
//!   timestamps to force ties, occasional past times to exercise the
//!   clamp) and asserts identical pop streams and counters.
//! * **Same-timestamp bursts** — all entries at one instant must drain
//!   in exact insertion order (FIFO among ties), at any burst size.
//! * **Schedule-during-drain** — scheduling from inside the drain loop
//!   (what every simulation handler does) preserves identity, including
//!   entries landing exactly at `now`.
//! * **Counter parity** — `popped()`/`scheduled()`/`len()`/`now()`
//!   agree at every step, not just at the end.

use polca::sim::reference::ReferenceQueue;
use polca::sim::EventQueue;
use polca::util::rng::Rng;

/// Drive both queues through one identical operation and assert every
/// observable agrees afterwards.
struct Pair {
    new: EventQueue<u64>,
    oracle: ReferenceQueue<u64>,
}

impl Pair {
    fn new() -> Pair {
        Pair { new: EventQueue::new(), oracle: ReferenceQueue::new() }
    }

    fn schedule_at(&mut self, t: u64, payload: u64) {
        self.new.schedule_at(t, payload);
        self.oracle.schedule_at(t, payload);
        self.check();
    }

    fn schedule_in(&mut self, dt: u64, payload: u64) {
        self.new.schedule_in(dt, payload);
        self.oracle.schedule_in(dt, payload);
        self.check();
    }

    fn pop(&mut self) -> Option<(u64, u64)> {
        let a = self.new.pop();
        let b = self.oracle.pop();
        assert_eq!(a, b, "pop #{} diverged", self.oracle.popped());
        self.check();
        a
    }

    fn check(&self) {
        assert_eq!(self.new.len(), self.oracle.len());
        assert_eq!(self.new.is_empty(), self.oracle.is_empty());
        assert_eq!(self.new.now(), self.oracle.now());
        assert_eq!(self.new.popped(), self.oracle.popped());
        assert_eq!(self.new.scheduled(), self.oracle.scheduled());
        assert_eq!(self.new.peek_time(), self.oracle.peek_time());
    }
}

// ---- randomized interleaving ------------------------------------------

#[test]
fn randomized_interleaved_schedule_pop_is_element_wise_identical() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xDE5_0000 + seed);
        let mut pair = Pair::new();
        let mut payload = 0u64;
        for _ in 0..600 {
            // Bias toward scheduling so the queues hold real depth, but
            // drain often enough that `now` advances and the past-time
            // clamp path is exercised.
            if rng.f64() < 0.6 {
                // Clustered times force same-timestamp ties; the
                // occasional draw below `now` exercises the clamp.
                let t = pair.new.now().saturating_sub(rng.below(20)) + rng.below(50);
                pair.schedule_at(t, payload);
                payload += 1;
            } else {
                pair.pop();
            }
        }
        // Full drain: the tail must match element-wise too.
        while pair.pop().is_some() {}
        assert!(pair.new.is_empty() && pair.oracle.is_empty());
    }
}

#[test]
fn randomized_relative_scheduling_matches() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xABCD_EF00 + seed);
        let mut pair = Pair::new();
        for i in 0..400u64 {
            if rng.f64() < 0.55 {
                pair.schedule_in(rng.below(100), i);
            } else {
                pair.pop();
            }
        }
        while pair.pop().is_some() {}
    }
}

// ---- same-timestamp bursts --------------------------------------------

#[test]
fn same_timestamp_bursts_drain_in_insertion_order() {
    for &burst in &[1usize, 2, 3, 4, 5, 8, 16, 100, 1000] {
        let mut pair = Pair::new();
        for i in 0..burst as u64 {
            pair.schedule_at(42, i);
        }
        for expect in 0..burst as u64 {
            let (t, payload) = pair.pop().expect("burst entry");
            assert_eq!((t, payload), (42, expect), "FIFO among ties at burst size {burst}");
        }
        assert!(pair.pop().is_none());
    }
}

#[test]
fn interleaved_bursts_at_multiple_timestamps() {
    let mut pair = Pair::new();
    // Round-robin insertion across three timestamps: pop order must be
    // time-major, insertion-order-minor.
    for i in 0..30u64 {
        pair.schedule_at(10 + (i % 3) * 10, i);
    }
    let mut popped = Vec::new();
    while let Some(x) = pair.pop() {
        popped.push(x);
    }
    let mut expect = Vec::new();
    for residue in 0..3u64 {
        for i in 0..30u64 {
            if i % 3 == residue {
                expect.push((10 + residue * 10, i));
            }
        }
    }
    assert_eq!(popped, expect);
}

// ---- schedule-during-drain --------------------------------------------

#[test]
fn scheduling_during_drain_matches_reference() {
    for seed in 0..10u64 {
        let mut rng = Rng::new(0x00D_0000 + seed);
        let mut pair = Pair::new();
        for i in 0..50u64 {
            pair.schedule_at(rng.below(100), i);
        }
        let mut payload = 1000u64;
        // The simulation pattern: every handler may schedule follow-ups,
        // sometimes exactly at `now` (zero-delay), sometimes far out.
        while let Some((t, _)) = pair.pop() {
            if payload < 1400 && rng.f64() < 0.7 {
                let dt = if rng.f64() < 0.2 { 0 } else { rng.below(30) };
                pair.schedule_at(t + dt, payload);
                payload += 1;
            }
        }
        assert_eq!(pair.new.popped(), pair.new.scheduled(), "every scheduled entry popped");
    }
}

#[test]
fn past_times_clamp_identically_mid_drain() {
    let mut pair = Pair::new();
    pair.schedule_at(100, 0);
    pair.schedule_at(200, 1);
    pair.pop(); // now = 100
    // All of these are in the past or at now; both queues must clamp to
    // now=100 and order them by insertion among themselves.
    pair.schedule_at(0, 2);
    pair.schedule_at(99, 3);
    pair.schedule_at(100, 4);
    let drained: Vec<_> = std::iter::from_fn(|| pair.pop()).collect();
    assert_eq!(drained, vec![(100, 2), (100, 3), (100, 4), (200, 1)]);
}

// ---- clone/counter behavior -------------------------------------------

#[test]
fn cloned_queue_continues_identically() {
    let mut pair = Pair::new();
    let mut rng = Rng::new(7);
    for i in 0..200u64 {
        pair.schedule_at(rng.below(500), i);
    }
    for _ in 0..50 {
        pair.pop();
    }
    // Cloning mid-run must preserve the whole observable state.
    let mut new2 = pair.new.clone();
    let mut oracle2 = pair.oracle.clone();
    loop {
        let (a, b) = (new2.pop(), oracle2.pop());
        assert_eq!(a, b);
        if a.is_none() {
            break;
        }
    }
    assert_eq!(new2.popped(), oracle2.popped());
    // The originals are untouched by the clones' drains.
    while pair.pop().is_some() {}
}
