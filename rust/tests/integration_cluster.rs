//! Integration tests across sim + cluster + policy + metrics: the
//! system-level invariants the paper's design depends on.

use polca::config::SloConfig;
use polca::policy::engine::PolicyKind;
use polca::policy::tuner::evaluate_point;
use polca::simulation::{run, run_with_impact, SimConfig};

fn cfg(seed: u64) -> SimConfig {
    let mut c = SimConfig::default();
    c.weeks = 0.08; // ~13.4 hours, covers a diurnal peak
    c.exp.row.num_servers = 16;
    c.deployed_servers = 16;
    c.exp.seed = seed;
    c.power_scale = 1.45; // small-row calibration (see simulation tests)
    c
}

/// The headline pipeline: base row has headroom; +30% under POLCA holds
/// SLOs; +30% without protection trips the breaker at the peak.
#[test]
fn headline_oversubscription_story() {
    let base = run(&cfg(1));
    assert!(base.power_peak < 0.9, "base peak {}", base.power_peak);
    assert_eq!(base.brake_events, 0);

    let mut polca30 = cfg(1);
    polca30.deployed_servers = 21; // +31%
    let (report, impact) = run_with_impact(&polca30);
    assert!(
        impact.meets_slo(&polca30.exp.slo),
        "POLCA +30% violated SLOs: {:?} | {:?}",
        impact.slo_violations(&polca30.exp.slo),
        impact
    );
    assert!(report.power_peak <= 1.0 + 1e-9);

    let mut nocap30 = cfg(1);
    nocap30.deployed_servers = 24; // +50% unprotected: must overload
    nocap30.policy_kind = PolicyKind::NoCap;
    let r = run(&nocap30);
    assert!(r.brake_events > 0, "unprotected +50% row should brake");
}

/// Capping must bite LP before HP across seeds (priority ordering).
#[test]
fn lp_absorbs_capping_before_hp() {
    for seed in [2, 3, 4] {
        let mut c = cfg(seed);
        c.deployed_servers = 22;
        let (_, impact) = run_with_impact(&c);
        assert!(
            impact.lp_p99 + 1e-6 >= impact.hp_p99,
            "seed {seed}: HP p99 {} > LP p99 {}",
            impact.hp_p99,
            impact.lp_p99
        );
    }
}

/// The telemetry/OOB latency chain must not break safety: even with a
/// lossy, jittery OOB channel, the brake path still bounds the damage.
#[test]
fn unreliable_oob_still_protected() {
    let mut c = cfg(5);
    c.deployed_servers = 22;
    c.oob_loss_prob = 0.3;
    c.oob_jitter_frac = 0.25;
    let r = run(&c);
    // The run completes and the row spends almost no time above budget:
    // any overload is cut by the (reliable) brake path within ~7s.
    assert!(r.power_peak < 1.15, "runaway power {}", r.power_peak);
    let over_budget_time = r.brake_time_s;
    assert!(over_budget_time < r.duration_s * 0.05);
}

/// Tuner: more added servers never *reduces* LP impact (monotone load),
/// and the zero-added point is SLO-clean.
#[test]
fn tuner_monotonicity() {
    let base = cfg(6);
    let slo = SloConfig::default();
    let p0 = evaluate_point(&base, 0.80, 0.89, 0.0, &slo);
    let p30 = evaluate_point(&base, 0.80, 0.89, 0.30, &slo);
    assert!(p0.meets_slo, "{p0:?}");
    assert!(p30.lp_p99 + 1e-9 >= p0.lp_p99, "{} vs {}", p30.lp_p99, p0.lp_p99);
}

/// Determinism across the whole stack: same seed, same report.
#[test]
fn full_stack_determinism() {
    let c = cfg(7);
    let (mut a, ia) = run_with_impact(&c);
    let (mut b, ib) = run_with_impact(&c);
    assert_eq!(a.hp.completed, b.hp.completed);
    assert_eq!(a.brake_events, b.brake_events);
    assert!((a.hp.latency.p99() - b.hp.latency.p99()).abs() < 1e-12);
    assert!((ia.lp_p99 - ib.lp_p99).abs() < 1e-12);
}

/// Seed sensitivity: the headline must not be a fluke of one seed.
#[test]
fn polca_zero_brakes_across_seeds() {
    for seed in [11, 13, 17] {
        let mut c = cfg(seed);
        c.deployed_servers = 21;
        let r = run(&c);
        assert_eq!(r.brake_events, 0, "seed {seed} braked");
    }
}

/// Fig 15b mechanism: shrinking the LP pool shifts pain to HP.
#[test]
fn small_lp_pool_hurts_hp() {
    let mut lots_lp = cfg(8);
    lots_lp.deployed_servers = 22;
    lots_lp.lp_fraction_override = Some(0.75);
    let (_, imp_lots) = run_with_impact(&lots_lp);

    let mut few_lp = cfg(8);
    few_lp.deployed_servers = 22;
    few_lp.lp_fraction_override = Some(0.10);
    let (_, imp_few) = run_with_impact(&few_lp);

    assert!(
        imp_few.hp_p99 + 1e-9 >= imp_lots.hp_p99,
        "HP impact should grow as LP pool shrinks: {} vs {}",
        imp_few.hp_p99,
        imp_lots.hp_p99
    );
}
