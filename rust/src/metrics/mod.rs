//! Evaluation metrics and SLO accounting (paper Table 5).
//!
//! Latency **impact** is measured the way the paper uses it: the relative
//! increase of a latency percentile under a power-management policy
//! versus the *same* workload realization executed unthrottled (same
//! seed → same arrivals, same token counts, no caps, no brake). This
//! isolates the capping-attributable slowdown — per-request latency in a
//! loaded queueing system is noisy, but paired percentiles cancel the
//! baseline queueing behaviour.

use crate::cluster::hierarchy::Priority;
use crate::config::SloConfig;
use crate::util::json::Json;
use crate::util::stats::Percentiles;

/// Per-priority accumulators for one run.
#[derive(Debug, Clone, Default)]
pub struct PriorityMetrics {
    /// End-to-end latency per request (queueing + execution), seconds.
    pub latency: Percentiles,
    /// Diagnostic: actual / nominal-execution − 1 per request (includes
    /// queueing, so useful for trends, not SLO checks).
    pub exec_impact: Percentiles,
    /// Requests completed.
    pub completed: u64,
    /// Requests rejected at a full buffer.
    pub dropped: u64,
    /// Output tokens produced (throughput accounting).
    pub tokens_out: f64,
    /// Sum of end-to-end latencies (for the mean).
    pub latency_sum: f64,
}

impl PriorityMetrics {
    /// Record one completed request.
    pub fn record(&mut self, actual_s: f64, nominal_s: f64, tokens: f64) {
        self.latency.push(actual_s);
        self.exec_impact.push(crate::perfmodel::latency_impact(actual_s, nominal_s));
        self.completed += 1;
        self.tokens_out += tokens;
        self.latency_sum += actual_s;
    }

    /// Requests offered to this class (completed + dropped).
    pub fn offered(&self) -> u64 {
        self.completed + self.dropped
    }
}

/// Training-side accumulators for one mixed-row run (§2.4 / §7).
///
/// Capping a training job costs *iteration time*, not request latency:
/// a frequency cap stretches the compute-bound fraction of every
/// iteration ([`crate::power::training::TrainingPowerModel::iter_time_s`]),
/// which this struct reports as inflation over the nominal iteration —
/// the §7 argument for why training is the safe thing to throttle.
#[derive(Debug, Clone, Default)]
pub struct TrainingMetrics {
    /// Completed training iterations across all jobs.
    pub iters: u64,
    /// Wall time per completed iteration, seconds.
    pub iter_time: Percentiles,
    /// Sum of iteration wall times (for the mean).
    pub iter_time_sum_s: f64,
    /// Iteration wall time at nominal frequency (0 when no training ran).
    pub nominal_iter_s: f64,
}

impl TrainingMetrics {
    /// Record one completed iteration.
    pub fn record(&mut self, wall_s: f64) {
        self.iters += 1;
        self.iter_time.push(wall_s);
        self.iter_time_sum_s += wall_s;
    }

    /// Mean iteration wall time over the run (0 when no training ran).
    pub fn mean_iter_s(&self) -> f64 {
        if self.iters == 0 {
            0.0
        } else {
            self.iter_time_sum_s / self.iters as f64
        }
    }

    /// Iteration-time inflation vs nominal, floored at zero — the
    /// training analogue of request-latency impact.
    pub fn inflation(&self) -> f64 {
        if self.iters == 0 || self.nominal_iter_s <= 0.0 {
            return 0.0;
        }
        (self.mean_iter_s() / self.nominal_iter_s - 1.0).max(0.0)
    }

    /// P99 iteration wall time — the tail a training-job owner sees
    /// when caps engage only around diurnal inference peaks (0 when no
    /// training ran).
    pub fn p99_iter_s(&mut self) -> f64 {
        if self.iters == 0 {
            0.0
        } else {
            self.iter_time.p99()
        }
    }
}

/// Relative latency-impact summary of a policy run vs its baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct ImpactSummary {
    /// High-priority P50 latency impact (relative increase vs baseline).
    pub hp_p50: f64,
    /// High-priority P99 latency impact.
    pub hp_p99: f64,
    /// Low-priority P50 latency impact.
    pub lp_p50: f64,
    /// Low-priority P99 latency impact.
    pub lp_p99: f64,
    /// Completed-request HP throughput ratio vs baseline (Fig 14).
    pub hp_throughput: f64,
    /// Completed-request LP throughput ratio vs baseline.
    pub lp_throughput: f64,
    /// Powerbrake engagements in the policy run (SLO: zero).
    pub brake_events: u64,
}

impl ImpactSummary {
    /// Check against the Table 5 SLOs; returns all violations.
    pub fn slo_violations(&self, slo: &SloConfig) -> Vec<String> {
        let mut v = Vec::new();
        let checks = [
            ("HP P50", self.hp_p50, slo.hp_p50_impact),
            ("HP P99", self.hp_p99, slo.hp_p99_impact),
            ("LP P50", self.lp_p50, slo.lp_p50_impact),
            ("LP P99", self.lp_p99, slo.lp_p99_impact),
        ];
        for (name, actual, limit) in checks {
            if !actual.is_nan() && actual > limit {
                v.push(format!(
                    "{name} impact {:.1}% > {:.0}% SLO",
                    actual * 100.0,
                    limit * 100.0
                ));
            }
        }
        if self.brake_events > slo.max_powerbrakes {
            v.push(format!(
                "{} powerbrakes > {} allowed",
                self.brake_events, slo.max_powerbrakes
            ));
        }
        v
    }

    /// Whether every Table 5 SLO holds.
    pub fn meets_slo(&self, slo: &SloConfig) -> bool {
        self.slo_violations(slo).is_empty()
    }

    /// Machine-readable view (the `polca run --json` impact block).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hp_p50", Json::Num(self.hp_p50)),
            ("hp_p99", Json::Num(self.hp_p99)),
            ("lp_p50", Json::Num(self.lp_p50)),
            ("lp_p99", Json::Num(self.lp_p99)),
            ("hp_throughput", Json::Num(self.hp_throughput)),
            ("lp_throughput", Json::Num(self.lp_throughput)),
            ("brake_events", Json::Num(self.brake_events as f64)),
        ])
    }
}

/// One fault episode's outcome: how long the row stayed over its
/// (effective) budget after the fault hit.
#[derive(Debug, Clone, PartialEq)]
pub struct IncidentOutcome {
    /// Fault-kind label ([`crate::faults::FaultKind::label`]).
    pub label: String,
    /// Episode start, seconds into the run.
    pub start_s: f64,
    /// Episode end (state restored), seconds into the run.
    pub end_s: f64,
    /// Seconds from episode onset until the *last* instant the true row
    /// power exceeded the effective budget (0 when the episode never
    /// caused a violation; [`f64::INFINITY`] when the run ends still in
    /// violation — the policy failed to contain the incident).
    pub time_to_contain_s: f64,
}

impl IncidentOutcome {
    /// Whether the incident was contained before the horizon.
    pub fn contained(&self) -> bool {
        self.time_to_contain_s.is_finite()
    }
}

/// Ground-truth budget-violation accounting for one run (the fault
/// subsystem's scoreboard — see [`crate::faults`]).
///
/// Unlike the Table-2 power statistics, which are computed on what the
/// *meter reports* (and are therefore corrupted by a meter-bias fault,
/// deliberately), these track the physically true row power against the
/// *effective* budget (nominal budget × any active feed-loss cut).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResilienceMetrics {
    /// Total seconds the true row power exceeded the effective budget.
    pub violation_s: f64,
    /// Energy over budget, watt-seconds (severity-weighted violation).
    pub overshoot_ws: f64,
    /// Largest instantaneous excess over the effective budget, watts.
    pub peak_overshoot_w: f64,
    /// Peak of true power / effective budget (the reported
    /// `power_peak` can sit below this under a meter-bias fault).
    pub true_peak_norm: f64,
    /// Slow-path commands the rack manager re-issued after an apply
    /// timeout (lost-command repair; acknowledged-but-ignored commands
    /// are never re-issued — those escalate to the brake path instead).
    pub reissued_commands: u64,
    /// Per-injected-fault containment outcomes, in plan order.
    pub incidents: Vec<IncidentOutcome>,
}

impl ResilienceMetrics {
    /// Whether every injected incident was contained before the horizon.
    pub fn all_contained(&self) -> bool {
        self.incidents.iter().all(|i| i.contained())
    }

    /// Worst incident time-to-contain (0 with no incidents; infinite if
    /// any incident was never contained).
    pub fn worst_time_to_contain_s(&self) -> f64 {
        self.incidents.iter().map(|i| i.time_to_contain_s).fold(0.0, f64::max)
    }

    /// Render a time-to-contain value for tables ("-" when there was
    /// nothing to contain, "uncontained" when the horizon hit first).
    pub fn fmt_ttc(ttc: f64) -> String {
        if ttc.is_infinite() {
            "uncontained".to_string()
        } else if ttc == 0.0 {
            "-".to_string()
        } else {
            format!("{ttc:.0}s")
        }
    }
}

/// Relative increase, floored at zero.
fn rel(policy: f64, baseline: f64) -> f64 {
    if baseline <= 0.0 || policy.is_nan() || baseline.is_nan() {
        return 0.0;
    }
    (policy / baseline - 1.0).max(0.0)
}

/// Everything a simulated run produces.
///
/// The control-plane counters keep the paper's two command paths
/// distinct (Table 1): `cap_commands`/`uncap_commands` count *slow-path*
/// OOB frequency commands (~40 s apply latency), while `brake_commands`
/// counts *fast-path* powerbrake engagements (~5 s, BMC hardware
/// signal). `brake_events` is the policy's intent-side count of brake
/// decisions; `brake_commands` is what the channel actually delivered.
/// The two differ only when a run ends with a brake still in flight:
/// the brake path is a dedicated hardware signal that the lossy-channel
/// model never drops (§4, [`crate::cluster::oob::OobChannel::issue`]),
/// so unlike cap commands, no brake decision can go missing mid-run.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    /// High-priority request metrics.
    pub hp: PriorityMetrics,
    /// Low-priority request metrics.
    pub lp: PriorityMetrics,
    /// Training-iteration metrics (mixed rows; empty otherwise).
    pub train: TrainingMetrics,
    /// Powerbrake engagements decided by the policy (the Fig 18 metric).
    pub brake_events: u64,
    /// Slow-path OOB frequency-cap commands that took effect (cap
    /// engagements) — the fleet planner's cap-event-rate input.
    pub cap_commands: u64,
    /// Slow-path OOB uncap commands that took effect.
    pub uncap_commands: u64,
    /// Fast-path powerbrake commands delivered through the BMC channel.
    pub brake_commands: u64,
    /// Seconds with the powerbrake engaged.
    pub brake_time_s: f64,
    /// Ground-truth budget-violation accounting and per-fault
    /// containment (populated by every run; incidents only when a
    /// [`crate::faults::FaultPlan`] was injected).
    pub resilience: ResilienceMetrics,
    /// Peak normalized row power over the run.
    pub power_peak: f64,
    /// P99 of the normalized row-power samples.
    pub power_p99: f64,
    /// Mean normalized row power.
    pub power_mean: f64,
    /// Max power rise within 2 s (Table 2).
    pub spike_2s: f64,
    /// Max power rise within 5 s (Table 2).
    pub spike_5s: f64,
    /// Max power rise within 40 s (Table 2).
    pub spike_40s: f64,
    /// Simulated duration in seconds.
    pub duration_s: f64,
    /// Discrete events processed (the §Perf events/s numerator).
    pub events: u64,
    /// Downsampled row power for Fig 16-style plots.
    pub power_series: Vec<(f64, f64)>,
    /// Adaptive-controller outcome ([`crate::policy::adapt`]); `None`
    /// whenever the controller was disabled, so reports from
    /// controller-free runs stay bit-identical to pre-adapt builds.
    pub adapt: Option<crate::policy::adapt::AdaptReport>,
}

impl RunReport {
    /// The per-priority accumulator for `p`.
    pub fn by_priority(&mut self, p: Priority) -> &mut PriorityMetrics {
        match p {
            Priority::High => &mut self.hp,
            Priority::Low => &mut self.lp,
        }
    }

    /// Paired impact summary vs an unthrottled baseline run.
    pub fn impact_vs(&mut self, baseline: &mut RunReport) -> ImpactSummary {
        ImpactSummary {
            hp_p50: rel(self.hp.latency.p50(), baseline.hp.latency.p50()),
            hp_p99: rel(self.hp.latency.p99(), baseline.hp.latency.p99()),
            lp_p50: rel(self.lp.latency.p50(), baseline.lp.latency.p50()),
            lp_p99: rel(self.lp.latency.p99(), baseline.lp.latency.p99()),
            hp_throughput: if baseline.hp.completed == 0 {
                1.0
            } else {
                self.hp.completed as f64 / baseline.hp.completed as f64
            },
            lp_throughput: if baseline.lp.completed == 0 {
                1.0
            } else {
                self.lp.completed as f64 / baseline.lp.completed as f64
            },
            brake_events: self.brake_events,
        }
    }

    /// One-line summary for CLI output. Reports the fast path (brakes)
    /// and the slow path (OOB caps/uncaps) separately, plus a training
    /// clause when the row ran mixed workloads. A priority class that
    /// served nothing (e.g. a pure-training row) prints `-` instead of
    /// NaN percentiles.
    pub fn summary(&mut self) -> String {
        let lat = |p: &mut PriorityMetrics| {
            if p.latency.is_empty() {
                "-".to_string()
            } else {
                format!("{:.1}s/{:.1}s", p.latency.p50(), p.latency.p99())
            }
        };
        let hp_lat = lat(&mut self.hp);
        let lp_lat = lat(&mut self.lp);
        let mut s = format!(
            "power peak={:.3} p99={:.3} mean={:.3} | HP p50/p99 lat={hp_lat} \
             | LP p50/p99 lat={lp_lat} | brakes={} (fast-path cmds {}) \
             | oob caps/uncaps={}/{} | done HP={} LP={} | drops={}",
            self.power_peak,
            self.power_p99,
            self.power_mean,
            self.brake_events,
            self.brake_commands,
            self.cap_commands,
            self.uncap_commands,
            self.hp.completed,
            self.lp.completed,
            self.hp.dropped + self.lp.dropped,
        );
        if self.train.iters > 0 {
            s.push_str(&format!(
                " | train iters={} mean/p99 iter={:.2}s/{:.2}s inflation={:.1}%",
                self.train.iters,
                self.train.mean_iter_s(),
                self.train.p99_iter_s(),
                self.train.inflation() * 100.0
            ));
        }
        let r = &self.resilience;
        if r.violation_s > 0.0 || !r.incidents.is_empty() {
            s.push_str(&format!(
                " | viol={:.1}s overshoot={:.0}W true-peak={:.3} ttc={} incidents={} reissued={}",
                r.violation_s,
                r.peak_overshoot_w,
                r.true_peak_norm,
                ResilienceMetrics::fmt_ttc(r.worst_time_to_contain_s()),
                r.incidents.len(),
                r.reissued_commands,
            ));
        }
        if let Some(a) = &self.adapt {
            s.push_str(&format!(
                " | adapt evals={} applies={} vetoes={} mean-added={:.1}% \
                 final +{:.0}% T1/T2 {:.0}%/{:.0}% shed={}",
                a.evals,
                a.applies,
                a.vetoes,
                a.mean_added * 100.0,
                a.final_added * 100.0,
                a.final_t1 * 100.0,
                a.final_t2 * 100.0,
                a.requests_shed,
            ));
        }
        s
    }

    /// Machine-readable view of the run (the `polca run --json` report
    /// block): the summary-level observables, per-priority counts and
    /// latency percentiles, training and resilience accounting. `&mut`
    /// because latency percentiles sort lazily. Quantities that can be
    /// non-finite (an uncontained incident's time-to-contain) go
    /// through [`Json::num`] and render as JSON null.
    pub fn to_json(&mut self) -> Json {
        fn priority_json(p: &mut PriorityMetrics) -> Json {
            let (p50, p99) = if p.latency.is_empty() {
                (Json::Null, Json::Null)
            } else {
                (Json::Num(p.latency.p50()), Json::Num(p.latency.p99()))
            };
            Json::obj(vec![
                ("completed", Json::Num(p.completed as f64)),
                ("dropped", Json::Num(p.dropped as f64)),
                ("tokens_out", Json::Num(p.tokens_out)),
                ("latency_p50_s", p50),
                ("latency_p99_s", p99),
            ])
        }
        let hp = priority_json(&mut self.hp);
        let lp = priority_json(&mut self.lp);
        let train = Json::obj(vec![
            ("iters", Json::Num(self.train.iters as f64)),
            ("mean_iter_s", Json::Num(self.train.mean_iter_s())),
            ("nominal_iter_s", Json::Num(self.train.nominal_iter_s)),
            ("inflation", Json::Num(self.train.inflation())),
        ]);
        let r = &self.resilience;
        let incidents = r.incidents.iter().map(|i| {
            Json::obj(vec![
                ("label", Json::Str(i.label.clone())),
                ("start_s", Json::Num(i.start_s)),
                ("end_s", Json::Num(i.end_s)),
                ("time_to_contain_s", Json::num(i.time_to_contain_s)),
                ("contained", Json::Bool(i.contained())),
            ])
        });
        let resilience = Json::obj(vec![
            ("violation_s", Json::Num(r.violation_s)),
            ("overshoot_ws", Json::Num(r.overshoot_ws)),
            ("peak_overshoot_w", Json::Num(r.peak_overshoot_w)),
            ("true_peak_norm", Json::Num(r.true_peak_norm)),
            ("reissued_commands", Json::Num(r.reissued_commands as f64)),
            ("incidents", Json::arr(incidents)),
        ]);
        let mut pairs = vec![
            ("power_peak", Json::Num(self.power_peak)),
            ("power_p99", Json::Num(self.power_p99)),
            ("power_mean", Json::Num(self.power_mean)),
            ("spike_2s", Json::Num(self.spike_2s)),
            ("spike_5s", Json::Num(self.spike_5s)),
            ("spike_40s", Json::Num(self.spike_40s)),
            ("brake_events", Json::Num(self.brake_events as f64)),
            ("brake_commands", Json::Num(self.brake_commands as f64)),
            ("cap_commands", Json::Num(self.cap_commands as f64)),
            ("uncap_commands", Json::Num(self.uncap_commands as f64)),
            ("brake_time_s", Json::Num(self.brake_time_s)),
            ("duration_s", Json::Num(self.duration_s)),
            ("events", Json::Num(self.events as f64)),
            ("hp", hp),
            ("lp", lp),
            ("train", train),
            ("resilience", resilience),
        ];
        if let Some(a) = &self.adapt {
            let decisions = a.decisions.iter().map(|d| {
                Json::obj(vec![
                    ("t_s", Json::Num(d.t_s)),
                    ("verdict", Json::Str(format!("{:?}", d.verdict).to_lowercase())),
                    ("added", Json::Num(d.added)),
                    ("t1", Json::Num(d.t1)),
                    ("t2", Json::Num(d.t2)),
                ])
            });
            pairs.push((
                "adapt",
                Json::obj(vec![
                    ("evals", Json::Num(a.evals as f64)),
                    ("applies", Json::Num(a.applies as f64)),
                    ("vetoes", Json::Num(a.vetoes as f64)),
                    ("mean_added", Json::Num(a.mean_added)),
                    ("final_added", Json::Num(a.final_added)),
                    ("final_t1", Json::Num(a.final_t1)),
                    ("final_t2", Json::Num(a.final_t2)),
                    ("requests_shed", Json::Num(a.requests_shed as f64)),
                    ("decisions", Json::arr(decisions)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(hp_lat: &[f64], lp_lat: &[f64], brakes: u64) -> RunReport {
        let mut r = RunReport::default();
        for &l in hp_lat {
            r.hp.record(l, l, 10.0);
        }
        for &l in lp_lat {
            r.lp.record(l, l, 10.0);
        }
        r.brake_events = brakes;
        r
    }

    #[test]
    fn identical_runs_have_zero_impact() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let mut a = report_with(&lats, &lats, 0);
        let mut b = report_with(&lats, &lats, 0);
        let imp = a.impact_vs(&mut b);
        assert_eq!(imp.hp_p50, 0.0);
        assert_eq!(imp.lp_p99, 0.0);
        assert_eq!(imp.hp_throughput, 1.0);
        assert!(imp.meets_slo(&SloConfig::default()));
    }

    #[test]
    fn slowdown_shows_as_impact() {
        let base: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let slowed: Vec<f64> = base.iter().map(|l| l * 1.3).collect();
        let mut a = report_with(&base, &slowed, 0);
        let mut b = report_with(&base, &base, 0);
        let imp = a.impact_vs(&mut b);
        assert!(imp.hp_p99 < 1e-9);
        assert!((imp.lp_p50 - 0.3).abs() < 1e-9);
        assert!((imp.lp_p99 - 0.3).abs() < 1e-9);
        // LP P50 30% > 5% SLO → violation; LP P99 30% < 50% → fine.
        let v = imp.slo_violations(&SloConfig::default());
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("LP P50"));
        // A gentle 3% uniform slowdown passes every SLO.
        let gentle: Vec<f64> = base.iter().map(|l| l * 1.03).collect();
        let mut c = report_with(&base, &gentle, 0);
        assert!(c.impact_vs(&mut b).meets_slo(&SloConfig::default()));
    }

    #[test]
    fn hp_violation_detected() {
        let base: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let slowed: Vec<f64> = base.iter().map(|l| l * 1.08).collect();
        let mut a = report_with(&slowed, &base, 0);
        let mut b = report_with(&base, &base, 0);
        let v = a.impact_vs(&mut b).slo_violations(&SloConfig::default());
        assert!(v.iter().any(|s| s.contains("HP P50")), "{v:?}");
        assert!(v.iter().any(|s| s.contains("HP P99")), "{v:?}");
    }

    #[test]
    fn brakes_violate() {
        let mut a = report_with(&[1.0], &[1.0], 2);
        let mut b = report_with(&[1.0], &[1.0], 0);
        let v = a.impact_vs(&mut b).slo_violations(&SloConfig::default());
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("powerbrakes"));
    }

    #[test]
    fn speedup_is_not_negative_impact() {
        let base = [2.0, 2.0];
        let faster = [1.0, 1.0];
        let mut a = report_with(&faster, &faster, 0);
        let mut b = report_with(&base, &base, 0);
        let imp = a.impact_vs(&mut b);
        assert_eq!(imp.hp_p50, 0.0);
    }

    #[test]
    fn empty_class_not_a_violation() {
        let mut a = report_with(&[], &[1.0], 0);
        let mut b = report_with(&[], &[1.0], 0);
        assert!(a.impact_vs(&mut b).meets_slo(&SloConfig::default()));
    }

    #[test]
    fn training_inflation_vs_nominal() {
        let mut t = TrainingMetrics::default();
        assert_eq!(t.inflation(), 0.0); // no training ran
        t.nominal_iter_s = 2.0;
        t.record(2.0);
        t.record(2.0);
        assert_eq!(t.iters, 2);
        assert!(t.inflation() < 1e-12, "uncapped training has no inflation");
        t.record(3.0); // one capped iteration
        assert!((t.mean_iter_s() - 7.0 / 3.0).abs() < 1e-12);
        assert!((t.inflation() - (7.0 / 6.0 - 1.0)).abs() < 1e-12);
        assert!(t.p99_iter_s() > 2.9, "tail must reflect the capped iteration");
    }

    #[test]
    fn summary_separates_fast_and_slow_paths() {
        let mut r = report_with(&[1.0], &[1.0], 3);
        r.cap_commands = 7;
        r.uncap_commands = 5;
        r.brake_commands = 2;
        let s = r.summary();
        assert!(s.contains("brakes=3 (fast-path cmds 2)"), "{s}");
        assert!(s.contains("oob caps/uncaps=7/5"), "{s}");
        assert!(!s.contains("train iters"), "no training clause: {s}");
        r.train.nominal_iter_s = 2.0;
        r.train.record(2.2);
        let s2 = r.summary();
        assert!(s2.contains("train iters=1"), "{s2}");
        // A class that served nothing prints '-' instead of NaN
        // (reachable via `polca mixed run --training 1.0`).
        let mut empty = RunReport::default();
        let s3 = empty.summary();
        assert!(!s3.contains("NaN"), "{s3}");
        assert!(s3.contains("HP p50/p99 lat=-"), "{s3}");
    }

    #[test]
    fn resilience_containment_accounting() {
        let mut r = ResilienceMetrics::default();
        assert!(r.all_contained());
        assert_eq!(r.worst_time_to_contain_s(), 0.0);
        r.incidents.push(IncidentOutcome {
            label: "feed-loss".into(),
            start_s: 100.0,
            end_s: 200.0,
            time_to_contain_s: 17.0,
        });
        r.incidents.push(IncidentOutcome {
            label: "meter-bias".into(),
            start_s: 400.0,
            end_s: 500.0,
            time_to_contain_s: 0.0,
        });
        assert!(r.all_contained());
        assert_eq!(r.worst_time_to_contain_s(), 17.0);
        r.incidents.push(IncidentOutcome {
            label: "cap-ignore".into(),
            start_s: 800.0,
            end_s: 900.0,
            time_to_contain_s: f64::INFINITY,
        });
        assert!(!r.all_contained());
        assert!(r.worst_time_to_contain_s().is_infinite());
        assert_eq!(ResilienceMetrics::fmt_ttc(0.0), "-");
        assert_eq!(ResilienceMetrics::fmt_ttc(17.4), "17s");
        assert_eq!(ResilienceMetrics::fmt_ttc(f64::INFINITY), "uncontained");
    }

    #[test]
    fn summary_includes_resilience_clause_only_when_relevant() {
        let mut r = report_with(&[1.0], &[1.0], 0);
        assert!(!r.summary().contains("viol="), "{}", r.summary());
        r.resilience.violation_s = 12.5;
        r.resilience.peak_overshoot_w = 4200.0;
        r.resilience.true_peak_norm = 1.08;
        let s = r.summary();
        assert!(s.contains("viol=12.5s"), "{s}");
        assert!(s.contains("true-peak=1.080"), "{s}");
    }

    #[test]
    fn adapt_clause_and_json_only_when_the_controller_ran() {
        use crate::policy::adapt::{AdaptReport, RetuneDecision, Verdict};
        let mut r = report_with(&[1.0], &[1.0], 0);
        assert!(!r.summary().contains("adapt"), "{}", r.summary());
        assert!(r.to_json().get("adapt").is_none());
        r.adapt = Some(AdaptReport {
            evals: 8,
            applies: 3,
            vetoes: 1,
            mean_added: 0.12,
            final_added: 0.20,
            final_t1: 0.80,
            final_t2: 0.89,
            requests_shed: 5,
            decisions: vec![RetuneDecision {
                t_s: 21_600.0,
                verdict: Verdict::Apply,
                added: 0.05,
                t1: 0.80,
                t2: 0.89,
            }],
        });
        let s = r.summary();
        assert!(s.contains("adapt evals=8 applies=3 vetoes=1"), "{s}");
        let j = r.to_json();
        let a = j.get("adapt").expect("adapt block");
        assert_eq!(a.get("applies").unwrap().as_f64(), Some(3.0));
        let d = &a.get("decisions").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("verdict").unwrap().as_str(), Some("apply"));
    }

    #[test]
    fn throughput_ratio() {
        let mut a = report_with(&[1.0; 9], &[], 0);
        let mut b = report_with(&[1.0; 10], &[], 0);
        let imp = a.impact_vs(&mut b);
        assert!((imp.hp_throughput - 0.9).abs() < 1e-12);
    }
}
