//! Evaluation metrics and SLO accounting (paper Table 5).
//!
//! Latency **impact** is measured the way the paper uses it: the relative
//! increase of a latency percentile under a power-management policy
//! versus the *same* workload realization executed unthrottled (same
//! seed → same arrivals, same token counts, no caps, no brake). This
//! isolates the capping-attributable slowdown — per-request latency in a
//! loaded queueing system is noisy, but paired percentiles cancel the
//! baseline queueing behaviour.

use crate::cluster::hierarchy::Priority;
use crate::config::SloConfig;
use crate::util::stats::Percentiles;

/// Per-priority accumulators for one run.
#[derive(Debug, Clone, Default)]
pub struct PriorityMetrics {
    /// End-to-end latency per request (queueing + execution), seconds.
    pub latency: Percentiles,
    /// Diagnostic: actual / nominal-execution − 1 per request (includes
    /// queueing, so useful for trends, not SLO checks).
    pub exec_impact: Percentiles,
    pub completed: u64,
    pub dropped: u64,
    pub tokens_out: f64,
    pub latency_sum: f64,
}

impl PriorityMetrics {
    pub fn record(&mut self, actual_s: f64, nominal_s: f64, tokens: f64) {
        self.latency.push(actual_s);
        self.exec_impact.push(crate::perfmodel::latency_impact(actual_s, nominal_s));
        self.completed += 1;
        self.tokens_out += tokens;
        self.latency_sum += actual_s;
    }

    pub fn offered(&self) -> u64 {
        self.completed + self.dropped
    }
}

/// Relative latency-impact summary of a policy run vs its baseline.
#[derive(Debug, Clone, Copy, Default)]
pub struct ImpactSummary {
    pub hp_p50: f64,
    pub hp_p99: f64,
    pub lp_p50: f64,
    pub lp_p99: f64,
    /// Completed-request throughput ratios vs baseline (Fig 14).
    pub hp_throughput: f64,
    pub lp_throughput: f64,
    pub brake_events: u64,
}

impl ImpactSummary {
    /// Check against the Table 5 SLOs; returns all violations.
    pub fn slo_violations(&self, slo: &SloConfig) -> Vec<String> {
        let mut v = Vec::new();
        let checks = [
            ("HP P50", self.hp_p50, slo.hp_p50_impact),
            ("HP P99", self.hp_p99, slo.hp_p99_impact),
            ("LP P50", self.lp_p50, slo.lp_p50_impact),
            ("LP P99", self.lp_p99, slo.lp_p99_impact),
        ];
        for (name, actual, limit) in checks {
            if !actual.is_nan() && actual > limit {
                v.push(format!(
                    "{name} impact {:.1}% > {:.0}% SLO",
                    actual * 100.0,
                    limit * 100.0
                ));
            }
        }
        if self.brake_events > slo.max_powerbrakes {
            v.push(format!(
                "{} powerbrakes > {} allowed",
                self.brake_events, slo.max_powerbrakes
            ));
        }
        v
    }

    pub fn meets_slo(&self, slo: &SloConfig) -> bool {
        self.slo_violations(slo).is_empty()
    }
}

/// Relative increase, floored at zero.
fn rel(policy: f64, baseline: f64) -> f64 {
    if baseline <= 0.0 || policy.is_nan() || baseline.is_nan() {
        return 0.0;
    }
    (policy / baseline - 1.0).max(0.0)
}

/// Everything a simulated run produces.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub hp: PriorityMetrics,
    pub lp: PriorityMetrics,
    pub brake_events: u64,
    /// OOB frequency-cap commands that took effect (cap engagements;
    /// uncaps not counted) — the fleet planner's cap-event-rate input.
    pub cap_commands: u64,
    /// Seconds with the powerbrake engaged.
    pub brake_time_s: f64,
    /// Normalized row power stats over the run.
    pub power_peak: f64,
    pub power_p99: f64,
    pub power_mean: f64,
    /// Max power rises within 2 s / 5 s / 40 s (Table 2).
    pub spike_2s: f64,
    pub spike_5s: f64,
    pub spike_40s: f64,
    pub duration_s: f64,
    pub events: u64,
    /// Downsampled row power for Fig 16-style plots.
    pub power_series: Vec<(f64, f64)>,
}

impl RunReport {
    pub fn by_priority(&mut self, p: Priority) -> &mut PriorityMetrics {
        match p {
            Priority::High => &mut self.hp,
            Priority::Low => &mut self.lp,
        }
    }

    /// Paired impact summary vs an unthrottled baseline run.
    pub fn impact_vs(&mut self, baseline: &mut RunReport) -> ImpactSummary {
        ImpactSummary {
            hp_p50: rel(self.hp.latency.p50(), baseline.hp.latency.p50()),
            hp_p99: rel(self.hp.latency.p99(), baseline.hp.latency.p99()),
            lp_p50: rel(self.lp.latency.p50(), baseline.lp.latency.p50()),
            lp_p99: rel(self.lp.latency.p99(), baseline.lp.latency.p99()),
            hp_throughput: if baseline.hp.completed == 0 {
                1.0
            } else {
                self.hp.completed as f64 / baseline.hp.completed as f64
            },
            lp_throughput: if baseline.lp.completed == 0 {
                1.0
            } else {
                self.lp.completed as f64 / baseline.lp.completed as f64
            },
            brake_events: self.brake_events,
        }
    }

    /// One-line summary for CLI output.
    pub fn summary(&mut self) -> String {
        format!(
            "power peak={:.3} p99={:.3} mean={:.3} | HP p50/p99 lat={:.1}s/{:.1}s \
             | LP p50/p99 lat={:.1}s/{:.1}s | brakes={} | done HP={} LP={} | drops={}",
            self.power_peak,
            self.power_p99,
            self.power_mean,
            self.hp.latency.p50(),
            self.hp.latency.p99(),
            self.lp.latency.p50(),
            self.lp.latency.p99(),
            self.brake_events,
            self.hp.completed,
            self.lp.completed,
            self.hp.dropped + self.lp.dropped,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(hp_lat: &[f64], lp_lat: &[f64], brakes: u64) -> RunReport {
        let mut r = RunReport::default();
        for &l in hp_lat {
            r.hp.record(l, l, 10.0);
        }
        for &l in lp_lat {
            r.lp.record(l, l, 10.0);
        }
        r.brake_events = brakes;
        r
    }

    #[test]
    fn identical_runs_have_zero_impact() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let mut a = report_with(&lats, &lats, 0);
        let mut b = report_with(&lats, &lats, 0);
        let imp = a.impact_vs(&mut b);
        assert_eq!(imp.hp_p50, 0.0);
        assert_eq!(imp.lp_p99, 0.0);
        assert_eq!(imp.hp_throughput, 1.0);
        assert!(imp.meets_slo(&SloConfig::default()));
    }

    #[test]
    fn slowdown_shows_as_impact() {
        let base: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let slowed: Vec<f64> = base.iter().map(|l| l * 1.3).collect();
        let mut a = report_with(&base, &slowed, 0);
        let mut b = report_with(&base, &base, 0);
        let imp = a.impact_vs(&mut b);
        assert!(imp.hp_p99 < 1e-9);
        assert!((imp.lp_p50 - 0.3).abs() < 1e-9);
        assert!((imp.lp_p99 - 0.3).abs() < 1e-9);
        // LP P50 30% > 5% SLO → violation; LP P99 30% < 50% → fine.
        let v = imp.slo_violations(&SloConfig::default());
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("LP P50"));
        // A gentle 3% uniform slowdown passes every SLO.
        let gentle: Vec<f64> = base.iter().map(|l| l * 1.03).collect();
        let mut c = report_with(&base, &gentle, 0);
        assert!(c.impact_vs(&mut b).meets_slo(&SloConfig::default()));
    }

    #[test]
    fn hp_violation_detected() {
        let base: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let slowed: Vec<f64> = base.iter().map(|l| l * 1.08).collect();
        let mut a = report_with(&slowed, &base, 0);
        let mut b = report_with(&base, &base, 0);
        let v = a.impact_vs(&mut b).slo_violations(&SloConfig::default());
        assert!(v.iter().any(|s| s.contains("HP P50")), "{v:?}");
        assert!(v.iter().any(|s| s.contains("HP P99")), "{v:?}");
    }

    #[test]
    fn brakes_violate() {
        let mut a = report_with(&[1.0], &[1.0], 2);
        let mut b = report_with(&[1.0], &[1.0], 0);
        let v = a.impact_vs(&mut b).slo_violations(&SloConfig::default());
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("powerbrakes"));
    }

    #[test]
    fn speedup_is_not_negative_impact() {
        let base = [2.0, 2.0];
        let faster = [1.0, 1.0];
        let mut a = report_with(&faster, &faster, 0);
        let mut b = report_with(&base, &base, 0);
        let imp = a.impact_vs(&mut b);
        assert_eq!(imp.hp_p50, 0.0);
    }

    #[test]
    fn empty_class_not_a_violation() {
        let mut a = report_with(&[], &[1.0], 0);
        let mut b = report_with(&[], &[1.0], 0);
        assert!(a.impact_vs(&mut b).meets_slo(&SloConfig::default()));
    }

    #[test]
    fn throughput_ratio() {
        let mut a = report_with(&[1.0; 9], &[], 0);
        let mut b = report_with(&[1.0; 10], &[], 0);
        let imp = a.impact_vs(&mut b);
        assert!((imp.hp_throughput - 0.9).abs() < 1e-12);
    }
}
