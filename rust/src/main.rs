//! `polca` — CLI for the POLCA reproduction.
//!
//! Canonical surface (the scenario layer — one declarative spec for
//! every run, see `rust/src/scenario/`):
//!   run <preset|file.toml> [--quick] [--policy P] [--weeks W]
//!       [--seed N] [--servers N] [--added FRAC] [--training FRAC]
//!       [--escalate S] [--json] [--trace FILE [--trace-format F]]
//!       Execute one scenario (row simulation or site plan); --json
//!       emits the machine-readable ScenarioReport on stdout. --trace
//!       records the run through the observability layer (`polca::obs`)
//!       and writes the trace as jsonl (default), csv, or chrome
//!       (chrome://tracing); the report gains per-incident timelines.
//!   trace [summarize|timeline|export] <trace.jsonl>
//!       [--format jsonl|csv|chrome] [--out FILE]
//!       Inspect or convert a recorded trace (schema in
//!       docs/OBSERVABILITY.md).
//!   scenario list
//!       Named presets with descriptions.
//!   scenario show <preset|file>      Print the scenario as TOML.
//!   scenario validate <preset|file> | --all
//!       Check a spec (and its TOML round-trip); --all covers every preset.
//!   scenario save <preset> [--out FILE]
//!       Write a preset to a TOML file to edit and `polca run`.
//!
//! Reproduction & tooling:
//!   figure <id|all|list> [--out-dir out] [--full] [--seed N]
//!       Regenerate paper tables/figures (CSV + stdout).
//!   tune [--weeks W] [--seed N]       Week-one threshold search (§6.2).
//!   calibrate [--weeks W] [--seed N]  Fit power_scale to the Table-2 peak.
//!   serve [--artifacts DIR] [--requests N] [--oversub F]
//!       Mini end-to-end serving run (real PJRT model, POLCA in loop).
//!       One-shot: plays a fixed request batch and exits — for the
//!       long-running control-plane daemon use `polca gateway`.
//!   gateway [--addr HOST:PORT] [--workers N] [--run-workers N]
//!       [--time-warp F] [--queue N]
//!       Live control-plane daemon over HTTP: submit scenarios
//!       (POST /scenarios, TOML or JSON envelope), fetch reports
//!       (GET /runs/:id — byte-identical to `polca run --json`),
//!       stream control decisions as Server-Sent Events
//!       (GET /runs/:id/events), /healthz, Prometheus /metrics,
//!       graceful POST /shutdown. `--time-warp F` paces runs at F
//!       simulated seconds per wall second (0 = unpaced). Contrast
//!       with `polca serve`, the one-shot PJRT artifact driver.
//!   gateway bench [--quick] [--clients N] [--per-client N]
//!       Built-in loopback load generator; writes req/s and p50/p99
//!       latency to BENCH_gateway.json. Endpoint reference:
//!       docs/GATEWAY.md.
//!   fleet region [plan|trace|validate] [--sites N] [--clusters N]
//!       [--grid-frac F] [--policy P] [--max-added PCT] [--step PCT]
//!       [--validate-sites N] [--quick] [--serial] [--out-dir DIR]
//!       Region-scale planning via compositional trace algebra: the
//!       archetype cache simulates each distinct (SKU, level) pair
//!       once, so cost is independent of site count; `validate`
//!       cross-checks analytic vs full simulation and exits nonzero
//!       out of tolerance (the CI gate).
//!
//! Deprecated aliases (each builds a `Scenario` internally; prefer
//! `polca run`): simulate, mixed [run|sweep], faults
//! [run|sweep|matrix|plan|list], fleet [plan|sweep|trace].
//!
//! Every multi-run path (`faults matrix|sweep`, `mixed sweep`, `tune`,
//! site planning) fans its batch out through the parallel scenario
//! executor (`polca::exec`) — bit-identical to serial; pass `--serial`
//! for the reference path. `faults matrix` also takes `--quick` (the
//! CI smoke shape) and `--json` (machine-readable MatrixOutcome).

use std::path::{Path, PathBuf};

use polca::config::ExperimentConfig;
use polca::experiments::{all_ids, run_experiment, Depth};
use polca::policy::engine::PolicyKind;
use polca::policy::tuner::tune_thresholds_exec;
use polca::scenario::{preset, preset_names, presets, Outcome, Scenario};
use polca::simulation::calibrate;
use polca::util::cli::Args;

fn main() {
    // The library's diagnostics are quiet by default (embedders opt
    // in); the CLI wants them on stderr.
    polca::obs::set_diag_handler(Box::new(|e| match e {
        polca::obs::DiagEvent::CalibrationFit { baseline_servers } => eprintln!(
            "calibrating power_scale for {baseline_servers}-server rows \
             (one-time simulation of one day; cached afterwards) ..."
        ),
        polca::obs::DiagEvent::RegionPlanned { sites, archetype_sims, candidate_evals } => {
            eprintln!(
                "planned {sites} sites from {archetype_sims} archetype simulations \
                 + {candidate_evals} closed-form candidate evaluations"
            )
        }
        polca::obs::DiagEvent::RetuneApplied { t_s, added, t1, t2 } => eprintln!(
            "retune at {:.1}h: +{:.0}% servers, T1 {:.0}% / T2 {:.0}%",
            t_s / 3600.0,
            added * 100.0,
            t1 * 100.0,
            t2 * 100.0
        ),
        polca::obs::DiagEvent::GatewayStarted { port, http_workers, run_workers } => eprintln!(
            "gateway listening on port {port} \
             ({http_workers} http workers, {run_workers} run workers) — \
             POST /scenarios, GET /runs/:id, GET /runs/:id/events, \
             /healthz, /metrics, POST /shutdown"
        ),
        polca::obs::DiagEvent::RunAccepted { run_seq, queued } => {
            eprintln!("accepted run-{run_seq:06} ({queued} queued)")
        }
        polca::obs::DiagEvent::SubscriberDropped { run_seq, pending } => eprintln!(
            "dropped a slow event-stream subscriber of run-{run_seq:06} \
             ({pending} records behind)"
        ),
    }));
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("trace") => cmd_trace(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("figure") => cmd_figure(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("tune") => cmd_tune(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve") => cmd_serve(&args),
        Some("gateway") => cmd_gateway(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("mixed") => cmd_mixed(&args),
        Some("faults") => cmd_faults(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_help();
            std::process::exit(2);
        }
        None => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "polca — Power Oversubscription in LLM Cloud Providers (reproduction)\n\n\
         usage: polca <run|trace|scenario|figure|tune|calibrate|serve|gateway> [options]\n\
         serve   = one-shot PJRT-artifact serving run; gateway = the\n\
         long-running control-plane daemon over HTTP (docs/GATEWAY.md)\n\
         try:   polca scenario list\n       \
                polca run oversubscribed-row --quick\n       \
                polca run cascade-faults --trace cascade.jsonl\n       \
                polca trace timeline cascade.jsonl\n       \
                polca trace export cascade.jsonl --format chrome\n       \
                polca run examples/scenarios/custom-fault-timeline.toml\n       \
                polca scenario save mixed-row --out my-row.toml\n       \
                polca figure fig13 --out-dir out\n       \
                polca serve --requests 16\n       \
                polca gateway --addr 127.0.0.1:7311 --time-warp 600\n       \
                polca gateway bench --quick\n       \
                polca fleet region plan --sites 50\n       \
                polca fleet region validate --quick\n\n\
         deprecated aliases (each builds a scenario internally):\n       \
                polca simulate --policy polca --added 0.30 --weeks 1\n       \
                polca mixed [run|sweep]\n       \
                polca faults [run|sweep|matrix|plan|list]\n       \
                polca fleet [plan|sweep|trace]"
    );
}

fn deprecation_note(old: &str, hint: &str) {
    eprintln!(
        "note: `polca {old}` is a deprecated alias (it now builds a scenario internally) — \
         prefer `{hint}`; see `polca scenario list`"
    );
}

/// Resolve a `polca run` target: an existing path (or anything ending
/// in `.toml`) loads a scenario file; otherwise it names a preset.
fn load_scenario(target: &str) -> anyhow::Result<Scenario> {
    if target.ends_with(".toml") || Path::new(target).exists() {
        Scenario::load(Path::new(target))
    } else {
        preset(target)
    }
}

/// Parse `--escalate [SECONDS]`: a value must be numeric (a typo like
/// `--escalate 60s` is an error, not a silent 120 s), the bare flag
/// arms the 120 s default, absence means "leave unchanged".
fn escalate_arg(args: &Args) -> anyhow::Result<Option<f64>> {
    if let Some(raw) = args.get("escalate") {
        let secs: f64 = raw
            .parse()
            .map_err(|_| anyhow::anyhow!("--escalate needs seconds, got '{raw}'"))?;
        Ok(Some(secs))
    } else if args.flag("escalate") {
        Ok(Some(120.0))
    } else {
        Ok(None)
    }
}

/// The flag overlays every scenario-driven subcommand shares. Options
/// that are absent leave the scenario untouched, so preset/file values
/// are the defaults.
fn apply_overrides(sc: &mut Scenario, args: &Args) -> anyhow::Result<()> {
    if let Some(p) = args.get("policy") {
        sc.policy_kind = polca::util::cli::parse_policy(p)?;
    }
    args.set_f64("weeks", &mut sc.weeks);
    args.set_u64("seed", &mut sc.exp.seed);
    args.set_usize("servers", &mut sc.exp.row.num_servers);
    args.set_f64("added", &mut sc.added_frac);
    args.set_f64("training", &mut sc.training.fraction);
    args.set_f64("power-mult", &mut sc.workload_power_mult);
    if let Some(secs) = escalate_arg(args)? {
        sc.brake_escalation_s = Some(secs);
    }
    if let Some(site) = sc.site.as_mut() {
        args.set_u32("max-added", &mut site.max_added_pct);
        args.set_u32("step", &mut site.step_pct);
        if args.flag("serial") {
            site.parallel = false;
        }
    }
    Ok(())
}

/// Validate, announce, execute, and print one scenario — the single
/// execution path behind `polca run` and every deprecated alias.
/// With `--json`, stdout carries exactly one machine-readable document
/// (the human narration stays on stderr). With `--trace FILE`, the run
/// goes through [`polca::obs::Recorder`], the trace lands in FILE
/// (`--trace-format jsonl|csv|chrome`, default jsonl), and the report
/// gains per-incident timelines — observation is passive, so the
/// numbers are bit-identical to an untraced run.
fn run_and_print(sc: &Scenario, args: &Args) -> anyhow::Result<()> {
    sc.validate()?;
    eprintln!("{}", sc.describe());
    let t = std::time::Instant::now();
    // On failure with --json, stdout still carries exactly one
    // machine-readable document — the shared error serialization
    // (`scenario::error_report_json`) also used by the gateway's
    // failed-run reports — before the nonzero exit.
    let mut report = match run_with_optional_trace(sc, args) {
        Ok(report) => report,
        Err(e) => {
            if args.flag("json") {
                println!("{}", polca::scenario::error_report_json(&sc.name, &e).to_pretty());
            }
            return Err(e);
        }
    };
    let wall = t.elapsed().as_secs_f64();
    if args.flag("json") {
        println!("{}", report.to_json().to_pretty());
        return Ok(());
    }
    print!("{}", report.render());
    if let Outcome::Row(row) = &report.outcome {
        println!(
            "{} events in {:.1}s wall ({:.2}M events/s)",
            row.report.events,
            wall,
            row.report.events as f64 / wall / 1e6
        );
    }
    Ok(())
}

/// The run itself (with the optional `--trace` recording), split out
/// of [`run_and_print`] so its error can be serialized for `--json`.
fn run_with_optional_trace(
    sc: &Scenario,
    args: &Args,
) -> anyhow::Result<polca::scenario::ScenarioReport> {
    match args.get("trace") {
        Some(path) => {
            let mut rec = polca::obs::Recorder::new(polca::obs::RecorderConfig::default());
            let mut report = sc.run_observed(&mut rec)?;
            let records = rec.into_trace(&sc.name).records();
            report.timeline = Some(polca::obs::export::incident_timeline(&records));
            write_trace(&records, Path::new(path), args.get_or("trace-format", "jsonl"))?;
            Ok(report)
        }
        None => sc.run(),
    }
}

/// Write trace records to `path` in one of the export formats.
fn write_trace(
    records: &[polca::util::json::Json],
    path: &Path,
    format: &str,
) -> anyhow::Result<()> {
    use polca::obs::export;
    match format {
        "jsonl" => std::fs::write(path, export::to_jsonl(records))?,
        "csv" => export::to_csv(records).write_to(path)?,
        "chrome" => std::fs::write(path, export::to_chrome(records).to_pretty())?,
        other => anyhow::bail!("unknown trace format '{other}' (jsonl|csv|chrome)"),
    }
    eprintln!("wrote {} trace records to {} ({format})", records.len(), path.display());
    Ok(())
}

/// `polca trace` — inspect or convert a recorded JSONL trace.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    use polca::obs::export;
    const USAGE: &str = "usage: polca trace [summarize|timeline|export] <trace.jsonl> \
                         [--format jsonl|csv|chrome] [--out FILE]";
    // `polca trace t.jsonl` defaults to summarize.
    let (mode, file) = match (args.positionals.first(), args.positionals.get(1)) {
        (Some(m), Some(f)) => (m.as_str(), f.as_str()),
        (Some(f), None) if !matches!(f.as_str(), "summarize" | "timeline" | "export") => {
            ("summarize", f.as_str())
        }
        _ => anyhow::bail!("{USAGE}"),
    };
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow::anyhow!("cannot read trace '{file}': {e}"))?;
    let records =
        export::parse_jsonl(&text).map_err(|e| anyhow::anyhow!("invalid trace '{file}': {e}"))?;
    match mode {
        "summarize" => println!("{}", export::summarize(&records).trim_end()),
        "timeline" => {
            let tls = export::incident_timeline(&records);
            if tls.is_empty() {
                println!(
                    "no incidents in {} records (no fault or violation windows)",
                    records.len()
                );
            } else {
                print!("{}", export::render_timeline(&tls));
            }
        }
        "export" => {
            let format = args.get_or("format", "chrome");
            let out = match args.get("out") {
                Some(o) => PathBuf::from(o),
                None => {
                    let ext = match format {
                        "chrome" => "trace.json",
                        "csv" => "csv",
                        _ => "out.jsonl",
                    };
                    PathBuf::from(format!("{file}.{ext}"))
                }
            };
            write_trace(&records, &out, format)?;
        }
        other => anyhow::bail!("unknown trace mode '{other}' (summarize|timeline|export)"),
    }
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let Some(target) = args.positionals.first() else {
        println!("usage: polca run <preset|file.toml> [--quick] [options]\npresets:");
        list_presets();
        return Ok(());
    };
    let mut sc = load_scenario(target)?;
    // --quick scales the spec's horizon first; explicit flags (e.g.
    // --weeks) then override whatever the spec or --quick chose.
    if args.flag("quick") {
        sc = sc.quick();
    }
    apply_overrides(&mut sc, args)?;
    run_and_print(&sc, args)
}

fn list_presets() {
    for sc in presets() {
        println!("  {:<20} {}", sc.name, sc.description);
    }
}

fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("list");
    let target = args.positionals.get(1).map(|s| s.as_str());
    match mode {
        "list" => list_presets(),
        "show" => {
            let target = target
                .ok_or_else(|| anyhow::anyhow!("usage: polca scenario show <preset|file.toml>"))?;
            print!("{}", load_scenario(target)?.to_toml_string());
        }
        "validate" => {
            let targets: Vec<String> = if args.flag("all") {
                preset_names().iter().map(|s| s.to_string()).collect()
            } else {
                vec![target
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "usage: polca scenario validate <preset|file.toml> | --all"
                        )
                    })?
                    .to_string()]
            };
            for t in &targets {
                let sc = load_scenario(t)?;
                sc.validate()?;
                // The save path must be faithful: spec -> TOML -> spec
                // reproduces the value exactly.
                let back = Scenario::parse(&sc.to_toml_string())?;
                anyhow::ensure!(back == sc, "scenario '{t}' does not round-trip through TOML");
                println!("{t}: ok ({})", sc.describe());
            }
        }
        "save" => {
            let target = target.ok_or_else(|| {
                anyhow::anyhow!("usage: polca scenario save <preset|file.toml> [--out FILE]")
            })?;
            let sc = load_scenario(target)?;
            let out = PathBuf::from(
                args.get("out")
                    .map(|s| s.to_string())
                    .unwrap_or_else(|| format!("{}.toml", sc.name)),
            );
            sc.save(&out)?;
            println!("wrote {} (edit it, then: polca run {})", out.display(), out.display());
        }
        other => anyhow::bail!("unknown scenario mode '{other}' (list|show|validate|save)"),
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args.positionals.first().map(|s| s.as_str()).unwrap_or("list");
    let depth = if args.flag("full") { Depth::Full } else { Depth::Quick };
    let seed = args.get_u64("seed", 1);
    let out_dir = PathBuf::from(args.get_or("out-dir", "out"));
    match id {
        "list" => {
            for id in all_ids() {
                println!("{id}");
            }
        }
        "all" => {
            for id in all_ids() {
                let fig = run_experiment(id, depth, seed)?;
                fig.print();
                fig.write(&out_dir)?;
            }
            println!("wrote CSVs to {}", out_dir.display());
        }
        id => {
            let fig = run_experiment(id, depth, seed)?;
            fig.print();
            fig.write(&out_dir)?;
            println!("wrote CSVs to {}", out_dir.display());
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    deprecation_note("simulate", "polca run inference-row (or oversubscribed-row)");
    let mut sc = Scenario::builder("simulate")
        .description("legacy `polca simulate` alias")
        .build();
    if let Some(path) = args.get("config") {
        sc.exp = ExperimentConfig::load(Path::new(path))?;
    }
    apply_overrides(&mut sc, args)?;
    run_and_print(&sc, args)
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let base = Scenario::builder("tune")
        .weeks(args.get_f64("weeks", 1.0))
        .seed(args.get_u64("seed", 1))
        .build()
        .sim_config();
    let combos = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)];
    let added = [0.0, 0.10, 0.20, 0.25, 0.30, 0.35, 0.40];
    let exec = polca::exec::ExecConfig::with_parallel(!args.flag("serial"));
    eprintln!(
        "sweeping {} points ({}) ...",
        combos.len() * added.len(),
        if exec.parallel { "parallel" } else { "serial" }
    );
    let outcome = tune_thresholds_exec(&base, &combos, &added, &base.exp.slo, &exec);
    for p in &outcome.points {
        println!(
            "T1-T2 {:.0}-{:.0} +{:>4.1}% | HP p99 {:>6.2}% LP p99 {:>6.2}% | brakes {} | {}",
            p.t1 * 100.0,
            p.t2 * 100.0,
            p.added_frac * 100.0,
            p.hp_p99 * 100.0,
            p.lp_p99 * 100.0,
            p.brakes,
            if p.meets_slo { "ok" } else { "VIOLATED" }
        );
    }
    if let Some((t1, t2, added)) = outcome.best {
        println!(
            "best: T1={:.0}% T2={:.0}% supports +{:.1}% servers within SLOs",
            t1 * 100.0,
            t2 * 100.0,
            added * 100.0
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let weeks = args.get_f64("weeks", 0.5);
    let seed = args.get_u64("seed", 1);
    let target = args.get_f64("target", 0.79);
    let scale = calibrate(target, weeks, seed);
    println!(
        "power_scale = {:.3} pins the base 40-server row peak at {target} \
         (current DEFAULT_POWER_SCALE = {:.3})",
        scale * polca::simulation::DEFAULT_POWER_SCALE,
        polca::simulation::DEFAULT_POWER_SCALE
    );
    Ok(())
}

fn cmd_mixed(args: &Args) -> anyhow::Result<()> {
    use polca::experiments::mixed::{
        contrast_verdict, sweep_table, sweep_training_fractions, SweepConfig,
        TRAINING_HEADROOM_BOUND,
    };

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("sweep");
    match mode {
        "run" => {
            deprecation_note("mixed run", "polca run mixed-row");
            let mut sc = Scenario::builder("mixed")
                .description("legacy `polca mixed run` alias")
                .weeks(0.25)
                .seed(1)
                .training(0.5)
                .build();
            apply_overrides(&mut sc, args)?;
            sc.training.fraction = sc.training.fraction.clamp(0.0, 1.0);
            sc.training.servers_per_job = args.get_usize("servers-per-job", 0);
            sc.training.stagger_s = args.get_f64("stagger", 0.0);
            run_and_print(&sc, args)
        }
        "sweep" => {
            let mut sc = SweepConfig::default();
            if let Some(p) = args.get("policy") {
                sc.policy = polca::util::cli::parse_policy(p)?;
            }
            args.set_f64("weeks", &mut sc.weeks);
            args.set_u64("seed", &mut sc.seed);
            args.set_usize("servers", &mut sc.servers);
            args.set_f64("added", &mut sc.added);
            sc.mixed.servers_per_job = args.get_usize("servers-per-job", 0);
            sc.mixed.job_stagger_s = args.get_f64("stagger", 0.0);
            sc.parallel = !args.flag("serial");
            let step = args.get_usize("step", 25).clamp(1, 100);
            let mut fractions = Vec::new();
            let mut p = 0usize;
            while p < 100 {
                fractions.push(p as f64 / 100.0);
                p += step;
            }
            fractions.push(1.0);
            eprintln!(
                "sweeping {} training fractions under {} for {:.2} weeks ({}) ...",
                fractions.len(),
                sc.policy.name(),
                sc.weeks,
                if sc.parallel { "parallel" } else { "serial" }
            );
            let points = sweep_training_fractions(&fractions, &sc);
            println!("{}", sweep_table(&points).render());
            let v = contrast_verdict(&points);
            println!(
                "pure-training headroom {:.1}% <= §2.4 bound {:.1}%: {}",
                v.train_headroom * 100.0,
                TRAINING_HEADROOM_BOUND * 100.0,
                if v.bound_ok { "ok" } else { "FAIL" }
            );
            println!(
                "pure-training 2s row swing {:.1}% (§2.4 observable, paper ≈37.5%): {}",
                v.train_swing_2s * 100.0,
                if v.swing_ok { "in band" } else { "out of band (capped or de-synchronized)" }
            );
            println!(
                "pure-inference peak {:.1}% / headroom {:.1}% (paper Table 2: 79% mean peak)",
                v.inference_peak * 100.0,
                v.inference_headroom * 100.0
            );
            println!(
                "headroom interpolates monotonically across mixes: {}",
                if v.monotone { "ok" } else { "FAIL" }
            );
            Ok(())
        }
        other => anyhow::bail!("unknown mixed mode '{other}' (run|sweep)"),
    }
}

fn cmd_faults(args: &Args) -> anyhow::Result<()> {
    use polca::faults::{run_matrix, FaultPlan, MatrixConfig};
    use polca::metrics::ResilienceMetrics;
    use polca::simulation::run;
    use polca::util::table::{f, pct, Table};

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("matrix");
    match mode {
        "list" => {
            for name in FaultPlan::scenario_names() {
                println!("{name}");
            }
        }
        "run" => {
            deprecation_note("faults run", "polca run cascade-faults (or cap-ignore-drill)");
            let mut sc = Scenario::builder("faults")
                .description("legacy `polca faults run` alias")
                .servers(16)
                .added(0.30)
                .weeks(0.1)
                .seed(1)
                .faults_scenario(args.get_or("scenario", "cap-ignore"))
                .escalate(120.0)
                .build();
            apply_overrides(&mut sc, args)?;
            run_and_print(&sc, args)?;
        }
        "sweep" => {
            let mut mc = MatrixConfig::default();
            mc.weeks = args.get_f64("weeks", 0.1);
            args.set_u64("seed", &mut mc.seed);
            args.set_usize("servers", &mut mc.servers);
            if let Some(secs) = escalate_arg(args)? {
                mc.escalation_s = Some(secs);
            }
            let scenario = args.get_or("scenario", "feed-loss");
            let policy = args.policy("polca")?;
            let max_added = args.get_usize("max-added", 40);
            let step = args.get_usize("step", 10).max(1);
            let exec = polca::exec::ExecConfig::with_parallel(!args.flag("serial"));
            eprintln!(
                "sweeping added servers under '{scenario}' with {} ({}) ...",
                policy.name(),
                if exec.parallel { "parallel" } else { "serial" }
            );
            let mut t = Table::new(
                "Oversubscription under faults",
                &["added", "true peak", "viol s", "overshoot W", "ttc", "brakes", "contained"],
            );
            // Resolve every added level's config up front, then fan the
            // independent runs out through the scenario executor.
            let mut levels = Vec::new();
            let mut added = 0usize;
            while added <= max_added {
                mc.added = added as f64 / 100.0;
                let plan = FaultPlan::scenario(scenario, mc.horizon_s())?;
                levels.push((mc.added, mc.sim_config(Some(plan), policy)));
                added += step;
            }
            let reports =
                polca::exec::run_batch(&levels, &exec, |_, (_, cfg)| run(cfg));
            for ((added_frac, _), report) in levels.iter().zip(&reports) {
                let r = &report.resilience;
                t.row(vec![
                    pct(*added_frac, 0),
                    f(r.true_peak_norm, 3),
                    f(r.violation_s, 1),
                    f(r.peak_overshoot_w, 0),
                    ResilienceMetrics::fmt_ttc(r.worst_time_to_contain_s()),
                    report.brake_events.to_string(),
                    if r.all_contained() { "yes".into() } else { "NO".into() },
                ]);
            }
            println!("{}", t.render());
        }
        "matrix" => {
            let mut mc = MatrixConfig::default();
            // --quick: the CI smoke shape — a small row on a short
            // horizon; explicit flags below still override it.
            if args.flag("quick") {
                mc.weeks = 0.02;
                mc.servers = 12;
            }
            args.set_f64("weeks", &mut mc.weeks);
            args.set_u64("seed", &mut mc.seed);
            args.set_usize("servers", &mut mc.servers);
            args.set_f64("added", &mut mc.added);
            mc.parallel = !args.flag("serial");
            if let Some(secs) = escalate_arg(args)? {
                mc.escalation_s = Some(secs);
            }
            let policy_arg = args.get_or("policy", "all");
            if policy_arg != "all" {
                mc.policies = vec![polca::util::cli::parse_policy(policy_arg)?];
            }
            eprintln!(
                "fault matrix: {} scenarios × {} policies on {} servers +{:.0}%, \
                 {:.2} weeks each ({}) ...",
                mc.scenarios.len(),
                mc.policies.len(),
                mc.servers,
                mc.added * 100.0,
                mc.weeks,
                if mc.parallel { "parallel" } else { "serial" }
            );
            let grid = run_matrix(&mc)?;
            if args.flag("json") {
                println!("{}", grid.to_json().to_pretty());
            } else {
                println!("{}", grid.table().render());
                println!(
                    "no-fault column == clean run: {} | all scenarios containable: {}",
                    if grid.clean_match { "ok" } else { "VIOLATED" },
                    if grid.scenarios_containable() { "ok" } else { "VIOLATED" }
                );
            }
            if let Some(dir) = args.get("out-dir") {
                let out_dir = PathBuf::from(dir);
                std::fs::create_dir_all(&out_dir)?;
                let path = out_dir.join("fault_matrix.csv");
                grid.csv().write_to(&path)?;
                eprintln!("wrote {}", path.display());
            }
        }
        "plan" => {
            deprecation_note("faults plan", "polca run site-derated");
            let mut sc = Scenario::builder("faults-plan")
                .description("legacy `polca faults plan` alias")
                .weeks(0.08)
                .seed(1)
                .site(args.get_usize("clusters", 4))
                .faults_scenario(args.get_or("scenario", "feed-loss"))
                .escalate(120.0)
                .build();
            apply_overrides(&mut sc, args)?;
            run_and_print(&sc, args)?;
        }
        other => anyhow::bail!("unknown faults mode '{other}' (run|sweep|matrix|plan|list)"),
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use polca::fleet::planner::evaluate_added;
    use polca::util::csv::Csv;
    use polca::util::table::{f, pct, Table};

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("plan");
    // `fleet region` is the first-class region surface, not a site
    // alias — dispatch before building the legacy site scenario.
    if mode == "region" {
        return cmd_fleet_region(args);
    }
    // The alias's base scenario: the demo site at the planner defaults.
    let mut sc = Scenario::builder("fleet")
        .description("legacy `polca fleet` alias")
        .weeks(0.08)
        .seed(1)
        .site(args.get_usize("clusters", 4))
        .build();
    apply_overrides(&mut sc, args)?;
    sc.training.fraction = sc.training.fraction.clamp(0.0, 1.0);
    if sc.training.fraction > 0.0 {
        eprintln!(
            "every cluster colocates {:.0}% training servers",
            sc.training.fraction * 100.0
        );
    }
    let site = sc.site_spec().expect("fleet alias always carries a site");
    let pc = sc.planner_config().expect("fleet alias always carries a site");

    let policy_arg = args.get_or("policy", "all");
    let policies: Vec<PolicyKind> = polca::util::cli::parse_policies(policy_arg)?;

    eprintln!(
        "site '{}': {} clusters / {} baseline servers / {:.0} kW substation budget ({})",
        site.name,
        site.clusters.len(),
        site.baseline_servers(),
        site.substation_budget_w / 1e3,
        if pc.parallel { "parallel" } else { "serial" }
    );
    for c in &site.clusters {
        eprintln!(
            "  {:<16} {:<10} {:>3} servers  {:>7.0} kW budget  +{:.0}h phase",
            c.name,
            c.sku.name,
            c.baseline_servers,
            c.budget_w() / 1e3,
            c.phase_offset_s / 3600.0
        );
    }

    match mode {
        "plan" => {
            deprecation_note("fleet plan", "polca run site-headroom");
            let mut t = Table::new(
                "Site capacity plan",
                &["policy", "deployable", "added", "site peak", "headroom", "brakes",
                  "caps/day", "HP p99", "LP p99"],
            );
            // One scenario per policy: the alias enumerates scenario
            // values exactly like the site-headroom experiment does.
            let plans: Vec<_> = policies
                .iter()
                .map(|&p| {
                    let mut s = sc.clone();
                    s.policy_kind = p;
                    match s.run()?.outcome {
                        Outcome::Site(site) => Ok(site.plan),
                        Outcome::Row(_) | Outcome::Region(_) => {
                            unreachable!("site scenario plans a site")
                        }
                    }
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            for p in &plans {
                t.row(vec![
                    p.policy.name().to_string(),
                    if p.feasible { p.deployable_servers.to_string() } else { "—".into() },
                    pct(p.added_pct as f64 / 100.0, 0),
                    pct(p.site_peak_w / p.substation_budget_w, 1),
                    pct(p.headroom_frac, 1),
                    p.brake_events.to_string(),
                    f(p.cap_events_per_day, 1),
                    pct(p.worst_hp_p99, 2),
                    pct(p.worst_lp_p99, 2),
                ]);
            }
            println!("{}", t.render());
            println!(
                "baseline {} servers; deployable = max servers with SLOs held, zero brakes, \
                 and every feed + the substation within budget",
                site.baseline_servers()
            );
        }
        "sweep" => {
            let mut t = Table::new(
                "Site oversubscription sweep",
                &["policy", "added", "site peak", "brakes", "HP p99", "LP p99", "deployable"],
            );
            for &policy in &policies {
                for added in [0u32, 10, 20, 30, 40] {
                    if added > pc.max_added_pct {
                        continue;
                    }
                    let o = evaluate_added(&site, policy, added, &pc);
                    t.row(vec![
                        policy.name().to_string(),
                        pct(added as f64 / 100.0, 0),
                        pct(o.substation_peak_w / o.substation_budget_w, 1),
                        o.total_brakes().to_string(),
                        pct(o.worst_hp_p99(), 2),
                        pct(o.worst_lp_p99(), 2),
                        if o.feasible(&pc.slo) { "yes".into() } else { "no".into() },
                    ]);
                }
            }
            println!("{}", t.render());
        }
        "trace" => {
            let added = args.get_usize("added", 0) as u32;
            // Trace emits one composed trace; default to POLCA rather
            // than silently dropping the rest of a multi-policy set.
            let policy = if policy_arg == "all" { PolicyKind::Polca } else { policies[0] };
            if policy_arg == "all" {
                eprintln!("tracing {} (pass --policy to trace another)", policy.name());
            }
            let o = evaluate_added(&site, policy, added, &pc);
            let mut header: Vec<String> = vec!["t_s".into(), "site_w".into(), "site_norm".into()];
            for c in &o.clusters {
                header.push(format!("{}_w", c.name));
            }
            let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut csv = Csv::new(&refs);
            let base_w = site.baseline_budget_w();
            // Use the simulator's recorded sample times rather than
            // reconstructing them from the period.
            let times = o.clusters.first().map(|c| &c.report.power_series);
            for (j, &w) in o.trace.site_w.iter().enumerate() {
                let t_s = times
                    .and_then(|s| s.get(j).map(|p| p.0))
                    .unwrap_or(j as f64 * o.trace.period_s);
                let mut row = vec![f(t_s, 0), f(w, 1), f(w / base_w, 4)];
                for cw in &o.trace.cluster_w {
                    row.push(f(cw[j], 1));
                }
                csv.row_strs(&row);
            }
            let out_dir = PathBuf::from(args.get_or("out-dir", "out"));
            std::fs::create_dir_all(&out_dir)?;
            let path = out_dir.join(format!("site_trace_{}_{added}pct.csv", policy.name()));
            csv.write_to(&path)?;
            println!(
                "{} at +{added}%: site peak {:.0} kW / budget {:.0} kW ({}), {} brakes, \
                 {} samples -> {}",
                policy.name(),
                o.substation_peak_w / 1e3,
                o.substation_budget_w / 1e3,
                if o.within_power_budget() { "within budget" } else { "OVER BUDGET" },
                o.total_brakes(),
                o.trace.site_w.len(),
                path.display()
            );
        }
        other => anyhow::bail!("unknown fleet mode '{other}' (plan|sweep|trace|region)"),
    }
    Ok(())
}

/// `polca fleet region [plan|trace|validate]` — region-scale planning
/// over the compositional trace algebra (`polca::fleet::region`): the
/// archetype cache simulates each distinct (SKU, level) pair once, so
/// planning cost is independent of the number of sites. `validate`
/// cross-checks the analytic composition against full simulation on
/// sampled sites and exits nonzero when the tolerances are exceeded
/// (the CI gate).
fn cmd_fleet_region(args: &Args) -> anyhow::Result<()> {
    use polca::fleet::region::{
        plan_region_with_cache, region_trace, validate_region, ArchetypeCache, RegionPlanConfig,
        RegionSpec,
    };
    use polca::util::csv::Csv;
    use polca::util::table::{f, pct, Table};

    let sub = args.positionals.get(1).map(|s| s.as_str()).unwrap_or("plan");
    let quick = args.flag("quick");
    let sites = args.get_usize("sites", if quick { 4 } else { 8 });
    let clusters = args.get_usize("clusters", if quick { 2 } else { 3 });
    let grid_frac = args.get_f64("grid-frac", 0.85);
    let region = RegionSpec::demo(sites, clusters, grid_frac);

    let mut pc = RegionPlanConfig::default();
    if quick {
        pc.max_added_pct = 30;
        pc.step_pct = 10;
    }
    if let Some(p) = args.get("policy") {
        pc.policy = polca::util::cli::parse_policy(p)?;
    }
    args.set_f64("weeks", &mut pc.weeks);
    args.set_u64("seed", &mut pc.seed);
    args.set_f64("sample-s", &mut pc.sample_s);
    args.set_u32("max-added", &mut pc.max_added_pct);
    args.set_u32("step", &mut pc.step_pct);
    pc.parallel = !args.flag("serial");

    eprintln!(
        "region '{}': {} sites x {} clusters / {} baseline servers / \
         grid budget {:.2} MW ({})",
        region.name,
        region.sites.len(),
        clusters,
        region.baseline_servers(),
        region.grid_budget_w / 1e6,
        if pc.parallel { "parallel" } else { "serial" }
    );

    let mut cache = ArchetypeCache::new(&pc);
    let plan = plan_region_with_cache(&region, &pc, &mut cache);

    match sub {
        "plan" => {
            let mut t = Table::new(
                "Region capacity plan",
                &["site", "tz", "added", "peak kW", "budget kW", "util"],
            );
            for (i, name) in plan.site_names.iter().enumerate() {
                t.row(vec![
                    name.clone(),
                    format!("{:+.0}h", region.sites[i].tz_offset_s / 3600.0),
                    pct(plan.added_pct[i] as f64 / 100.0, 0),
                    f(plan.site_peak_w[i] / 1e3, 0),
                    f(plan.site_budget_w[i] / 1e3, 0),
                    pct(plan.site_peak_w[i] / plan.site_budget_w[i], 1),
                ]);
            }
            println!("{}", t.render());
            println!(
                "{} deployable servers of {} baseline (+{:.1}%); grid peak {:.2} MW / \
                 budget {:.2} MW; uniform +{}%; {} archetype sims for {} closed-form evals{}",
                plan.deployed_servers,
                plan.baseline_servers,
                plan.headroom_pct(),
                plan.grid_peak_w / 1e6,
                plan.grid_budget_w / 1e6,
                plan.uniform_added_pct,
                plan.archetype_sims,
                plan.candidate_evals,
                if plan.feasible { "" } else { " — INFEASIBLE at zero added servers" }
            );
        }
        "trace" => {
            let rt = region_trace(&region, &plan.added_pct, &mut cache);
            let mut header: Vec<String> =
                vec!["t_s".into(), "region_w".into(), "region_norm".into()];
            for name in &plan.site_names {
                header.push(format!("{name}_w"));
            }
            let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut csv = Csv::new(&refs);
            for (j, &w) in rt.region_w.samples.iter().enumerate() {
                let mut row =
                    vec![f(j as f64 * rt.period_s, 0), f(w, 1), f(w / region.grid_budget_w, 4)];
                for site in &rt.site_w {
                    row.push(f(site.samples[j], 1));
                }
                csv.row_strs(&row);
            }
            let out_dir = PathBuf::from(args.get_or("out-dir", "out"));
            std::fs::create_dir_all(&out_dir)?;
            let path = out_dir.join(format!("region_trace_{}site.csv", region.sites.len()));
            csv.write_to(&path)?;
            println!(
                "region peak {:.2} MW / budget {:.2} MW at the plan's levels, \
                 {} samples -> {}",
                rt.region_w.peak_w() / 1e6,
                region.grid_budget_w / 1e6,
                rt.region_w.len(),
                path.display()
            );
        }
        "validate" => {
            let n = args.get_usize("validate-sites", if quick { 2 } else { 3 });
            eprintln!("cross-validating {} sampled sites against full simulation ...", n);
            let v = validate_region(&region, &plan, &pc, n);
            let mut t = Table::new(
                "Analytic composition vs full simulation",
                &["site", "added", "mean err", "peak err"],
            );
            for s in &v.sites {
                t.row(vec![
                    s.site.clone(),
                    pct(s.added_pct as f64 / 100.0, 0),
                    pct(s.mean_rel_err, 2),
                    pct(s.peak_rel_err, 2),
                ]);
            }
            println!("{}", t.render());
            println!(
                "worst mean err {:.2}% (tolerance {:.0}%), worst peak err {:.2}% \
                 (tolerance {:.0}%) over {:.2} simulated weeks",
                v.worst_mean_rel_err * 100.0,
                v.mean_tolerance * 100.0,
                v.worst_peak_rel_err * 100.0,
                v.peak_tolerance * 100.0,
                v.weeks
            );
            if !v.passed() {
                if let Some(w) = v.worst_site() {
                    eprintln!(
                        "worst offender: site '{}' at +{}% — analytic mean {:.1} kW vs \
                         simulated {:.1} kW, analytic peak {:.1} kW vs simulated {:.1} kW",
                        w.site,
                        w.added_pct,
                        w.analytic_mean_w / 1e3,
                        w.simulated_mean_w / 1e3,
                        w.analytic_peak_w / 1e3,
                        w.simulated_peak_w / 1e3
                    );
                }
                anyhow::bail!("analytic composition is out of tolerance");
            }
            println!("ok: analytic composition within tolerance on every sampled site");
        }
        other => anyhow::bail!("unknown fleet region mode '{other}' (plan|trace|validate)"),
    }
    Ok(())
}

/// `polca gateway [bench]` — the live control-plane daemon (and its
/// built-in loopback load generator). Contrast with `polca serve`
/// (one-shot PJRT artifact driver): the gateway is long-running,
/// speaks HTTP, and executes *scenarios*, not compiled models.
fn cmd_gateway(args: &Args) -> anyhow::Result<()> {
    use polca::gateway::{bench, Gateway, GatewayConfig};
    match args.positionals.first().map(|s| s.as_str()) {
        Some("bench") => {
            let defaults = bench::BenchOpts::default();
            let opts = bench::BenchOpts {
                quick: args.flag("quick"),
                clients: args.get_usize("clients", defaults.clients),
                per_client: args.get_usize("per-client", defaults.per_client),
                sse_subs: args.get_usize("sse-subs", defaults.sse_subs),
                http_workers: args.get_usize("workers", defaults.http_workers),
                run_workers: args.get_usize("run-workers", defaults.run_workers),
                out: args.get_or("out", &defaults.out).to_string(),
            };
            let doc = bench::run(&opts)?;
            let f = |k: &str| doc.get(k).and_then(polca::util::json::Json::as_f64).unwrap_or(0.0);
            println!(
                "gateway bench: {} submissions from {} clients in {:.2}s \
                 ({:.0} req/s over {} requests)",
                f("submissions"),
                f("clients"),
                f("wall_s"),
                f("req_per_s"),
                f("requests"),
            );
            println!(
                "submit latency p50 {:.2}ms p99 {:.2}ms; status p50 {:.2}ms p99 {:.2}ms; \
                 {} SSE records; {} dropped runs",
                f("submit_p50_ms"),
                f("submit_p99_ms"),
                f("status_p50_ms"),
                f("status_p99_ms"),
                f("sse_records"),
                f("dropped_runs"),
            );
            println!("wrote {}", opts.out);
            Ok(())
        }
        None | Some("serve") => {
            let defaults = GatewayConfig::default();
            let cfg = GatewayConfig {
                addr: args.get_or("addr", &defaults.addr).to_string(),
                http_workers: args.get_usize("workers", defaults.http_workers),
                run_workers: args.get_usize("run-workers", defaults.run_workers),
                time_warp: args.get_f64("time-warp", defaults.time_warp),
                queue_depth: args.get_usize("queue", defaults.queue_depth),
                accept_queue: args.get_usize("accept-queue", defaults.accept_queue),
            };
            let gw = Gateway::start(&cfg)?;
            eprintln!(
                "gateway up on http://{} — stop with: \
                 curl -X POST http://{}/shutdown",
                gw.local_addr(),
                gw.local_addr()
            );
            gw.join();
            eprintln!("gateway stopped (all workers joined)");
            Ok(())
        }
        Some(other) => anyhow::bail!(
            "unknown gateway mode '{other}' (expected no mode, 'serve', or 'bench')"
        ),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use polca::cluster::hierarchy::Priority;
    use polca::coordinator::{run_policy_over_row, timeline_power, Coordinator, Request};
    use polca::runtime::Engine;

    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n_requests = args.get_usize("requests", 16);
    let oversub = args.get_f64("oversub", 1.3);
    eprintln!("loading artifacts from {} ...", dir.display());
    let engine = Engine::load(&dir)?;
    let max_new = 12.min(engine.manifest.model.max_seq / 4);
    let mut coord = Coordinator::new(engine)?;
    let mut rng = polca::util::rng::Rng::new(args.get_u64("seed", 1));
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let len = rng.range_usize(4, 14);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        let pri = if rng.bool(0.5) { Priority::High } else { Priority::Low };
        coord.submit(Request { id: i as u64, prompt, max_new_tokens: max_new, priority: pri });
    }
    let done = coord.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = done.iter().map(|d| d.tokens.len()).sum();
    println!(
        "served {} requests / {} tokens in {:.2}s ({:.1} tok/s, {:.1} req/s)",
        done.len(),
        tokens,
        wall,
        tokens as f64 / wall,
        done.len() as f64 / wall
    );
    let mut lat = polca::util::stats::Percentiles::new();
    for d in &done {
        lat.push(d.queue_s + d.prefill_s + d.decode_s);
    }
    println!("request latency p50 {:.3}s p99 {:.3}s", lat.p50(), lat.p99());

    // POLCA in the loop over a replicated row of this node.
    let model = polca::power::server::ServerPowerModel::default();
    let trace = timeline_power(&coord.timeline, &model, 0.5, 50.0);
    let report = run_policy_over_row(
        &trace,
        40,
        oversub,
        &polca::config::PolicyConfig::default(),
        &model.calib,
        0.22,
        0.92,
    );
    let caps = report.cap_timeline.iter().filter(|(_, lp, _, _)| lp.is_some()).count();
    println!(
        "POLCA over a 40-replica row at {oversub:.2}x oversubscription: \
         {} / {} intervals LP-capped, {} brake events, LP/HP modeled stretch {:.3}/{:.3}",
        caps,
        report.cap_timeline.len(),
        report.brake_events,
        report.lp_modeled_stretch,
        report.hp_modeled_stretch
    );
    Ok(())
}
