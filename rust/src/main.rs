//! `polca` — CLI for the POLCA reproduction.
//!
//! Subcommands:
//!   figure <id|all|list> [--out-dir out] [--full] [--seed N]
//!       Regenerate paper tables/figures (CSV + stdout).
//!   simulate [--policy polca|1t-lp|1t-all|nocap] [--servers N]
//!            [--added FRAC] [--weeks W] [--seed N] [--config FILE]
//!       One cluster simulation with an impact report.
//!   tune [--weeks W] [--seed N]
//!       Week-one threshold search (§6.2).
//!   calibrate [--weeks W] [--seed N]
//!       Fit the power-scale factor to the Table-2 peak.
//!   serve [--artifacts DIR] [--requests N] [--oversub F]
//!       Mini end-to-end serving run (real PJRT model, POLCA in loop).
//!   fleet [plan|sweep|trace] [--clusters N] [--policy polca|all]
//!         [--added PCT] [--training FRAC] [--weeks W] [--seed N]
//!         [--serial] [--out-dir out]
//!       Site-level planning over a heterogeneous multi-cluster site.
//!   mixed [run|sweep] [--training FRAC] [--policy polca|nocap|...]
//!         [--servers N] [--added FRAC] [--weeks W] [--seed N]
//!         [--servers-per-job N] [--stagger S] [--step PCT]
//!       Mixed-workload rows: colocate synchronized training jobs with
//!       inference and reproduce the §2.4 headroom contrast.
//!   faults [run|sweep|matrix|plan|list] [--scenario NAME]
//!          [--policy polca|...|all] [--servers N] [--added FRAC]
//!          [--weeks W] [--seed N] [--escalate S] [--clusters N]
//!          [--out-dir out]
//!       Fault injection: run one scenario, sweep oversubscription
//!       under it, grid scenario × policy containment, or derate the
//!       site plan for a fault timeline (docs/RELIABILITY.md).

use std::path::{Path, PathBuf};

use polca::config::ExperimentConfig;
use polca::experiments::{all_ids, run_experiment, Depth};
use polca::policy::engine::PolicyKind;
use polca::policy::tuner::tune_thresholds;
use polca::simulation::{calibrate, run_with_impact, SimConfig};
use polca::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("figure") => cmd_figure(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("tune") => cmd_tune(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve") => cmd_serve(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("mixed") => cmd_mixed(&args),
        Some("faults") => cmd_faults(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_help();
            std::process::exit(2);
        }
        None => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "polca — Power Oversubscription in LLM Cloud Providers (reproduction)\n\n\
         usage: polca <figure|simulate|tune|calibrate|serve|fleet|mixed|faults> [options]\n\
         try:   polca figure list\n       \
                polca figure fig13 --out-dir out\n       \
                polca simulate --policy polca --added 0.30 --weeks 1\n       \
                polca fleet --clusters 4 --policy polca\n       \
                polca mixed sweep --weeks 0.3\n       \
                polca mixed run --training 0.5 --policy polca\n       \
                polca faults matrix --weeks 0.1\n       \
                polca faults run --scenario cap-ignore --policy polca\n       \
                polca serve --requests 16"
    );
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args.positionals.first().map(|s| s.as_str()).unwrap_or("list");
    let depth = if args.flag("full") { Depth::Full } else { Depth::Quick };
    let seed = args.get_u64("seed", 1);
    let out_dir = PathBuf::from(args.get_or("out-dir", "out"));
    match id {
        "list" => {
            for id in all_ids() {
                println!("{id}");
            }
        }
        "all" => {
            for id in all_ids() {
                let fig = run_experiment(id, depth, seed)?;
                fig.print();
                fig.write(&out_dir)?;
            }
            println!("wrote CSVs to {}", out_dir.display());
        }
        id => {
            let fig = run_experiment(id, depth, seed)?;
            fig.print();
            fig.write(&out_dir)?;
            println!("wrote CSVs to {}", out_dir.display());
        }
    }
    Ok(())
}

fn parse_policy(s: &str) -> anyhow::Result<PolicyKind> {
    Ok(match s {
        "polca" => PolicyKind::Polca,
        "1t-lp" => PolicyKind::OneThreshLowPri,
        "1t-all" => PolicyKind::OneThreshAll,
        "nocap" => PolicyKind::NoCap,
        other => anyhow::bail!("unknown policy '{other}' (polca|1t-lp|1t-all|nocap)"),
    })
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let mut cfg = SimConfig::default();
    if let Some(path) = args.get("config") {
        cfg.exp = ExperimentConfig::load(Path::new(path))?;
    }
    cfg.policy_kind = parse_policy(args.get_or("policy", "polca"))?;
    cfg.weeks = args.get_f64("weeks", 1.0);
    cfg.exp.seed = args.get_u64("seed", cfg.exp.seed);
    let baseline_servers = args.get_usize("servers", cfg.exp.row.num_servers);
    cfg.exp.row.num_servers = baseline_servers;
    let added = args.get_f64("added", 0.0);
    cfg.deployed_servers = (baseline_servers as f64 * (1.0 + added)).round() as usize;
    cfg.workload_power_mult = args.get_f64("power-mult", 1.0);

    eprintln!(
        "simulating {} for {:.2} weeks: {} servers deployed on a {}-server budget (+{:.0}%)",
        cfg.policy_kind.name(),
        cfg.weeks,
        cfg.deployed_servers,
        baseline_servers,
        added * 100.0
    );
    let t = std::time::Instant::now();
    let (mut report, impact) = run_with_impact(&cfg);
    let wall = t.elapsed().as_secs_f64();
    println!("{}", report.summary());
    println!(
        "impact vs uncapped: HP p50/p99 = {:.2}%/{:.2}%  LP p50/p99 = {:.2}%/{:.2}%  thrpt HP/LP = {:.3}/{:.3}",
        impact.hp_p50 * 100.0,
        impact.hp_p99 * 100.0,
        impact.lp_p50 * 100.0,
        impact.lp_p99 * 100.0,
        impact.hp_throughput,
        impact.lp_throughput
    );
    let v = impact.slo_violations(&cfg.exp.slo);
    if v.is_empty() {
        println!("SLO: OK (Table 5)");
    } else {
        println!("SLO: VIOLATED — {}", v.join("; "));
    }
    println!(
        "{} events in {:.1}s wall ({:.2}M events/s)",
        report.events,
        wall,
        report.events as f64 / wall / 1e6
    );
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let mut base = SimConfig::default();
    base.weeks = args.get_f64("weeks", 1.0);
    base.exp.seed = args.get_u64("seed", 1);
    let combos = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)];
    let added = [0.0, 0.10, 0.20, 0.25, 0.30, 0.35, 0.40];
    eprintln!("sweeping {} points ...", combos.len() * added.len());
    let outcome = tune_thresholds(&base, &combos, &added, &base.exp.slo);
    for p in &outcome.points {
        println!(
            "T1-T2 {:.0}-{:.0} +{:>4.1}% | HP p99 {:>6.2}% LP p99 {:>6.2}% | brakes {} | {}",
            p.t1 * 100.0,
            p.t2 * 100.0,
            p.added_frac * 100.0,
            p.hp_p99 * 100.0,
            p.lp_p99 * 100.0,
            p.brakes,
            if p.meets_slo { "ok" } else { "VIOLATED" }
        );
    }
    if let Some((t1, t2, added)) = outcome.best {
        println!(
            "best: T1={:.0}% T2={:.0}% supports +{:.1}% servers within SLOs",
            t1 * 100.0,
            t2 * 100.0,
            added * 100.0
        );
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let weeks = args.get_f64("weeks", 0.5);
    let seed = args.get_u64("seed", 1);
    let target = args.get_f64("target", 0.79);
    let scale = calibrate(target, weeks, seed);
    println!(
        "power_scale = {:.3} pins the base 40-server row peak at {target} \
         (current DEFAULT_POWER_SCALE = {:.3})",
        scale * polca::simulation::DEFAULT_POWER_SCALE,
        polca::simulation::DEFAULT_POWER_SCALE
    );
    Ok(())
}

fn cmd_mixed(args: &Args) -> anyhow::Result<()> {
    use polca::experiments::mixed::{
        contrast_verdict, sweep_table, sweep_training_fractions, SweepConfig,
        TRAINING_HEADROOM_BOUND,
    };

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("sweep");
    match mode {
        "run" => {
            let mut sc = SweepConfig::default();
            sc.policy = parse_policy(args.get_or("policy", "polca"))?;
            sc.weeks = args.get_f64("weeks", 0.25);
            sc.seed = args.get_u64("seed", sc.seed);
            sc.servers = args.get_usize("servers", sc.servers);
            sc.added = args.get_f64("added", 0.0);
            sc.mixed.servers_per_job = args.get_usize("servers-per-job", 0);
            sc.mixed.job_stagger_s = args.get_f64("stagger", 0.0);
            let frac = args.get_f64("training", 0.5).clamp(0.0, 1.0);
            let cfg = sc.sim_config(frac);
            eprintln!(
                "mixed row: {} with {:.0}% training, {} servers deployed on a {}-server \
                 budget (+{:.0}%), {:.2} weeks",
                cfg.policy_kind.name(),
                frac * 100.0,
                cfg.deployed_servers,
                sc.servers,
                sc.added * 100.0,
                cfg.weeks
            );
            let (mut report, impact) = run_with_impact(&cfg);
            println!("{}", report.summary());
            println!(
                "inference impact vs uncapped: HP p50/p99 = {:.2}%/{:.2}%  \
                 LP p50/p99 = {:.2}%/{:.2}%",
                impact.hp_p50 * 100.0,
                impact.hp_p99 * 100.0,
                impact.lp_p50 * 100.0,
                impact.lp_p99 * 100.0
            );
            println!(
                "training: {} iterations, mean {:.3}s vs nominal {:.3}s (inflation {:.1}%)",
                report.train.iters,
                report.train.mean_iter_s(),
                report.train.nominal_iter_s,
                report.train.inflation() * 100.0
            );
            let v = impact.slo_violations(&cfg.exp.slo);
            if v.is_empty() {
                println!("SLO: OK (Table 5; training pays in iteration time, not SLOs)");
            } else {
                println!("SLO: VIOLATED — {}", v.join("; "));
            }
        }
        "sweep" => {
            let mut sc = SweepConfig::default();
            sc.policy = parse_policy(args.get_or("policy", "nocap"))?;
            sc.weeks = args.get_f64("weeks", sc.weeks);
            sc.seed = args.get_u64("seed", sc.seed);
            sc.servers = args.get_usize("servers", sc.servers);
            sc.added = args.get_f64("added", sc.added);
            sc.mixed.servers_per_job = args.get_usize("servers-per-job", 0);
            sc.mixed.job_stagger_s = args.get_f64("stagger", 0.0);
            let step = args.get_usize("step", 25).clamp(1, 100);
            let mut fractions = Vec::new();
            let mut p = 0usize;
            while p < 100 {
                fractions.push(p as f64 / 100.0);
                p += step;
            }
            fractions.push(1.0);
            eprintln!(
                "sweeping {} training fractions under {} for {:.2} weeks ...",
                fractions.len(),
                sc.policy.name(),
                sc.weeks
            );
            let points = sweep_training_fractions(&fractions, &sc);
            println!("{}", sweep_table(&points).render());
            let v = contrast_verdict(&points);
            println!(
                "pure-training headroom {:.1}% <= §2.4 bound {:.1}%: {}",
                v.train_headroom * 100.0,
                TRAINING_HEADROOM_BOUND * 100.0,
                if v.bound_ok { "ok" } else { "FAIL" }
            );
            println!(
                "pure-training 2s row swing {:.1}% (§2.4 observable, paper ≈37.5%): {}",
                v.train_swing_2s * 100.0,
                if v.swing_ok { "in band" } else { "out of band (capped or de-synchronized)" }
            );
            println!(
                "pure-inference peak {:.1}% / headroom {:.1}% (paper Table 2: 79% mean peak)",
                v.inference_peak * 100.0,
                v.inference_headroom * 100.0
            );
            println!(
                "headroom interpolates monotonically across mixes: {}",
                if v.monotone { "ok" } else { "FAIL" }
            );
        }
        other => anyhow::bail!("unknown mixed mode '{other}' (run|sweep)"),
    }
    Ok(())
}

fn cmd_faults(args: &Args) -> anyhow::Result<()> {
    use polca::faults::{run_matrix, ContainmentSlo, FaultPlan, MatrixConfig};
    use polca::fleet::planner::{plan_site_under_faults, PlannerConfig};
    use polca::fleet::site::SiteSpec;
    use polca::metrics::ResilienceMetrics;
    use polca::simulation::run;
    use polca::util::table::{f, pct, Table};

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("matrix");
    let escalation = args.get("escalate").map(|s| s.parse::<f64>().unwrap_or(120.0));
    let escalation = if args.flag("escalate") { Some(120.0) } else { escalation };
    match mode {
        "list" => {
            for name in FaultPlan::scenario_names() {
                println!("{name}");
            }
        }
        "run" => {
            let mut mc = MatrixConfig::default();
            mc.weeks = args.get_f64("weeks", 0.1);
            mc.seed = args.get_u64("seed", mc.seed);
            mc.servers = args.get_usize("servers", mc.servers);
            mc.added = args.get_f64("added", mc.added);
            mc.escalation_s = escalation.or(mc.escalation_s);
            let scenario = args.get_or("scenario", "cap-ignore");
            let policy = parse_policy(args.get_or("policy", "polca"))?;
            let plan = FaultPlan::scenario(scenario, mc.horizon_s())?;
            eprintln!(
                "injecting '{scenario}' ({} episodes) into {} at {} servers +{:.0}% \
                 for {:.2} weeks",
                plan.len(),
                policy.name(),
                mc.servers,
                mc.added * 100.0,
                mc.weeks
            );
            let mut report = run(&mc.sim_config(Some(plan), policy));
            println!("{}", report.summary());
            for inc in &report.resilience.incidents {
                println!(
                    "incident {:<16} [{:>7.0}s..{:>7.0}s]  time-to-contain {}",
                    inc.label,
                    inc.start_s,
                    inc.end_s,
                    ResilienceMetrics::fmt_ttc(inc.time_to_contain_s)
                );
            }
            let r = &report.resilience;
            println!(
                "containment: {} (violation {:.1}s, peak overshoot {:.0} W, \
                 true peak {:.3}, reissued {})",
                if r.all_contained() { "OK" } else { "FAILED" },
                r.violation_s,
                r.peak_overshoot_w,
                r.true_peak_norm,
                r.reissued_commands
            );
        }
        "sweep" => {
            let mut mc = MatrixConfig::default();
            mc.weeks = args.get_f64("weeks", 0.1);
            mc.seed = args.get_u64("seed", mc.seed);
            mc.servers = args.get_usize("servers", mc.servers);
            mc.escalation_s = escalation.or(mc.escalation_s);
            let scenario = args.get_or("scenario", "feed-loss");
            let policy = parse_policy(args.get_or("policy", "polca"))?;
            let max_added = args.get_usize("max-added", 40);
            let step = args.get_usize("step", 10).max(1);
            eprintln!(
                "sweeping added servers under '{scenario}' with {} ...",
                policy.name()
            );
            let mut t = Table::new(
                "Oversubscription under faults",
                &["added", "true peak", "viol s", "overshoot W", "ttc", "brakes", "contained"],
            );
            let mut added = 0usize;
            while added <= max_added {
                mc.added = added as f64 / 100.0;
                let plan = FaultPlan::scenario(scenario, mc.horizon_s())?;
                let report = run(&mc.sim_config(Some(plan), policy));
                let r = &report.resilience;
                t.row(vec![
                    pct(mc.added, 0),
                    f(r.true_peak_norm, 3),
                    f(r.violation_s, 1),
                    f(r.peak_overshoot_w, 0),
                    ResilienceMetrics::fmt_ttc(r.worst_time_to_contain_s()),
                    report.brake_events.to_string(),
                    if r.all_contained() { "yes".into() } else { "NO".into() },
                ]);
                added += step;
            }
            println!("{}", t.render());
        }
        "matrix" => {
            let mut mc = MatrixConfig::default();
            mc.weeks = args.get_f64("weeks", mc.weeks);
            mc.seed = args.get_u64("seed", mc.seed);
            mc.servers = args.get_usize("servers", mc.servers);
            mc.added = args.get_f64("added", mc.added);
            mc.escalation_s = escalation.or(mc.escalation_s);
            let policy_arg = args.get_or("policy", "all");
            if policy_arg != "all" {
                mc.policies = vec![parse_policy(policy_arg)?];
            }
            eprintln!(
                "fault matrix: {} scenarios × {} policies on {} servers +{:.0}%, \
                 {:.2} weeks each ...",
                mc.scenarios.len(),
                mc.policies.len(),
                mc.servers,
                mc.added * 100.0,
                mc.weeks
            );
            let grid = run_matrix(&mc)?;
            println!("{}", grid.table().render());
            println!(
                "no-fault column == clean run: {} | all scenarios containable: {}",
                if grid.clean_match { "ok" } else { "VIOLATED" },
                if grid.scenarios_containable() { "ok" } else { "VIOLATED" }
            );
            if let Some(dir) = args.get("out-dir") {
                let out_dir = PathBuf::from(dir);
                std::fs::create_dir_all(&out_dir)?;
                let path = out_dir.join("fault_matrix.csv");
                grid.csv().write_to(&path)?;
                println!("wrote {}", path.display());
            }
        }
        "plan" => {
            let n_clusters = args.get_usize("clusters", 4);
            let scenario = args.get_or("scenario", "feed-loss");
            let policy = parse_policy(args.get_or("policy", "polca"))?;
            let site = SiteSpec::demo(n_clusters);
            let mut pc = PlannerConfig::default();
            pc.weeks = args.get_f64("weeks", pc.weeks);
            pc.seed = args.get_u64("seed", pc.seed);
            pc.parallel = !args.flag("serial");
            pc.max_added_pct = args.get_usize("max-added", pc.max_added_pct as usize) as u32;
            pc.step_pct = args.get_usize("step", pc.step_pct as usize) as u32;
            pc.brake_escalation_s = escalation.or(Some(120.0));
            let horizon_s = pc.weeks * 7.0 * 86_400.0;
            let plan = FaultPlan::scenario(scenario, horizon_s)?;
            let cslo = ContainmentSlo::default();
            eprintln!(
                "derating site '{}' for '{scenario}' under {} ...",
                site.name,
                policy.name()
            );
            let fp = plan_site_under_faults(&site, policy, &pc, &plan, &cslo);
            println!(
                "clean plan:   {} servers (+{}%)",
                fp.clean.deployable_servers, fp.clean.added_pct
            );
            println!(
                "under faults: {} servers (+{}%) — derated by {} servers{}",
                fp.derated_servers,
                fp.derated_added_pct,
                fp.clean.deployable_servers.saturating_sub(fp.derated_servers),
                if fp.feasible { "" } else { " (NOT deployable even at baseline)" }
            );
            println!(
                "worst case at the derated point: violation {:.1}s, ttc {}, overshoot {:.1}%",
                fp.worst_violation_s,
                ResilienceMetrics::fmt_ttc(fp.worst_time_to_contain_s),
                fp.worst_overshoot_frac * 100.0
            );
        }
        other => anyhow::bail!("unknown faults mode '{other}' (run|sweep|matrix|plan|list)"),
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    use polca::fleet::planner::{evaluate_added, plan_site, PlannerConfig};
    use polca::fleet::site::SiteSpec;
    use polca::util::csv::Csv;
    use polca::util::table::{f, pct, Table};

    let mode = args.positionals.first().map(|s| s.as_str()).unwrap_or("plan");
    let n_clusters = args.get_usize("clusters", 4);
    let training = args.get_f64("training", 0.0).clamp(0.0, 1.0);
    let site = if training > 0.0 {
        SiteSpec::demo(n_clusters).with_training(training)
    } else {
        SiteSpec::demo(n_clusters)
    };
    if training > 0.0 {
        eprintln!("every cluster colocates {:.0}% training servers", training * 100.0);
    }
    let mut pc = PlannerConfig::default();
    pc.weeks = args.get_f64("weeks", pc.weeks);
    pc.seed = args.get_u64("seed", pc.seed);
    pc.parallel = !args.flag("serial");
    pc.max_added_pct = args.get_usize("max-added", pc.max_added_pct as usize) as u32;
    pc.step_pct = args.get_usize("step", pc.step_pct as usize) as u32;

    let policy_arg = args.get_or("policy", "all");
    let policies: Vec<PolicyKind> = if policy_arg == "all" {
        PolicyKind::all().to_vec()
    } else {
        vec![parse_policy(policy_arg)?]
    };

    eprintln!(
        "site '{}': {} clusters / {} baseline servers / {:.0} kW substation budget ({})",
        site.name,
        site.clusters.len(),
        site.baseline_servers(),
        site.substation_budget_w / 1e3,
        if pc.parallel { "parallel" } else { "serial" }
    );
    for c in &site.clusters {
        eprintln!(
            "  {:<16} {:<10} {:>3} servers  {:>7.0} kW budget  +{:.0}h phase",
            c.name,
            c.sku.name,
            c.baseline_servers,
            c.budget_w() / 1e3,
            c.phase_offset_s / 3600.0
        );
    }

    match mode {
        "plan" => {
            let mut t = Table::new(
                "Site capacity plan",
                &["policy", "deployable", "added", "site peak", "headroom", "brakes",
                  "caps/day", "HP p99", "LP p99"],
            );
            let plans: Vec<_> = policies.iter().map(|&p| plan_site(&site, p, &pc)).collect();
            for p in &plans {
                t.row(vec![
                    p.policy.name().to_string(),
                    if p.feasible { p.deployable_servers.to_string() } else { "—".into() },
                    pct(p.added_pct as f64 / 100.0, 0),
                    pct(p.site_peak_w / p.substation_budget_w, 1),
                    pct(p.headroom_frac, 1),
                    p.brake_events.to_string(),
                    f(p.cap_events_per_day, 1),
                    pct(p.worst_hp_p99, 2),
                    pct(p.worst_lp_p99, 2),
                ]);
            }
            println!("{}", t.render());
            println!(
                "baseline {} servers; deployable = max servers with SLOs held, zero brakes, \
                 and every feed + the substation within budget",
                site.baseline_servers()
            );
        }
        "sweep" => {
            let mut t = Table::new(
                "Site oversubscription sweep",
                &["policy", "added", "site peak", "brakes", "HP p99", "LP p99", "deployable"],
            );
            for &policy in &policies {
                for added in [0u32, 10, 20, 30, 40] {
                    if added > pc.max_added_pct {
                        continue;
                    }
                    let o = evaluate_added(&site, policy, added, &pc);
                    t.row(vec![
                        policy.name().to_string(),
                        pct(added as f64 / 100.0, 0),
                        pct(o.substation_peak_w / o.substation_budget_w, 1),
                        o.total_brakes().to_string(),
                        pct(o.worst_hp_p99(), 2),
                        pct(o.worst_lp_p99(), 2),
                        if o.feasible(&pc.slo) { "yes".into() } else { "no".into() },
                    ]);
                }
            }
            println!("{}", t.render());
        }
        "trace" => {
            let added = args.get_usize("added", 0) as u32;
            // Trace emits one composed trace; default to POLCA rather
            // than silently dropping the rest of a multi-policy set.
            let policy = if policy_arg == "all" { PolicyKind::Polca } else { policies[0] };
            if policy_arg == "all" {
                eprintln!("tracing {} (pass --policy to trace another)", policy.name());
            }
            let o = evaluate_added(&site, policy, added, &pc);
            let mut header: Vec<String> = vec!["t_s".into(), "site_w".into(), "site_norm".into()];
            for c in &o.clusters {
                header.push(format!("{}_w", c.name));
            }
            let refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut csv = Csv::new(&refs);
            let base_w = site.baseline_budget_w();
            // Use the simulator's recorded sample times rather than
            // reconstructing them from the period.
            let times = o.clusters.first().map(|c| &c.report.power_series);
            for (j, &w) in o.trace.site_w.iter().enumerate() {
                let t_s = times
                    .and_then(|s| s.get(j).map(|p| p.0))
                    .unwrap_or(j as f64 * o.trace.period_s);
                let mut row = vec![f(t_s, 0), f(w, 1), f(w / base_w, 4)];
                for cw in &o.trace.cluster_w {
                    row.push(f(cw[j], 1));
                }
                csv.row_strs(&row);
            }
            let out_dir = PathBuf::from(args.get_or("out-dir", "out"));
            std::fs::create_dir_all(&out_dir)?;
            let path = out_dir.join(format!("site_trace_{}_{added}pct.csv", policy.name()));
            csv.write_to(&path)?;
            println!(
                "{} at +{added}%: site peak {:.0} kW / budget {:.0} kW ({}), {} brakes, \
                 {} samples -> {}",
                policy.name(),
                o.substation_peak_w / 1e3,
                o.substation_budget_w / 1e3,
                if o.within_power_budget() { "within budget" } else { "OVER BUDGET" },
                o.total_brakes(),
                o.trace.site_w.len(),
                path.display()
            );
        }
        other => anyhow::bail!("unknown fleet mode '{other}' (plan|sweep|trace)"),
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use polca::cluster::hierarchy::Priority;
    use polca::coordinator::{run_policy_over_row, timeline_power, Coordinator, Request};
    use polca::runtime::Engine;

    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n_requests = args.get_usize("requests", 16);
    let oversub = args.get_f64("oversub", 1.3);
    eprintln!("loading artifacts from {} ...", dir.display());
    let engine = Engine::load(&dir)?;
    let max_new = 12.min(engine.manifest.model.max_seq / 4);
    let mut coord = Coordinator::new(engine)?;
    let mut rng = polca::util::rng::Rng::new(args.get_u64("seed", 1));
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let len = rng.range_usize(4, 14);
        let prompt: Vec<i32> = (0..len).map(|_| rng.below(512) as i32).collect();
        let pri = if rng.bool(0.5) { Priority::High } else { Priority::Low };
        coord.submit(Request { id: i as u64, prompt, max_new_tokens: max_new, priority: pri });
    }
    let done = coord.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = done.iter().map(|d| d.tokens.len()).sum();
    println!(
        "served {} requests / {} tokens in {:.2}s ({:.1} tok/s, {:.1} req/s)",
        done.len(),
        tokens,
        wall,
        tokens as f64 / wall,
        done.len() as f64 / wall
    );
    let mut lat = polca::util::stats::Percentiles::new();
    for d in &done {
        lat.push(d.queue_s + d.prefill_s + d.decode_s);
    }
    println!("request latency p50 {:.3}s p99 {:.3}s", lat.p50(), lat.p99());

    // POLCA in the loop over a replicated row of this node.
    let model = polca::power::server::ServerPowerModel::default();
    let trace = timeline_power(&coord.timeline, &model, 0.5, 50.0);
    let report = run_policy_over_row(
        &trace,
        40,
        oversub,
        &polca::config::PolicyConfig::default(),
        &model.calib,
        0.22,
        0.92,
    );
    let caps = report.cap_timeline.iter().filter(|(_, lp, _, _)| lp.is_some()).count();
    println!(
        "POLCA over a 40-replica row at {oversub:.2}x oversubscription: \
         {} / {} intervals LP-capped, {} brake events, LP/HP modeled stretch {:.3}/{:.3}",
        caps,
        report.cap_timeline.len(),
        report.brake_events,
        report.lp_modeled_stretch,
        report.hp_modeled_stretch
    );
    Ok(())
}
