//! `polca gateway bench` — the built-in loopback load generator.
//!
//! Boots an in-process gateway on an ephemeral port, hammers it with
//! concurrent scenario submissions over keep-alive connections plus
//! SSE event-stream subscribers, waits for every run to complete, and
//! records sustained request throughput and p50/p99 request latency
//! into `BENCH_gateway.json`. The harness is the acceptance check for
//! the daemon's concurrency story: every submission must finish with a
//! report (zero dropped runs) while the event stream stays
//! well-formed.

use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::json::{parse as parse_json, Json};
use crate::util::stats::Percentiles;

use super::http::{request_once, sse_collect, Client};
use super::{Gateway, GatewayConfig};

/// Load-generator knobs (`polca gateway bench` flags).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// CI smoke shape: fewer/shorter runs.
    pub quick: bool,
    /// Concurrent submitting clients.
    pub clients: usize,
    /// Submissions per client.
    pub per_client: usize,
    /// Concurrent SSE subscriber threads.
    pub sse_subs: usize,
    /// HTTP worker threads for the embedded daemon.
    pub http_workers: usize,
    /// Run-queue worker threads for the embedded daemon.
    pub run_workers: usize,
    /// Output path for the JSON record.
    pub out: String,
}

impl Default for BenchOpts {
    fn default() -> BenchOpts {
        BenchOpts {
            quick: false,
            clients: 8,
            per_client: 8,
            sse_subs: 2,
            http_workers: 12,
            run_workers: 4,
            out: "BENCH_gateway.json".to_string(),
        }
    }
}

impl BenchOpts {
    /// The simulated horizon per benched run, in weeks (shorter for
    /// `--quick`).
    fn weeks(&self) -> f64 {
        if self.quick {
            0.002
        } else {
            0.01
        }
    }

    /// Submissions per client after applying `--quick`.
    fn submissions(&self) -> usize {
        if self.quick {
            self.per_client.min(3)
        } else {
            self.per_client
        }
    }
}

/// Drive the load, wait for completion, write `opts.out`, and return
/// the recorded document.
pub fn run(opts: &BenchOpts) -> anyhow::Result<Json> {
    let total = opts.clients * opts.submissions();
    let cfg = GatewayConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: opts.http_workers,
        run_workers: opts.run_workers,
        time_warp: 0.0,
        queue_depth: total + 8,
        accept_queue: 128,
    };
    let gw = Gateway::start(&cfg)?;
    let addr = gw.local_addr();
    let submit_ms = Mutex::new(Vec::<f64>::new());
    let status_ms = Mutex::new(Vec::<f64>::new());
    let incomplete = Mutex::new(0usize);
    let failed = Mutex::new(0usize);
    let sse_records = Mutex::new(0usize);
    let weeks = opts.weeks();
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for c in 0..opts.clients {
            let submit_ms = &submit_ms;
            let status_ms = &status_ms;
            let incomplete = &incomplete;
            let failed = &failed;
            scope.spawn(move || {
                let Ok(mut client) = Client::connect(addr) else {
                    *incomplete.lock().unwrap() += opts.submissions();
                    return;
                };
                let mut ids = Vec::new();
                for i in 0..opts.submissions() {
                    let body = format!(
                        "{{\"preset\": \"oversubscribed-row\", \"weeks\": {weeks}, \
                         \"seed\": {}, \"name\": \"bench-c{c}-{i}\"}}",
                        c * opts.per_client + i + 1
                    );
                    let t = Instant::now();
                    let resp = client.request(
                        "POST",
                        "/scenarios",
                        Some("application/json"),
                        body.as_bytes(),
                    );
                    submit_ms.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                    match resp {
                        Ok((202, text)) => {
                            if let Some(id) = parse_json(&text)
                                .ok()
                                .and_then(|j| j.get("id").and_then(Json::as_str).map(String::from))
                            {
                                ids.push(id);
                            } else {
                                *incomplete.lock().unwrap() += 1;
                            }
                        }
                        _ => *incomplete.lock().unwrap() += 1,
                    }
                }
                // Poll each submitted run to completion.
                let deadline = Instant::now() + Duration::from_secs(120);
                for id in &ids {
                    loop {
                        let t = Instant::now();
                        let resp = client.request("GET", &format!("/runs/{id}"), None, b"");
                        status_ms.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                        match resp {
                            Ok((200, text)) if text.contains("\"outcome\"") => break,
                            Ok((500, _)) => {
                                *failed.lock().unwrap() += 1;
                                break;
                            }
                            Ok(_) if Instant::now() < deadline => {
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            _ => {
                                *incomplete.lock().unwrap() += 1;
                                break;
                            }
                        }
                    }
                }
            });
        }
        for _ in 0..opts.sse_subs {
            let sse_records = &sse_records;
            scope.spawn(move || {
                // The first submission lands as run-000001; retry until
                // it exists, then collect its stream to the end.
                for _ in 0..200 {
                    match sse_collect(
                        addr,
                        "/runs/run-000001/events",
                        200_000,
                        Duration::from_secs(30),
                    ) {
                        Ok(recs) if !recs.is_empty() => {
                            *sse_records.lock().unwrap() += recs.len();
                            return;
                        }
                        _ => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            });
        }
    });

    let wall_s = t0.elapsed().as_secs_f64();
    let incomplete = *incomplete.lock().unwrap();
    let failed = *failed.lock().unwrap();
    let sse_records = *sse_records.lock().unwrap();
    let metrics = gw.metrics().clone();
    let requests = metrics.http_requests.load(Ordering::Relaxed);
    let rejected = metrics.runs_rejected.load(Ordering::Relaxed);

    // Graceful stop through the public endpoint, then join all threads.
    let _ = request_once(addr, "POST", "/shutdown", None, b"");
    gw.trigger_shutdown();
    gw.join();

    let mut submit = Percentiles::new();
    for v in submit_ms.lock().unwrap().iter() {
        submit.push(*v);
    }
    let mut status = Percentiles::new();
    for v in status_ms.lock().unwrap().iter() {
        status.push(*v);
    }
    let doc = Json::obj(vec![
        ("quick", Json::Bool(opts.quick)),
        ("clients", Json::num(opts.clients as f64)),
        ("submissions", Json::num(total as f64)),
        ("weeks_per_run", Json::num(weeks)),
        ("http_workers", Json::num(opts.http_workers as f64)),
        ("run_workers", Json::num(opts.run_workers as f64)),
        ("wall_s", Json::num(wall_s)),
        ("requests", Json::num(requests as f64)),
        ("req_per_s", Json::num(requests as f64 / wall_s.max(1e-9))),
        ("submit_p50_ms", Json::num(submit.p50())),
        ("submit_p99_ms", Json::num(submit.p99())),
        ("status_p50_ms", Json::num(status.p50())),
        ("status_p99_ms", Json::num(status.p99())),
        ("sse_subscribers", Json::num(opts.sse_subs as f64)),
        ("sse_records", Json::num(sse_records as f64)),
        ("runs_failed", Json::num(failed as f64)),
        ("runs_rejected_429", Json::num(rejected as f64)),
        ("dropped_runs", Json::num(incomplete as f64)),
    ]);
    std::fs::write(&opts.out, format!("{}\n", doc.to_pretty()))
        .map_err(|e| anyhow::anyhow!("cannot write {}: {e}", opts.out))?;
    if incomplete > 0 {
        anyhow::bail!("{incomplete} of {total} benched runs did not complete");
    }
    Ok(doc)
}
