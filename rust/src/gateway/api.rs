//! The gateway's HTTP API: routing, the submission codec, report
//! retrieval, Server-Sent-Events streaming, health, and Prometheus
//! metrics.
//!
//! Endpoints (full reference with examples in `docs/GATEWAY.md`):
//!
//! | Method | Path               | Purpose                                   |
//! |--------|--------------------|-------------------------------------------|
//! | POST   | `/scenarios`       | Submit a scenario (TOML body or JSON envelope) → `202` + run id |
//! | GET    | `/runs`            | List every run with its lifecycle state    |
//! | GET    | `/runs/:id`        | Status document, or the final report verbatim once done |
//! | GET    | `/runs/:id/events` | SSE stream of the run's observation records |
//! | GET    | `/healthz`         | Liveness + run counts                      |
//! | GET    | `/metrics`         | Prometheus text exposition                 |
//! | POST   | `/shutdown`        | Graceful daemon stop                       |
//!
//! A finished run's `GET /runs/:id` body is the stored
//! [`ScenarioReport::to_json`](crate::scenario::ScenarioReport::to_json)
//! pretty document (trailing newline included) — byte-identical to
//! `polca run <same scenario> --json` because both surfaces share that
//! single serialization (and share
//! [`error_report_json`](crate::scenario::error_report_json) on the
//! error path).

use std::io::{self, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use crate::scenario::Scenario;
use crate::util::json::{parse as parse_json, Json};

use super::http::{write_response, Request};
use super::state::{Metrics, Registry, RunView, SubNext};
use super::ShutdownSignal;

/// How long an SSE loop waits on the hub before re-checking shutdown.
const SSE_POLL: Duration = Duration::from_millis(250);

/// Shared context the router hands every request handler.
pub struct Ctx {
    /// The run registry.
    pub registry: Arc<Registry>,
    /// Daemon-wide counters.
    pub metrics: Arc<Metrics>,
    /// Graceful-stop signal; `POST /shutdown` trips it.
    pub shutdown: Arc<ShutdownSignal>,
    /// Fast per-request shutdown check shared with the HTTP layer.
    pub shutdown_flag: Arc<AtomicBool>,
}

/// Route one request. Returns whether the connection may be kept
/// alive (SSE streams always close).
pub fn handle(req: &Request, stream: &mut TcpStream, ctx: &Ctx) -> io::Result<bool> {
    Metrics::add(&ctx.metrics.http_requests, 1);
    let path = req.path.as_str();
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            let c = ctx.registry.counts();
            let body = Json::obj(vec![
                ("status", Json::Str("ok".to_string())),
                ("queued", Json::num(c[0] as f64)),
                ("running", Json::num(c[1] as f64)),
                ("done", Json::num(c[2] as f64)),
                ("failed", Json::num(c[3] as f64)),
            ]);
            respond_json(stream, 200, &body)
        }
        ("GET", "/metrics") => {
            let text = ctx.metrics.render(&ctx.registry);
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4",
                text.as_bytes(),
                true,
                &[],
            )?;
            Ok(true)
        }
        ("POST", "/scenarios") => submit(req, stream, ctx),
        ("POST", "/shutdown") => {
            let body = Json::obj(vec![("status", Json::Str("shutting-down".to_string()))]);
            // Respond first so the client sees the acknowledgement
            // before the listener goes away.
            let r = respond_json_close(stream, 200, &body);
            ctx.shutdown.trigger();
            r
        }
        ("GET", "/runs") => {
            let runs = ctx.registry.list();
            let body = Json::arr(runs.iter().map(run_status_doc));
            respond_json(stream, 200, &body)
        }
        ("GET", p) if p.starts_with("/runs/") && p.ends_with("/events") => {
            let id = &p["/runs/".len()..p.len() - "/events".len()];
            match ctx.registry.get(id) {
                Some(view) => sse_stream(stream, &view, ctx),
                None => not_found(stream),
            }
        }
        ("GET", p) if p.starts_with("/runs/") => {
            let id = &p["/runs/".len()..];
            match ctx.registry.get(id) {
                Some(view) => run_doc(stream, &view),
                None => not_found(stream),
            }
        }
        (_, "/scenarios" | "/shutdown" | "/healthz" | "/metrics" | "/runs") => {
            respond_error(stream, 405, "method not allowed")
        }
        _ => not_found(stream),
    }
}

/// `POST /scenarios`: decode, validate, enqueue.
fn submit(req: &Request, stream: &mut TcpStream, ctx: &Ctx) -> io::Result<bool> {
    let sc = match decode_submission(req) {
        Ok(sc) => sc,
        Err(e) => return respond_error(stream, 400, &format!("{e:#}")),
    };
    match ctx.registry.submit(sc) {
        Ok(view) => {
            let body = Json::obj(vec![
                ("id", Json::Str(view.id.clone())),
                ("name", Json::Str(view.name.clone())),
                ("status", Json::Str(view.status.label().to_string())),
                ("report", Json::Str(format!("/runs/{}", view.id))),
                ("events", Json::Str(format!("/runs/{}/events", view.id))),
            ]);
            respond_json(stream, 202, &body)
        }
        Err(_full) => {
            Metrics::add(&ctx.metrics.runs_rejected, 1);
            respond_error(stream, 429, "run queue full")
        }
    }
}

/// Decode a submission body into a validated [`Scenario`].
///
/// Two codecs, chosen by shape: a body whose first non-space byte is
/// `{` (or whose `Content-Type` mentions `json`) is a JSON envelope —
/// `{"preset": NAME}` or `{"toml": TEXT}`, with optional `"name"`,
/// `"weeks"`, and `"seed"` overrides applied after loading. Anything
/// else is the scenario TOML codec itself (the same bit-lossless
/// format `polca scenario save` writes).
pub fn decode_submission(req: &Request) -> anyhow::Result<Scenario> {
    let body = req.body_str();
    let text = body.trim();
    if text.is_empty() {
        anyhow::bail!("empty submission body (send scenario TOML or a JSON envelope)");
    }
    let looks_json = text.starts_with('{')
        || req.header("content-type").map(|ct| ct.contains("json")).unwrap_or(false);
    let sc = if looks_json {
        let doc = parse_json(text).map_err(|e| anyhow::anyhow!("invalid JSON envelope: {e}"))?;
        let mut sc = if let Some(name) = doc.get("preset").and_then(Json::as_str) {
            crate::scenario::preset(name)?
        } else if let Some(toml) = doc.get("toml").and_then(Json::as_str) {
            Scenario::parse(toml)?
        } else {
            anyhow::bail!("JSON envelope needs a \"preset\" or \"toml\" field");
        };
        if let Some(name) = doc.get("name").and_then(Json::as_str) {
            sc.name = name.to_string();
        }
        if let Some(weeks) = doc.get("weeks").and_then(Json::as_f64) {
            sc.weeks = weeks;
        }
        if let Some(seed) = doc.get("seed").and_then(Json::as_f64) {
            sc.exp.seed = seed as u64;
        }
        sc
    } else {
        Scenario::parse(text)?
    };
    sc.validate()?;
    Ok(sc)
}

/// `GET /runs/:id`: the status document while queued/running, the
/// stored terminal document verbatim once done/failed.
fn run_doc(stream: &mut TcpStream, view: &RunView) -> io::Result<bool> {
    match (&view.body, view.status) {
        (Some(body), super::state::RunStatus::Done) => {
            write_response(stream, 200, "application/json", body.as_bytes(), true, &[])?;
            Ok(true)
        }
        (Some(body), _) => {
            write_response(stream, 500, "application/json", body.as_bytes(), true, &[])?;
            Ok(true)
        }
        (None, _) => respond_json(stream, 200, &run_status_doc(view)),
    }
}

/// The non-terminal run document: `{"id", "name", "status"}`.
fn run_status_doc(view: &RunView) -> Json {
    Json::obj(vec![
        ("id", Json::Str(view.id.clone())),
        ("name", Json::Str(view.name.clone())),
        ("status", Json::Str(view.status.label().to_string())),
    ])
}

/// `GET /runs/:id/events`: stream the run's records as Server-Sent
/// Events (`data: <record>\n\n` per record). Replays the backlog, then
/// follows live until the run finishes, the daemon stops, or the
/// subscriber falls behind its bounded queue and is dropped.
fn sse_stream(stream: &mut TcpStream, view: &RunView, ctx: &Ctx) -> io::Result<bool> {
    Metrics::add(&ctx.metrics.sse_subscribers, 1);
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n",
    )?;
    let (sub, snapshot) = view.hub.subscribe();
    let result = (|| -> io::Result<()> {
        for rec in &snapshot {
            write_sse_record(stream, rec)?;
        }
        stream.flush()?;
        loop {
            if ctx.shutdown_flag.load(std::sync::atomic::Ordering::Relaxed) {
                return Ok(());
            }
            match view.hub.next(sub, SSE_POLL) {
                SubNext::Records(rs) if rs.is_empty() => continue,
                SubNext::Records(rs) => {
                    for rec in &rs {
                        write_sse_record(stream, rec)?;
                    }
                    stream.flush()?;
                }
                SubNext::Closed | SubNext::Lagged => return Ok(()),
            }
        }
    })();
    view.hub.unsubscribe(sub);
    result?;
    Ok(false)
}

fn write_sse_record(w: &mut impl Write, record: &str) -> io::Result<()> {
    w.write_all(b"data: ")?;
    w.write_all(record.as_bytes())?;
    w.write_all(b"\n\n")
}

fn respond_json(stream: &mut TcpStream, status: u16, body: &Json) -> io::Result<bool> {
    let text = format!("{}\n", body.to_pretty());
    write_response(stream, status, "application/json", text.as_bytes(), true, &[])?;
    Ok(true)
}

fn respond_json_close(stream: &mut TcpStream, status: u16, body: &Json) -> io::Result<bool> {
    let text = format!("{}\n", body.to_pretty());
    write_response(stream, status, "application/json", text.as_bytes(), false, &[])?;
    Ok(false)
}

fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) -> io::Result<bool> {
    let body = Json::obj(vec![("error", Json::Str(msg.to_string()))]);
    respond_json(stream, status, &body)
}

fn not_found(stream: &mut TcpStream) -> io::Result<bool> {
    respond_error(stream, 404, "not found")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::preset;

    fn req(content_type: Option<&str>, body: &str) -> Request {
        let mut headers = Vec::new();
        if let Some(ct) = content_type {
            headers.push(("content-type".to_string(), ct.to_string()));
        }
        Request {
            method: "POST".to_string(),
            path: "/scenarios".to_string(),
            query: String::new(),
            headers,
            body: body.as_bytes().to_vec(),
        }
    }

    #[test]
    fn decodes_raw_toml_and_json_envelopes() {
        let toml = preset("oversubscribed-row").unwrap().to_toml_string();
        let sc = decode_submission(&req(None, &toml)).unwrap();
        assert_eq!(sc, preset("oversubscribed-row").unwrap());

        let sc = decode_submission(&req(
            Some("application/json"),
            "{\"preset\": \"inference-row\", \"weeks\": 0.25, \"seed\": 9, \"name\": \"mine\"}",
        ))
        .unwrap();
        assert_eq!(sc.name, "mine");
        assert_eq!(sc.weeks, 0.25);
        assert_eq!(sc.exp.seed, 9);

        let envelope = format!("{{\"toml\": {}}}", Json::Str(toml).to_string());
        let sc = decode_submission(&req(Some("application/json"), &envelope)).unwrap();
        assert_eq!(sc, preset("oversubscribed-row").unwrap());
    }

    #[test]
    fn rejects_malformed_submissions() {
        assert!(decode_submission(&req(None, "")).is_err());
        assert!(decode_submission(&req(None, "{\"nope\": 1}")).is_err());
        assert!(decode_submission(&req(None, "{\"preset\": \"no-such-preset\"}")).is_err());
        // Valid envelope, invalid scenario: weeks must be > 0.
        assert!(decode_submission(&req(
            None,
            "{\"preset\": \"inference-row\", \"weeks\": -1}"
        ))
        .is_err());
    }
}
