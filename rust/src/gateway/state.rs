//! Gateway state: the run registry, the per-run event broadcast hub,
//! and the daemon-wide metric counters.
//!
//! The registry is the single source of truth for every submitted run:
//! a `Mutex`-guarded map keyed by deterministic run ids
//! (`run-000001`, `run-000002`, ... in submission order) plus a
//! bounded FIFO of not-yet-started work that run-queue worker threads
//! drain through [`Registry::claim`]. Run lifecycle is strictly
//! `Queued → Running → Done | Failed`; the terminal body (the report
//! document on success, the shared error document on failure) is
//! immutable once set, so `GET /runs/:id` can serve it without
//! re-serialization.
//!
//! Each run owns an [`EventHub`] that fans its observation records out
//! to SSE subscribers: a bounded backlog replays the stream to late
//! subscribers, and each live subscriber drains a bounded queue — a
//! subscriber that falls [`SUB_QUEUE_CAP`] records behind is dropped
//! (with a [`DiagEvent::SubscriberDropped`] notice) instead of ever
//! backpressuring the simulation.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::obs::{emit_diag, DiagEvent};
use crate::scenario::Scenario;

/// Records the backlog retains for replay to late subscribers; older
/// records fall off the front (the count is exposed in `/metrics`).
pub const BACKLOG_CAP: usize = 16_384;

/// Pending-record bound per live subscriber; a subscriber this far
/// behind is dropped rather than slowing the run.
pub const SUB_QUEUE_CAP: usize = 4_096;

/// Format the deterministic run id for submission sequence `seq`
/// (1-based): `run-000001`, `run-000002`, ...
pub fn run_id(seq: u64) -> String {
    format!("run-{seq:06}")
}

/// Lifecycle of a submitted run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunStatus {
    /// Accepted, waiting for a run-queue worker.
    Queued,
    /// A worker is executing the scenario.
    Running,
    /// Finished; the report document is available.
    Done,
    /// The scenario errored; the error document is available.
    Failed,
}

impl RunStatus {
    /// Lowercase wire label (`"queued"` / `"running"` / ...).
    pub fn label(self) -> &'static str {
        match self {
            RunStatus::Queued => "queued",
            RunStatus::Running => "running",
            RunStatus::Done => "done",
            RunStatus::Failed => "failed",
        }
    }
}

/// Daemon-wide monotonic counters, rendered by `GET /metrics` in
/// Prometheus text format. All fields are totals since daemon start;
/// instantaneous state (queue depth, live runs) is read from the
/// [`Registry`] at render time instead of being mirrored here.
#[derive(Debug, Default)]
pub struct Metrics {
    /// HTTP requests parsed and routed.
    pub http_requests: AtomicU64,
    /// TCP connections accepted into the worker pool.
    pub http_connections: AtomicU64,
    /// Connections shed with `503` because the accept queue was full.
    pub http_shed: AtomicU64,
    /// Scenario submissions accepted (`202`).
    pub runs_submitted: AtomicU64,
    /// Submissions rejected with `429` because the run queue was full.
    pub runs_rejected: AtomicU64,
    /// Runs finished successfully.
    pub runs_done: AtomicU64,
    /// Runs that errored.
    pub runs_failed: AtomicU64,
    /// Event-stream subscriptions served.
    pub sse_subscribers: AtomicU64,
    /// Subscribers dropped for falling behind their bounded queue.
    pub sse_dropped: AtomicU64,
    /// Observation records broadcast to the hubs.
    pub sse_records: AtomicU64,
    /// Simulator events dispatched across all finished runs (the obs
    /// `events-dispatched` end-of-run counter, aggregated).
    pub sim_events: AtomicU64,
    /// Energy-segment settlements across all finished runs (the obs
    /// settle hot-path counter, aggregated).
    pub sim_settles: AtomicU64,
}

impl Metrics {
    /// Bump a counter by `n` (relaxed; totals only, no ordering needs).
    pub fn add(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    /// Render the Prometheus text exposition for `GET /metrics`.
    /// Counter totals come from `self`; queue/live-run gauges from
    /// `registry`.
    pub fn render(&self, registry: &Registry) -> String {
        let mut out = String::with_capacity(2048);
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        counter("polca_http_requests_total", "HTTP requests routed.", g(&self.http_requests));
        counter(
            "polca_http_connections_total",
            "TCP connections accepted.",
            g(&self.http_connections),
        );
        counter(
            "polca_http_shed_total",
            "Connections shed with 503 (accept queue full).",
            g(&self.http_shed),
        );
        counter("polca_runs_submitted_total", "Scenario submissions accepted.", g(&self.runs_submitted));
        counter(
            "polca_runs_rejected_total",
            "Submissions rejected with 429 (run queue full).",
            g(&self.runs_rejected),
        );
        counter("polca_runs_done_total", "Runs finished successfully.", g(&self.runs_done));
        counter("polca_runs_failed_total", "Runs that errored.", g(&self.runs_failed));
        counter("polca_sse_subscribers_total", "Event-stream subscriptions served.", g(&self.sse_subscribers));
        counter(
            "polca_sse_dropped_total",
            "Subscribers dropped for falling behind.",
            g(&self.sse_dropped),
        );
        counter("polca_sse_records_total", "Observation records broadcast.", g(&self.sse_records));
        counter(
            "polca_sim_events_total",
            "Simulator events dispatched across finished runs.",
            g(&self.sim_events),
        );
        counter(
            "polca_sim_settles_total",
            "Energy segments settled across finished runs.",
            g(&self.sim_settles),
        );
        let counts = registry.counts();
        for (i, name) in
            ["polca_runs_queued", "polca_runs_running"].iter().enumerate()
        {
            out.push_str(&format!(
                "# HELP {name} Runs currently in this state.\n# TYPE {name} gauge\n{name} {}\n",
                counts[i]
            ));
        }
        out
    }
}

/// What [`EventHub::next`] yields to a draining subscriber.
#[derive(Debug)]
pub enum SubNext {
    /// New records to forward (may be empty on a wait timeout; the
    /// caller re-checks shutdown and calls again).
    Records(Vec<Arc<String>>),
    /// The run finished and everything pending has been drained.
    Closed,
    /// The subscriber fell [`SUB_QUEUE_CAP`] behind and was dropped.
    Lagged,
}

struct SubSlot {
    id: u64,
    queue: VecDeque<Arc<String>>,
    dead: bool,
}

struct HubInner {
    backlog: VecDeque<Arc<String>>,
    dropped_backlog: u64,
    subs: Vec<SubSlot>,
    next_sub: u64,
    closed: bool,
}

/// Per-run fan-out of observation records (JSON-encoded, one record
/// per string) to SSE subscribers. See the module docs for the
/// backlog/queue bounding contract.
pub struct EventHub {
    run_seq: u64,
    metrics: Arc<Metrics>,
    inner: Mutex<HubInner>,
    cv: Condvar,
}

impl EventHub {
    /// New hub for the run with submission sequence `run_seq`.
    pub fn new(run_seq: u64, metrics: Arc<Metrics>) -> EventHub {
        EventHub {
            run_seq,
            metrics,
            inner: Mutex::new(HubInner {
                backlog: VecDeque::new(),
                dropped_backlog: 0,
                subs: Vec::new(),
                next_sub: 0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Broadcast one record: append to the backlog and every live
    /// subscriber queue; slow subscribers are marked dropped.
    pub fn publish(&self, record: String) {
        let rec = Arc::new(record);
        let mut dropped = 0u64;
        {
            let mut g = self.inner.lock().unwrap();
            if g.backlog.len() >= BACKLOG_CAP {
                g.backlog.pop_front();
                g.dropped_backlog += 1;
            }
            g.backlog.push_back(rec.clone());
            for s in g.subs.iter_mut() {
                if s.dead {
                    continue;
                }
                if s.queue.len() >= SUB_QUEUE_CAP {
                    s.dead = true;
                    s.queue.clear();
                    dropped += 1;
                } else {
                    s.queue.push_back(rec.clone());
                }
            }
        }
        self.cv.notify_all();
        Metrics::add(&self.metrics.sse_records, 1);
        if dropped > 0 {
            Metrics::add(&self.metrics.sse_dropped, dropped);
            emit_diag(&DiagEvent::SubscriberDropped {
                run_seq: self.run_seq,
                pending: SUB_QUEUE_CAP,
            });
        }
    }

    /// The run finished: wake every subscriber so it can drain and
    /// observe [`SubNext::Closed`]. Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Register a subscriber. Returns its id plus a snapshot of the
    /// backlog taken atomically with registration, so the caller can
    /// replay history without missing or duplicating records published
    /// concurrently (those land in the new queue).
    pub fn subscribe(&self) -> (u64, Vec<Arc<String>>) {
        let mut g = self.inner.lock().unwrap();
        let id = g.next_sub;
        g.next_sub += 1;
        let snapshot: Vec<Arc<String>> = g.backlog.iter().cloned().collect();
        g.subs.push(SubSlot { id, queue: VecDeque::new(), dead: false });
        (id, snapshot)
    }

    /// Wait up to `wait` for records, then drain the subscriber's
    /// queue. Unknown ids (already dropped and reaped) read as
    /// [`SubNext::Lagged`].
    pub fn next(&self, sub: u64, wait: Duration) -> SubNext {
        let mut g = self.inner.lock().unwrap();
        loop {
            let Some(pos) = g.subs.iter().position(|s| s.id == sub) else {
                return SubNext::Lagged;
            };
            if g.subs[pos].dead {
                g.subs.remove(pos);
                return SubNext::Lagged;
            }
            if !g.subs[pos].queue.is_empty() {
                let drained: Vec<Arc<String>> = g.subs[pos].queue.drain(..).collect();
                return SubNext::Records(drained);
            }
            if g.closed {
                g.subs.remove(pos);
                return SubNext::Closed;
            }
            let (guard, timeout) = self.cv.wait_timeout(g, wait).unwrap();
            g = guard;
            if timeout.timed_out() {
                return SubNext::Records(Vec::new());
            }
        }
    }

    /// Deregister (client went away or the stream ended).
    pub fn unsubscribe(&self, sub: u64) {
        let mut g = self.inner.lock().unwrap();
        g.subs.retain(|s| s.id != sub);
    }

    /// Records lost off the front of the replay backlog.
    pub fn dropped_backlog(&self) -> u64 {
        self.inner.lock().unwrap().dropped_backlog
    }
}

/// Immutable snapshot of one run for the API layer.
#[derive(Clone)]
pub struct RunView {
    /// Deterministic run id (`run-000001`, ...).
    pub id: String,
    /// The scenario's name.
    pub name: String,
    /// Lifecycle state at snapshot time.
    pub status: RunStatus,
    /// Terminal document (report on `Done`, error document on
    /// `Failed`), pretty-printed JSON with a trailing newline — served
    /// verbatim so it is byte-identical to `polca run --json` output.
    pub body: Option<Arc<String>>,
    /// The run's event fan-out hub.
    pub hub: Arc<EventHub>,
}

struct Slot {
    name: String,
    status: RunStatus,
    body: Option<Arc<String>>,
    hub: Arc<EventHub>,
}

struct RegInner {
    next_seq: u64,
    queue: VecDeque<(String, Scenario)>,
    runs: BTreeMap<String, Slot>,
    closed: bool,
}

/// Submission rejected: the run queue is at capacity (HTTP 429).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RegistryFull;

/// The run registry: deterministic ids, the bounded run queue, and
/// per-run terminal state. One instance per daemon, shared by the API
/// handlers and the run-queue workers.
pub struct Registry {
    metrics: Arc<Metrics>,
    queue_cap: usize,
    inner: Mutex<RegInner>,
    cv: Condvar,
}

impl Registry {
    /// New registry whose run queue holds at most `queue_cap` pending
    /// scenarios.
    pub fn new(queue_cap: usize, metrics: Arc<Metrics>) -> Registry {
        Registry {
            metrics,
            queue_cap: queue_cap.max(1),
            inner: Mutex::new(RegInner {
                next_seq: 1,
                queue: VecDeque::new(),
                runs: BTreeMap::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a validated scenario. Returns the new run's snapshot,
    /// or [`RegistryFull`] when the queue is at capacity.
    pub fn submit(&self, sc: Scenario) -> Result<RunView, RegistryFull> {
        let (view, seq, queued) = {
            let mut g = self.inner.lock().unwrap();
            if g.queue.len() >= self.queue_cap || g.closed {
                return Err(RegistryFull);
            }
            let seq = g.next_seq;
            g.next_seq += 1;
            let id = run_id(seq);
            let hub = Arc::new(EventHub::new(seq, self.metrics.clone()));
            let name = sc.name.clone();
            g.runs.insert(
                id.clone(),
                Slot { name: name.clone(), status: RunStatus::Queued, body: None, hub: hub.clone() },
            );
            g.queue.push_back((id.clone(), sc));
            let queued = g.queue.len();
            (RunView { id, name, status: RunStatus::Queued, body: None, hub }, seq, queued)
        };
        self.cv.notify_one();
        Metrics::add(&self.metrics.runs_submitted, 1);
        emit_diag(&DiagEvent::RunAccepted { run_seq: seq, queued });
        Ok(view)
    }

    /// Blocking claim for run-queue workers: waits for a queued run,
    /// marks it `Running`, and hands back everything needed to execute
    /// it. Returns `None` once the registry is closed (shutdown).
    pub fn claim(&self) -> Option<(String, Scenario, Arc<EventHub>)> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return None;
            }
            if let Some((id, sc)) = g.queue.pop_front() {
                let hub = {
                    let slot = g.runs.get_mut(&id).expect("queued run must be registered");
                    slot.status = RunStatus::Running;
                    slot.hub.clone()
                };
                return Some((id, sc, hub));
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Record a run's terminal state and close its hub. `Ok` carries
    /// the report document, `Err` the error document (both served
    /// verbatim by `GET /runs/:id`).
    pub fn finish(&self, id: &str, result: Result<String, String>) {
        let ok = result.is_ok();
        let hub = {
            let mut g = self.inner.lock().unwrap();
            let Some(slot) = g.runs.get_mut(id) else { return };
            let (status, body) = match result {
                Ok(body) => (RunStatus::Done, body),
                Err(body) => (RunStatus::Failed, body),
            };
            slot.status = status;
            slot.body = Some(Arc::new(body));
            slot.hub.clone()
        };
        hub.close();
        let counter = if ok { &self.metrics.runs_done } else { &self.metrics.runs_failed };
        Metrics::add(counter, 1);
    }

    /// Snapshot one run.
    pub fn get(&self, id: &str) -> Option<RunView> {
        let g = self.inner.lock().unwrap();
        g.runs.get(id).map(|s| RunView {
            id: id.to_string(),
            name: s.name.clone(),
            status: s.status,
            body: s.body.clone(),
            hub: s.hub.clone(),
        })
    }

    /// Snapshot every run in id (= submission) order.
    pub fn list(&self) -> Vec<RunView> {
        let g = self.inner.lock().unwrap();
        g.runs
            .iter()
            .map(|(id, s)| RunView {
                id: id.clone(),
                name: s.name.clone(),
                status: s.status,
                body: s.body.clone(),
                hub: s.hub.clone(),
            })
            .collect()
    }

    /// `[queued, running, done, failed]` run counts.
    pub fn counts(&self) -> [u64; 4] {
        let g = self.inner.lock().unwrap();
        let mut out = [0u64; 4];
        for s in g.runs.values() {
            let i = match s.status {
                RunStatus::Queued => 0,
                RunStatus::Running => 1,
                RunStatus::Done => 2,
                RunStatus::Failed => 3,
            };
            out[i] += 1;
        }
        out
    }

    /// Stop accepting and dispensing work: `submit` returns
    /// [`RegistryFull`] and `claim` returns `None`. Queued-but-unrun
    /// scenarios are abandoned (the daemon is exiting).
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::preset;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    #[test]
    fn run_ids_are_deterministic_and_ordered() {
        assert_eq!(run_id(1), "run-000001");
        assert_eq!(run_id(42), "run-000042");
        let reg = Registry::new(8, metrics());
        let a = reg.submit(preset("oversubscribed-row").unwrap()).unwrap();
        let b = reg.submit(preset("inference-row").unwrap()).unwrap();
        assert_eq!(a.id, "run-000001");
        assert_eq!(b.id, "run-000002");
        assert_eq!(a.status, RunStatus::Queued);
    }

    #[test]
    fn queue_capacity_rejects_and_lifecycle_advances() {
        let reg = Registry::new(1, metrics());
        reg.submit(preset("inference-row").unwrap()).unwrap();
        assert!(matches!(reg.submit(preset("inference-row").unwrap()), Err(RegistryFull)));
        let (id, _sc, _hub) = reg.claim().unwrap();
        assert_eq!(reg.get(&id).unwrap().status, RunStatus::Running);
        // Queue drained: capacity is available again.
        reg.submit(preset("inference-row").unwrap()).unwrap();
        reg.finish(&id, Ok("{}\n".to_string()));
        let v = reg.get(&id).unwrap();
        assert_eq!(v.status, RunStatus::Done);
        assert_eq!(v.body.as_deref().map(|s| s.as_str()), Some("{}\n"));
        assert_eq!(reg.counts(), [1, 0, 1, 0]);
        reg.close();
        assert!(reg.claim().is_none());
        assert!(matches!(reg.submit(preset("inference-row").unwrap()), Err(RegistryFull)));
    }

    #[test]
    fn hub_replays_backlog_and_drops_slow_subscribers() {
        let hub = EventHub::new(1, metrics());
        hub.publish("{\"a\":1}".to_string());
        // Late subscriber sees the backlog as its snapshot.
        let (sub, snapshot) = hub.subscribe();
        assert_eq!(snapshot.len(), 1);
        hub.publish("{\"a\":2}".to_string());
        match hub.next(sub, Duration::from_millis(50)) {
            SubNext::Records(rs) => assert_eq!(rs.len(), 1),
            other => panic!("expected records, got {other:?}"),
        }
        hub.close();
        assert!(matches!(hub.next(sub, Duration::from_millis(50)), SubNext::Closed));

        // A subscriber that never drains is dropped at the bound.
        let hub = EventHub::new(2, metrics());
        let (lazy, _) = hub.subscribe();
        for i in 0..(SUB_QUEUE_CAP + 2) {
            hub.publish(format!("{{\"i\":{i}}}"));
        }
        assert!(matches!(hub.next(lazy, Duration::from_millis(10)), SubNext::Lagged));
    }
}
