//! `polca gateway` — the live control-plane daemon: the
//! telemetry→policy→OOB loop served over HTTP.
//!
//! Everything before this module runs the POLCA control loop as a
//! one-shot batch simulation. The gateway turns it into a long-running
//! service: scenarios are submitted over HTTP (the same bit-lossless
//! TOML codec, or a small JSON envelope), executed by a pool of
//! run-queue workers — optionally paced against wall-clock at a
//! configurable time-warp — and their control decisions stream to
//! subscribers as Server-Sent Events while Prometheus metrics track
//! the daemon. Std-only: the HTTP/1.1 server is hand-rolled over
//! `std::net::TcpListener` (see [`http`]).
//!
//! Layer map:
//!
//! * [`http`] — listener, parser, router plumbing, fixed worker pool,
//!   keep-alive, bounded accept queue (backpressure → `503`),
//!   graceful shutdown; plus the loopback client for tests/bench.
//! * [`api`] — endpoint handlers: submission codec, reports, SSE,
//!   `/healthz`, `/metrics`, `/shutdown`.
//! * [`live`] — run-queue workers; wall-clock pacing and record
//!   broadcast as passive observers composed via
//!   [`obs::Tee`](crate::obs::Tee).
//! * [`state`] — run registry (deterministic ids, lifecycle
//!   `Queued → Running → Done/Failed`), per-run event hubs, metrics.
//! * [`bench`] — the built-in loopback load generator
//!   (`polca gateway bench`), writing `BENCH_gateway.json`.
//!
//! Contrast with `polca serve` (the one-shot PJRT-artifact serving
//! driver): `serve` loads a real compiled model, plays a fixed request
//! batch through the coordinator once, and exits; `gateway` is the
//! long-running daemon around the *simulation* control loop. The two
//! are cross-referenced in the CLI help.
//!
//! Endpoint reference and wire examples: `docs/GATEWAY.md`.

pub mod api;
pub mod bench;
pub mod http;
pub mod live;
pub mod state;

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::obs::{emit_diag, DiagEvent};

pub use state::{Metrics, Registry, RunStatus, RunView};

/// Daemon configuration (`polca gateway` flags).
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Bind address; `127.0.0.1:0` picks an ephemeral port (tests).
    pub addr: String,
    /// HTTP worker threads (each SSE subscriber occupies one for the
    /// life of its stream).
    pub http_workers: usize,
    /// Run-queue worker threads executing scenarios.
    pub run_workers: usize,
    /// Simulated seconds advanced per wall-clock second for observed
    /// runs; `0` (default) runs unpaced.
    pub time_warp: f64,
    /// Run-queue bound; submissions beyond it answer `429`.
    pub queue_depth: usize,
    /// Accepted-connection queue bound; connections beyond it are shed
    /// with `503`.
    pub accept_queue: usize,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig {
            addr: "127.0.0.1:7311".to_string(),
            http_workers: 8,
            run_workers: 2,
            time_warp: 0.0,
            queue_depth: 64,
            accept_queue: 64,
        }
    }
}

/// Level-triggered graceful-stop signal: an atomic flag for cheap
/// polling plus a condvar for the orchestrator's blocking wait.
pub struct ShutdownSignal {
    flag: AtomicBool,
    lock: Mutex<bool>,
    cv: Condvar,
}

impl ShutdownSignal {
    /// New, untriggered signal.
    pub fn new() -> ShutdownSignal {
        ShutdownSignal { flag: AtomicBool::new(false), lock: Mutex::new(false), cv: Condvar::new() }
    }

    /// Trip the signal (idempotent).
    pub fn trigger(&self) {
        self.flag.store(true, Ordering::SeqCst);
        *self.lock.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Whether the signal has been tripped.
    pub fn is_set(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// Block until tripped.
    pub fn wait(&self) {
        let mut g = self.lock.lock().unwrap();
        while !*g {
            g = self.cv.wait(g).unwrap();
        }
    }
}

impl Default for ShutdownSignal {
    fn default() -> ShutdownSignal {
        ShutdownSignal::new()
    }
}

/// A running gateway daemon. Obtain with [`Gateway::start`]; stop with
/// `POST /shutdown`, or programmatically via
/// [`Gateway::trigger_shutdown`]; either way [`Gateway::join`] blocks
/// until the stop and then joins every thread (acceptor, HTTP
/// workers, run-queue workers).
pub struct Gateway {
    addr: SocketAddr,
    server: http::Server,
    run_workers: Vec<JoinHandle<()>>,
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    shutdown: Arc<ShutdownSignal>,
    shutdown_flag: Arc<AtomicBool>,
}

impl Gateway {
    /// Bind, spawn the worker pools, and start serving. Emits
    /// [`DiagEvent::GatewayStarted`] once the listener is live.
    pub fn start(cfg: &GatewayConfig) -> anyhow::Result<Gateway> {
        let metrics = Arc::new(Metrics::default());
        let registry = Arc::new(Registry::new(cfg.queue_depth, metrics.clone()));
        let shutdown = Arc::new(ShutdownSignal::new());
        let shutdown_flag = Arc::new(AtomicBool::new(false));

        let mut run_workers = Vec::with_capacity(cfg.run_workers.max(1));
        for i in 0..cfg.run_workers.max(1) {
            let registry = registry.clone();
            let metrics = metrics.clone();
            let flag = shutdown_flag.clone();
            let warp = cfg.time_warp;
            run_workers.push(
                std::thread::Builder::new()
                    .name(format!("gw-run-{i}"))
                    .spawn(move || live::run_worker(registry, metrics, warp, flag))
                    .map_err(|e| anyhow::anyhow!("cannot spawn run worker: {e}"))?,
            );
        }

        let ctx = Arc::new(api::Ctx {
            registry: registry.clone(),
            metrics: metrics.clone(),
            shutdown: shutdown.clone(),
            shutdown_flag: shutdown_flag.clone(),
        });
        let handler: Arc<http::Handler> =
            Arc::new(move |req, stream| api::handle(req, stream, &ctx));
        let http_cfg = http::HttpConfig {
            addr: cfg.addr.clone(),
            workers: cfg.http_workers,
            accept_queue: cfg.accept_queue,
        };
        let server = http::Server::start(&http_cfg, handler)
            .map_err(|e| anyhow::anyhow!("cannot bind gateway on {}: {e}", cfg.addr))?;
        let addr = server.local_addr;
        emit_diag(&DiagEvent::GatewayStarted {
            port: addr.port(),
            http_workers: cfg.http_workers.max(1),
            run_workers: cfg.run_workers.max(1),
        });
        Ok(Gateway { addr, server, run_workers, registry, metrics, shutdown, shutdown_flag })
    }

    /// The bound address (resolves `:0` bindings).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The daemon's run registry (shared; useful for in-process
    /// inspection in tests and the bench harness).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The daemon's metric counters.
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Trip the graceful-stop signal (same effect as `POST /shutdown`).
    pub fn trigger_shutdown(&self) {
        self.shutdown.trigger();
    }

    /// Fold acceptor-side counters into the metrics struct so
    /// `/metrics` reflects connection-level shedding.
    fn sync_http_counters(&self) {
        let shed = self.server.shed.load(Ordering::Relaxed);
        let accepted = self.server.accepted.load(Ordering::Relaxed);
        let cur_shed = self.metrics.http_shed.load(Ordering::Relaxed);
        let cur_acc = self.metrics.http_connections.load(Ordering::Relaxed);
        Metrics::add(&self.metrics.http_shed, shed.saturating_sub(cur_shed));
        Metrics::add(&self.metrics.http_connections, accepted.saturating_sub(cur_acc));
    }

    /// Block until the shutdown signal trips, then stop everything and
    /// join every thread: the registry closes (run workers exit), the
    /// HTTP layer stops accepting and its workers drain, and all join
    /// handles are collected.
    pub fn join(self) {
        self.shutdown.wait();
        self.shutdown_flag.store(true, Ordering::SeqCst);
        self.registry.close();
        self.server.shutdown();
        self.sync_http_counters();
        self.server.join();
        for w in self.run_workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shutdown_signal_levels_and_wakes() {
        let s = Arc::new(ShutdownSignal::new());
        assert!(!s.is_set());
        let waiter = {
            let s = s.clone();
            std::thread::spawn(move || s.wait())
        };
        s.trigger();
        waiter.join().unwrap();
        assert!(s.is_set());
        // Idempotent.
        s.trigger();
        assert!(s.is_set());
    }

    #[test]
    fn metrics_render_is_prometheus_text() {
        let metrics = Arc::new(Metrics::default());
        let registry = Registry::new(4, metrics.clone());
        Metrics::add(&metrics.runs_submitted, 3);
        let text = metrics.render(&registry);
        assert!(text.contains("# TYPE polca_runs_submitted_total counter"));
        assert!(text.contains("polca_runs_submitted_total 3\n"));
        assert!(text.contains("# TYPE polca_runs_queued gauge"));
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }
}
