//! The live run driver: executes queued scenarios on run-queue worker
//! threads, pacing the discrete-event simulator against wall-clock at
//! a configurable time-warp and broadcasting the control loop's
//! observation stream to SSE subscribers.
//!
//! Pacing and broadcasting are both implemented as passive
//! [`Observer`]s composed with [`obs::Tee`](crate::obs::Tee):
//!
//! * [`Pacer`] sleeps just enough that simulated time never runs ahead
//!   of `wall_elapsed × warp` — `--time-warp 60` replays one simulated
//!   minute per wall second; warp `0` (the default) runs unpaced.
//! * [`Broadcaster`] converts each event/sample/counter into the same
//!   JSON record schema as [`Trace::records`](crate::obs::Trace::records)
//!   and publishes it to the run's [`EventHub`].
//!
//! Observation is passive by the PR 6 contract, so a gateway run's
//! [`ScenarioReport`](crate::scenario::ScenarioReport) is bit-identical
//! to a direct in-process `Scenario::run()` — which is what makes the
//! byte-identical report guarantee of `GET /runs/:id` testable.
//!
//! Site and region scenarios have no single simulation to observe
//! (`Scenario::run_observed` refuses them), so they execute unobserved
//! and their event stream carries only the meta and terminal status
//! records.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{EventKind, Observer, SeriesId, Tee};
use crate::scenario::{error_report_json, Scenario};
use crate::util::json::Json;

use super::state::{EventHub, Metrics, Registry};

/// Longest single sleep slice while pacing, so a paced run still
/// notices shutdown promptly.
const PACE_SLICE: Duration = Duration::from_millis(100);

/// An [`Observer`] that holds simulated time at or below
/// `wall_elapsed × warp`. Emits nothing; composes with a
/// [`Broadcaster`] through [`Tee`](crate::obs::Tee).
pub struct Pacer {
    warp: f64,
    started: Instant,
    shutdown: Arc<AtomicBool>,
}

impl Pacer {
    /// New pacer; `warp <= 0` disables pacing entirely. `shutdown`
    /// cancels remaining sleeps so the daemon can stop mid-run.
    pub fn new(warp: f64, shutdown: Arc<AtomicBool>) -> Pacer {
        Pacer { warp, started: Instant::now(), shutdown }
    }

    fn pace(&self, t_s: f64) {
        if self.warp <= 0.0 || !t_s.is_finite() {
            return;
        }
        let target = Duration::from_secs_f64((t_s / self.warp).max(0.0));
        loop {
            let elapsed = self.started.elapsed();
            if elapsed >= target || self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep((target - elapsed).min(PACE_SLICE));
        }
    }
}

impl Observer for Pacer {
    fn event(&mut self, t_s: f64, _kind: EventKind) {
        self.pace(t_s);
    }

    fn sample(&mut self, _id: SeriesId, t_s: f64, _value: f64) {
        self.pace(t_s);
    }
}

/// An [`Observer`] that serializes every observation into the trace
/// record schema and publishes it to the run's [`EventHub`].
pub struct Broadcaster<'a> {
    hub: &'a EventHub,
    /// `settle()` is the hot path; counted locally and folded into the
    /// daemon metrics once at end of run.
    settles: u64,
    events_dispatched: u64,
}

impl<'a> Broadcaster<'a> {
    /// New broadcaster publishing into `hub`.
    pub fn new(hub: &'a EventHub) -> Broadcaster<'a> {
        Broadcaster { hub, settles: 0, events_dispatched: 0 }
    }

    /// Fold the locally-accumulated hot-path counts into `metrics`.
    pub fn fold_into(&self, metrics: &Metrics) {
        Metrics::add(&metrics.sim_settles, self.settles);
        Metrics::add(&metrics.sim_events, self.events_dispatched);
    }
}

impl Observer for Broadcaster<'_> {
    fn event(&mut self, t_s: f64, kind: EventKind) {
        self.hub.publish(crate::obs::Event { t_s, kind }.to_record().to_string());
    }

    fn sample(&mut self, id: SeriesId, t_s: f64, value: f64) {
        self.hub.publish(
            Json::obj(vec![
                ("type", Json::Str("sample".to_string())),
                ("t_s", Json::num(t_s)),
                ("series", Json::Str(id.name().to_string())),
                ("v", Json::num(value)),
            ])
            .to_string(),
        );
    }

    fn settle(&mut self) {
        self.settles += 1;
    }

    fn counter(&mut self, name: &'static str, value: u64) {
        if name == "events-dispatched" {
            self.events_dispatched += value;
        }
        self.hub.publish(
            Json::obj(vec![
                ("type", Json::Str("counter".to_string())),
                ("name", Json::Str(name.to_string())),
                ("v", Json::num(value as f64)),
            ])
            .to_string(),
        );
    }
}

/// Dequeue-and-run loop for one run-queue worker thread; returns when
/// the registry closes.
pub fn run_worker(
    registry: Arc<Registry>,
    metrics: Arc<Metrics>,
    warp: f64,
    shutdown: Arc<AtomicBool>,
) {
    while let Some((id, sc, hub)) = registry.claim() {
        run_one(&id, &sc, &hub, &registry, &metrics, warp, &shutdown);
    }
}

/// Execute one claimed run end to end: meta record, observed (or
/// plain) execution, terminal status record, registry finish.
pub fn run_one(
    id: &str,
    sc: &Scenario,
    hub: &EventHub,
    registry: &Registry,
    metrics: &Metrics,
    warp: f64,
    shutdown: &Arc<AtomicBool>,
) {
    hub.publish(
        Json::obj(vec![
            ("type", Json::Str("meta".to_string())),
            ("name", Json::Str(sc.name.clone())),
            ("run", Json::Str(id.to_string())),
            ("warp", Json::num(warp.max(0.0))),
        ])
        .to_string(),
    );
    let observable = sc.site.is_none() && sc.region.is_none();
    let result = if observable {
        let mut pacer = Pacer::new(warp, shutdown.clone());
        let mut caster = Broadcaster::new(hub);
        let outcome = sc.run_observed(&mut Tee(&mut pacer, &mut caster));
        caster.fold_into(metrics);
        outcome
    } else {
        sc.run()
    };
    match result {
        Ok(mut report) => {
            let body = format!("{}\n", report.to_json().to_pretty());
            hub.publish(status_record(id, "done", None));
            registry.finish(id, Ok(body));
        }
        Err(e) => {
            let body = format!("{}\n", error_report_json(&sc.name, &e).to_pretty());
            hub.publish(status_record(id, "failed", Some(&format!("{e:#}"))));
            registry.finish(id, Err(body));
        }
    }
}

/// The stream-terminating record: `{"type":"status", "run":..,
/// "status":"done"|"failed"[, "error":..]}`.
fn status_record(id: &str, status: &str, error: Option<&str>) -> String {
    let mut pairs = vec![
        ("type", Json::Str("status".to_string())),
        ("run", Json::Str(id.to_string())),
        ("status", Json::Str(status.to_string())),
    ];
    if let Some(e) = error {
        pairs.push(("error", Json::Str(e.to_string())));
    }
    Json::obj(pairs).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::preset;

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    #[test]
    fn broadcaster_emits_trace_schema_records() {
        let hub = EventHub::new(1, metrics());
        let (sub, _) = hub.subscribe();
        {
            let mut b = Broadcaster::new(&hub);
            b.event(2.0, EventKind::BrakeEngaged);
            b.sample(SeriesId::RowPower, 2.5, 0.8);
            b.counter("events-dispatched", 9);
            b.settle();
            assert_eq!(b.events_dispatched, 9);
            assert_eq!(b.settles, 1);
        }
        let recs = match hub.next(sub, Duration::from_millis(100)) {
            super::super::state::SubNext::Records(rs) => rs,
            other => panic!("expected records, got {other:?}"),
        };
        assert_eq!(recs.len(), 3);
        let types: Vec<String> = recs
            .iter()
            .map(|r| {
                crate::util::json::parse(r)
                    .unwrap()
                    .get("type")
                    .and_then(Json::as_str)
                    .unwrap()
                    .to_string()
            })
            .collect();
        assert_eq!(types, ["event", "sample", "counter"]);
    }

    #[test]
    fn run_one_produces_the_in_process_report_byte_for_byte() {
        let mut sc = preset("oversubscribed-row").unwrap();
        sc.weeks = 0.01;
        let metrics = metrics();
        let registry = Arc::new(Registry::new(4, metrics.clone()));
        let view = registry.submit(sc.clone()).unwrap();
        let (id, claimed, hub) = registry.claim().unwrap();
        assert_eq!(id, view.id);
        let shutdown = Arc::new(AtomicBool::new(false));
        run_one(&id, &claimed, &hub, &registry, &metrics, 0.0, &shutdown);
        let done = registry.get(&id).unwrap();
        assert_eq!(done.status, super::super::state::RunStatus::Done);
        let mut expected = sc.run().unwrap();
        let expected = format!("{}\n", expected.to_json().to_pretty());
        assert_eq!(done.body.as_deref().map(|s| s.as_str()), Some(expected.as_str()));
    }

    #[test]
    fn pacer_holds_sim_time_to_the_warp() {
        // 1 simulated second at warp 100 must take ~10ms of wall time.
        let mut p = Pacer::new(100.0, Arc::new(AtomicBool::new(false)));
        let t0 = Instant::now();
        p.event(1.0, EventKind::BrakeEngaged);
        assert!(t0.elapsed() >= Duration::from_millis(8), "pacer did not sleep");
        // Unpaced: no sleep at all.
        let mut p = Pacer::new(0.0, Arc::new(AtomicBool::new(false)));
        let t0 = Instant::now();
        p.event(1e9, EventKind::BrakeEngaged);
        assert!(t0.elapsed() < Duration::from_millis(50));
    }
}
