//! Hand-rolled HTTP/1.1 layer for the gateway daemon: listener,
//! request parser, bounded accept queue, fixed worker-thread pool,
//! keep-alive, and graceful shutdown — `std::net` only, no external
//! dependencies. A minimal loopback client for the bench harness and
//! the integration tests lives here too.
//!
//! Backpressure contract: the acceptor thread never blocks on slow
//! handlers — accepted connections land in a bounded queue that the
//! fixed worker pool drains. When the queue is full the acceptor sheds
//! the connection immediately with `503 Service Unavailable` (and a
//! `Retry-After` hint) instead of letting the accept backlog grow
//! unboundedly. Run-queue saturation is a separate, higher layer and
//! answers `429` (see `gateway::api`).
//!
//! Shutdown contract: `Server::shutdown` flips the shared flag, wakes
//! the acceptor with a loopback connect, and closes the connection
//! queue; `Server::join` then joins the acceptor and every worker.
//! Handlers observe the flag between requests (and streaming handlers
//! poll it), so all threads exit within one poll interval.

use std::collections::VecDeque;
use std::io::{self, BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Header-section bound (request line + headers) before `431`-style
/// rejection; generous for hand-written clients.
const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Request-body bound before rejection with `413` (scenario TOML files
/// are a few KiB; 1 MiB is far beyond any legitimate submission).
const MAX_BODY_BYTES: usize = 1024 * 1024;

/// Idle keep-alive read timeout: a worker parked on a quiet connection
/// returns it after this long (also bounds shutdown latency).
pub const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Uppercase method (`GET`, `POST`, ...).
    pub method: String,
    /// Path with any query string split off.
    pub path: String,
    /// Raw query string (may be empty).
    pub query: String,
    /// Headers with lowercased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Request body (`Content-Length` bytes; empty when absent).
    pub body: Vec<u8>,
}

impl Request {
    /// First header value for lowercased `name`.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Body decoded as UTF-8 (lossy; scenario codecs re-validate).
    pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
        String::from_utf8_lossy(&self.body)
    }

    /// Whether the client asked to drop the connection after this
    /// response (`Connection: close`).
    pub fn wants_close(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }
}

/// Outcome of reading one request off a keep-alive connection.
#[derive(Debug)]
pub enum ReadOutcome {
    /// Clean EOF between requests (client hung up).
    Eof,
    /// A complete, well-formed request.
    Request(Request),
    /// Malformed or over-limit input: respond with this status and
    /// message, then close.
    Bad(u16, &'static str),
}

/// Read one request from a buffered connection. I/O errors (including
/// read timeouts on idle keep-alive connections) surface as `Err` and
/// close the connection.
pub fn read_request<R: BufRead>(r: &mut R) -> io::Result<ReadOutcome> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(ReadOutcome::Eof);
    }
    let mut parts = line.split_whitespace();
    let (Some(method), Some(target), Some(version)) = (parts.next(), parts.next(), parts.next())
    else {
        return Ok(ReadOutcome::Bad(400, "malformed request line"));
    };
    if !version.starts_with("HTTP/1.") {
        return Ok(ReadOutcome::Bad(505, "HTTP/1.x only"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let method = method.to_ascii_uppercase();

    let mut headers = Vec::new();
    let mut header_bytes = line.len();
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Ok(ReadOutcome::Bad(400, "connection closed mid-headers"));
        }
        header_bytes += h.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Ok(ReadOutcome::Bad(431, "header section too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let Some((k, v)) = h.split_once(':') else {
            return Ok(ReadOutcome::Bad(400, "malformed header"));
        };
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse::<usize>())
        .transpose()
        .map_err(|_| io::Error::new(ErrorKind::InvalidData, "bad content-length"));
    let content_length = match content_length {
        Ok(v) => v.unwrap_or(0),
        Err(_) => return Ok(ReadOutcome::Bad(400, "unparseable content-length")),
    };
    if content_length > MAX_BODY_BYTES {
        return Ok(ReadOutcome::Bad(413, "request body too large"));
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)?;
    Ok(ReadOutcome::Request(Request { method, path, query, headers, body }))
}

/// Canonical reason phrase for the status codes the gateway emits.
pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

/// Write a complete response (status line, `Content-Length`, body) and
/// flush. `extra` headers are appended verbatim.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, &str)],
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        status,
        status_reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in extra {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Bounded handoff from the acceptor to the worker pool.
struct ConnQueue {
    cap: usize,
    inner: Mutex<(VecDeque<TcpStream>, bool)>,
    cv: Condvar,
}

impl ConnQueue {
    fn new(cap: usize) -> ConnQueue {
        ConnQueue { cap: cap.max(1), inner: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() }
    }

    /// Hand a connection to the pool; gives it back when full or closed
    /// (the acceptor sheds it with `503`).
    fn push(&self, stream: TcpStream) -> Result<(), TcpStream> {
        let mut g = self.inner.lock().unwrap();
        if g.1 || g.0.len() >= self.cap {
            return Err(stream);
        }
        g.0.push_back(stream);
        drop(g);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained.
    fn pop(&self) -> Option<TcpStream> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(s) = g.0.pop_front() {
                return Some(s);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    fn close(&self) {
        self.inner.lock().unwrap().1 = true;
        self.cv.notify_all();
    }
}

/// Tuning for [`serve`].
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Worker threads handling connections (SSE subscribers each hold
    /// one for the life of their stream).
    pub workers: usize,
    /// Accepted-connection queue bound; beyond it the acceptor sheds
    /// with `503`.
    pub accept_queue: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig { addr: "127.0.0.1:0".to_string(), workers: 8, accept_queue: 64 }
    }
}

/// The connection handler worker threads run per request: write the
/// response (or stream) to `stream`, return whether the connection may
/// be kept alive for another request.
pub type Handler = dyn Fn(&Request, &mut TcpStream) -> io::Result<bool> + Send + Sync;

/// A running HTTP server: the bound address plus the thread handles
/// needed for a graceful stop.
pub struct Server {
    /// The actual bound address (resolves `:0` bindings).
    pub local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    queue: Arc<ConnQueue>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// Connections shed with 503 since start (acceptor-side counter,
    /// folded into `/metrics` by the gateway).
    pub shed: Arc<std::sync::atomic::AtomicU64>,
    /// Connections accepted into the pool since start.
    pub accepted: Arc<std::sync::atomic::AtomicU64>,
}

impl Server {
    /// Bind and start the acceptor + worker pool. `handler` runs once
    /// per parsed request on a worker thread.
    pub fn start(cfg: &HttpConfig, handler: Arc<Handler>) -> io::Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(ConnQueue::new(cfg.accept_queue));
        let shed = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let accepted = Arc::new(std::sync::atomic::AtomicU64::new(0));

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let shutdown = shutdown.clone();
            let handler = handler.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("gw-http-{i}"))
                    .spawn(move || {
                        while let Some(stream) = queue.pop() {
                            let _ = handle_connection(stream, &shutdown, handler.as_ref());
                        }
                    })
                    .expect("spawn http worker"),
            );
        }

        let acceptor = {
            let queue = queue.clone();
            let shutdown = shutdown.clone();
            let shed = shed.clone();
            let accepted = accepted.clone();
            std::thread::Builder::new()
                .name("gw-accept".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = conn else { continue };
                        match queue.push(stream) {
                            Ok(()) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(mut stream) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                                let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
                                let _ = write_response(
                                    &mut stream,
                                    503,
                                    "application/json",
                                    b"{\"error\": \"accept queue full\"}\n",
                                    false,
                                    &[("Retry-After", "1")],
                                );
                            }
                        }
                    }
                })
                .expect("spawn acceptor")
        };

        Ok(Server {
            local_addr,
            shutdown,
            queue,
            acceptor: Some(acceptor),
            workers,
            shed,
            accepted,
        })
    }

    /// Begin a graceful stop: flag shutdown, wake the acceptor with a
    /// loopback connect, close the worker queue. Idempotent.
    pub fn shutdown(&self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // The acceptor is parked in accept(); a throwaway connection
        // wakes it so it can observe the flag and exit.
        let _ = TcpStream::connect_timeout(&self.local_addr, Duration::from_millis(500));
        self.queue.close();
    }

    /// Join the acceptor and every worker (call after [`shutdown`]).
    pub fn join(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Serve one connection: parse requests in a keep-alive loop, handing
/// each to the handler until EOF, error, `Connection: close`, or
/// shutdown.
fn handle_connection(
    stream: TcpStream,
    shutdown: &AtomicBool,
    handler: &(dyn Fn(&Request, &mut TcpStream) -> io::Result<bool> + Send + Sync),
) -> io::Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    stream.set_nodelay(true).ok();
    let mut write_half = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match read_request(&mut reader) {
            Ok(ReadOutcome::Eof) => return Ok(()),
            Ok(ReadOutcome::Bad(status, msg)) => {
                let body = format!("{{\"error\": \"{msg}\"}}\n");
                write_response(&mut write_half, status, "application/json", body.as_bytes(), false, &[])?;
                return Ok(());
            }
            Ok(ReadOutcome::Request(req)) => {
                let keep = handler(&req, &mut write_half)?;
                if !keep || req.wants_close() || shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            // Idle keep-alive timeout (or client reset): return the
            // worker to the pool.
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                return Ok(())
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// Loopback client (bench harness, integration tests, CI smoke).

/// A keep-alive HTTP/1.1 client connection for loopback testing.
pub struct Client {
    write_half: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to `addr`.
    pub fn connect(addr: SocketAddr) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let write_half = stream.try_clone()?;
        Ok(Client { write_half, reader: BufReader::new(stream) })
    }

    /// Issue one request on the kept-alive connection and read the full
    /// response. Returns `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        content_type: Option<&str>,
        body: &[u8],
    ) -> io::Result<(u16, String)> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: polca-gateway\r\n");
        if let Some(ct) = content_type {
            head.push_str(&format!("Content-Type: {ct}\r\n"));
        }
        head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
        self.write_half.write_all(head.as_bytes())?;
        self.write_half.write_all(body)?;
        self.write_half.flush()?;
        read_client_response(&mut self.reader)
    }
}

/// Read a response (status line, headers, `Content-Length` body — or
/// read-to-EOF when the server closes the connection).
fn read_client_response<R: BufRead>(r: &mut R) -> io::Result<(u16, String)> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Err(io::Error::new(ErrorKind::UnexpectedEof, "no status line"));
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| io::Error::new(ErrorKind::InvalidData, format!("bad status line {line:?}")))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Err(io::Error::new(ErrorKind::UnexpectedEof, "eof in headers"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().ok();
            }
        }
    }
    let body = match content_length {
        Some(n) => {
            let mut buf = vec![0u8; n];
            r.read_exact(&mut buf)?;
            buf
        }
        None => {
            let mut buf = Vec::new();
            r.read_to_end(&mut buf)?;
            buf
        }
    };
    Ok((status, String::from_utf8_lossy(&body).into_owned()))
}

/// One-shot request on a fresh connection (`Connection: close`).
pub fn request_once(
    addr: SocketAddr,
    method: &str,
    path: &str,
    content_type: Option<&str>,
    body: &[u8],
) -> io::Result<(u16, String)> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut w = stream.try_clone()?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: polca-gateway\r\nConnection: close\r\n");
    if let Some(ct) = content_type {
        head.push_str(&format!("Content-Type: {ct}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    read_client_response(&mut BufReader::new(stream))
}

/// Subscribe to a Server-Sent-Events endpoint and collect the payloads
/// of up to `max_records` `data:` lines, stopping early when the
/// server closes the stream. Returns the raw JSON payload strings.
pub fn sse_collect(
    addr: SocketAddr,
    path: &str,
    max_records: usize,
    timeout: Duration,
) -> io::Result<Vec<String>> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    let mut w = stream.try_clone()?;
    w.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: polca-gateway\r\nAccept: text/event-stream\r\nConnection: close\r\n\r\n")
            .as_bytes(),
    )?;
    w.flush()?;
    let mut r = BufReader::new(stream);
    // Status line + headers.
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Err(io::Error::new(ErrorKind::UnexpectedEof, "no status line"));
    }
    if !line.contains("200") {
        return Err(io::Error::new(ErrorKind::InvalidData, format!("sse status {line:?}")));
    }
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            return Err(io::Error::new(ErrorKind::UnexpectedEof, "eof in sse headers"));
        }
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut out = Vec::new();
    while out.len() < max_records {
        let mut l = String::new();
        match r.read_line(&mut l) {
            Ok(0) => break,
            Ok(_) => {
                if let Some(payload) = l.trim_end().strip_prefix("data: ") {
                    out.push(payload.to_string());
                }
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
            Err(e) => return Err(e),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_request_with_body_and_query() {
        let raw = "POST /scenarios?warp=2 HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 4\r\n\r\nabcd";
        let mut r = Cursor::new(raw.as_bytes());
        match read_request(&mut r).unwrap() {
            ReadOutcome::Request(req) => {
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/scenarios");
                assert_eq!(req.query, "warp=2");
                assert_eq!(req.header("content-type"), Some("application/json"));
                assert_eq!(req.body, b"abcd");
                assert!(!req.wants_close());
            }
            other => panic!("expected request, got {other:?}"),
        }
    }

    #[test]
    fn eof_malformed_and_oversize_are_distinguished() {
        assert!(matches!(read_request(&mut Cursor::new(b"")).unwrap(), ReadOutcome::Eof));
        assert!(matches!(
            read_request(&mut Cursor::new(b"garbage\r\n\r\n" as &[u8])).unwrap(),
            ReadOutcome::Bad(400, _)
        ));
        let big = format!("GET / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(
            read_request(&mut Cursor::new(big.as_bytes())).unwrap(),
            ReadOutcome::Bad(413, _)
        ));
        assert!(matches!(
            read_request(&mut Cursor::new(b"GET / SPDY/3\r\n\r\n" as &[u8])).unwrap(),
            ReadOutcome::Bad(505, _)
        ));
    }

    #[test]
    fn response_writer_sets_length_and_connection() {
        let mut buf = Vec::new();
        write_response(&mut buf, 202, "application/json", b"{}", true, &[("X-Run", "run-000001")])
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 202 Accepted\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.contains("X-Run: run-000001\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
