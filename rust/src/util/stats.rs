//! Statistics primitives used throughout the evaluation: streaming moments,
//! exact percentiles, histograms, sliding-window spike statistics (the
//! paper's "max power spike in 2s/5s/40s", Table 2) and MAPE (the paper's
//! trace-replication fidelity metric, §6.1).

/// Streaming mean/variance/min/max (Welford).
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    /// Empty accumulator.
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }
    /// Running mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }
    /// Sample variance (0 below two observations).
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }
    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact percentile over a sample set (collects values; fine for the
/// per-request metrics this crate produces — a few 1e6 points max).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    /// Empty sample set.
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.xs.len()
    }
    /// Whether no samples were added.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// p in [0, 100]; linear interpolation between order statistics.
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = (p / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi.min(n - 1)] * frac
    }

    /// Median.
    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    /// 90th percentile.
    pub fn p90(&mut self) -> f64 {
        self.percentile(90.0)
    }
    /// 99th percentile.
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
    /// Largest sample.
    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }
    /// Arithmetic mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the
/// edge bins. Used for power-distribution figures.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
}

impl Histogram {
    /// `nbins` equal bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins] }
    }

    /// Count one value (clamped to the edge bins).
    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (t.max(0.0) as usize).min(n - 1);
        self.bins[idx] += 1;
    }

    /// Raw bin counts.
    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    /// Center value of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) / self.bins.len() as f64 * (self.hi - self.lo)
    }

    /// Total counted values.
    pub fn total(&self) -> u64 {
        self.bins.iter().sum()
    }
}

/// Mean Absolute Percentage Error between two equally-sampled series —
/// the paper reports MAPE < 3% between the synthetic and original power
/// timeseries (§6.1).
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let mut sum = 0.0;
    let mut n = 0u64;
    for (&a, &p) in actual.iter().zip(predicted) {
        if a.abs() > 1e-12 {
            sum += ((a - p) / a).abs();
            n += 1;
        }
    }
    if n == 0 { f64::NAN } else { 100.0 * sum / n as f64 }
}

/// Max *rise* of a series within any window of `window` samples:
/// max over i of (max(x[i..i+window]) - x[i]), expressed in the series'
/// units. This is Table 2's "max power spike in Ns" statistic.
pub fn max_rise_within(xs: &[f64], window: usize) -> f64 {
    if xs.len() < 2 || window == 0 {
        return 0.0;
    }
    // O(n * window); windows here are small (40s at 2s sampling = 20).
    let mut best = 0.0f64;
    for i in 0..xs.len() - 1 {
        let end = (i + window).min(xs.len() - 1);
        let mut mx = f64::NEG_INFINITY;
        for &x in &xs[i + 1..=end] {
            mx = mx.max(x);
        }
        best = best.max(mx - xs[i]);
    }
    best
}

/// Time-weighted average of a step function given (time, value) change
/// points, over [t0, t1]. Values hold until the next change point.
pub fn time_weighted_mean(points: &[(f64, f64)], t0: f64, t1: f64) -> f64 {
    assert!(t1 > t0);
    if points.is_empty() {
        return f64::NAN;
    }
    let mut acc = 0.0;
    let mut cur_val = points[0].1;
    let mut cur_t = t0;
    for &(t, v) in points {
        if t <= t0 {
            cur_val = v;
            continue;
        }
        if t >= t1 {
            break;
        }
        acc += cur_val * (t - cur_t);
        cur_t = t;
        cur_val = v;
    }
    acc += cur_val * (t1 - cur_t);
    acc / (t1 - t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_moments() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn percentiles_basic() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.p50() - 50.5).abs() < 1e-9);
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((p.max() - 100.0).abs() < 1e-12);
        assert!((p.p99() - 99.01).abs() < 0.02);
    }

    #[test]
    fn percentiles_single_and_empty() {
        let mut p = Percentiles::new();
        assert!(p.p50().is_nan());
        p.push(3.0);
        assert_eq!(p.p50(), 3.0);
        assert_eq!(p.p99(), 3.0);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(-5.0);
        h.push(0.5);
        h.push(9.9);
        h.push(50.0);
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[9], 2);
        assert_eq!(h.total(), 4);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mape_exact_and_offset() {
        let a = vec![1.0, 2.0, 4.0];
        assert_eq!(mape(&a, &a), 0.0);
        let b = vec![1.1, 2.2, 4.4];
        assert!((mape(&a, &b) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn max_rise_finds_spike() {
        // flat, then a spike of +0.5 three samples later
        let xs = vec![0.5, 0.5, 0.5, 0.5, 1.0, 0.5, 0.5];
        assert!((max_rise_within(&xs, 4) - 0.5).abs() < 1e-12);
        // window of 1: only adjacent rises
        let xs2 = vec![0.0, 0.2, 0.5, 0.6];
        assert!((max_rise_within(&xs2, 1) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn max_rise_monotone_in_window() {
        let mut rng = crate::util::rng::Rng::new(5);
        let xs: Vec<f64> = (0..500).map(|_| rng.f64()).collect();
        let r1 = max_rise_within(&xs, 2);
        let r2 = max_rise_within(&xs, 10);
        let r3 = max_rise_within(&xs, 100);
        assert!(r1 <= r2 + 1e-12 && r2 <= r3 + 1e-12, "{r1} {r2} {r3}");
    }

    #[test]
    fn max_rise_ignores_falls() {
        let xs = vec![1.0, 0.8, 0.6, 0.4];
        assert_eq!(max_rise_within(&xs, 3), 0.0);
    }

    #[test]
    fn time_weighted_mean_step() {
        // value 1.0 on [0,5), 3.0 on [5,10) -> mean 2.0
        let pts = vec![(0.0, 1.0), (5.0, 3.0)];
        assert!((time_weighted_mean(&pts, 0.0, 10.0) - 2.0).abs() < 1e-12);
        // window entirely after last change point
        assert!((time_weighted_mean(&pts, 6.0, 8.0) - 3.0).abs() < 1e-12);
    }
}
