//! General-purpose substrates written in-tree.
//!
//! The build environment is fully offline and the vendored registry only
//! carries the `xla` crate's dependency closure, so the usual ecosystem
//! crates (`rand`, `serde`, `clap`, `criterion`, `proptest`) are not
//! available. Everything the system needs from them is implemented here,
//! scoped to exactly what the reproduction requires.

pub mod cli;
pub mod csv;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
