//! Minimal command-line argument parser (no `clap` offline).
//!
//! Model: `polca <subcommand> [positionals...] [--key value | --flag]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare argument (the subcommand).
    pub subcommand: Option<String>,
    /// Remaining bare arguments.
    pub positionals: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse the process's arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as f64, or `default`.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as usize, or `default`.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as u64, or `default`.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["figure", "fig13", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["fig13", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["run", "--seed", "7", "--out-dir=out", "--verbose"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("out-dir"), Some("out"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--safe"]);
        assert!(a.flag("fast") && a.flag("safe"));
        assert!(a.options.is_empty());
    }
}
