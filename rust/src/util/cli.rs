//! Minimal command-line argument parser (no `clap` offline), plus the
//! flag-parsing helpers shared by every `polca` subcommand: policy
//! parsing ([`parse_policy`] / [`parse_policies`]) and the
//! `set_*` overlay methods that replace the per-subcommand
//! `cfg.x = args.get_*(...)` loops with one call per knob.
//!
//! Model: `polca <subcommand> [positionals...] [--key value | --flag]`.

use std::collections::BTreeMap;

use crate::policy::engine::PolicyKind;

/// Parse a `--policy` value; the slugs are [`PolicyKind::slug`]s.
pub fn parse_policy(s: &str) -> anyhow::Result<PolicyKind> {
    PolicyKind::from_slug(s)
        .ok_or_else(|| anyhow::anyhow!("unknown policy '{s}' (polca|1t-lp|1t-all|nocap)"))
}

/// Parse a `--policy` value that may also be `all` (the comparison set).
pub fn parse_policies(s: &str) -> anyhow::Result<Vec<PolicyKind>> {
    if s == "all" {
        Ok(PolicyKind::all().to_vec())
    } else {
        Ok(vec![parse_policy(s)?])
    }
}

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare argument (the subcommand).
    pub subcommand: Option<String>,
    /// Remaining bare arguments.
    pub positionals: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse the process's arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` parsed as f64, or `default`.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as usize, or `default`.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--name` parsed as u64, or `default`.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// The `--policy` option parsed as one [`PolicyKind`] (`default` is
    /// a slug, used when the option is absent).
    pub fn policy(&self, default: &str) -> anyhow::Result<PolicyKind> {
        parse_policy(self.get_or("policy", default))
    }

    /// The `--policy` option parsed as a policy set (`all` expands to
    /// the full comparison set).
    pub fn policies(&self, default: &str) -> anyhow::Result<Vec<PolicyKind>> {
        parse_policies(self.get_or("policy", default))
    }

    /// Overwrite `slot` with `--name` when present and parseable.
    pub fn set_f64(&self, name: &str, slot: &mut f64) {
        if let Some(v) = self.get(name).and_then(|s| s.parse().ok()) {
            *slot = v;
        }
    }

    /// Overwrite `slot` with `--name` when present and parseable.
    pub fn set_usize(&self, name: &str, slot: &mut usize) {
        if let Some(v) = self.get(name).and_then(|s| s.parse().ok()) {
            *slot = v;
        }
    }

    /// Overwrite `slot` with `--name` when present and parseable.
    pub fn set_u64(&self, name: &str, slot: &mut u64) {
        if let Some(v) = self.get(name).and_then(|s| s.parse().ok()) {
            *slot = v;
        }
    }

    /// Overwrite `slot` with `--name` when present and parseable.
    pub fn set_u32(&self, name: &str, slot: &mut u32) {
        if let Some(v) = self.get(name).and_then(|s| s.parse().ok()) {
            *slot = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["figure", "fig13", "extra"]);
        assert_eq!(a.subcommand.as_deref(), Some("figure"));
        assert_eq!(a.positionals, vec!["fig13", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["run", "--seed", "7", "--out-dir=out", "--verbose"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("out-dir"), Some("out"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--fast", "--safe"]);
        assert!(a.flag("fast") && a.flag("safe"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn policy_helpers_share_the_slug_set() {
        let a = parse(&["run", "--policy", "1t-lp"]);
        assert_eq!(a.policy("polca").unwrap(), PolicyKind::OneThreshLowPri);
        // default applies when the option is absent
        assert_eq!(parse(&["run"]).policy("nocap").unwrap(), PolicyKind::NoCap);
        assert!(parse(&["run", "--policy", "bogus"]).policy("polca").is_err());
        assert_eq!(parse(&["run", "--policy", "all"]).policies("polca").unwrap().len(), 4);
        assert_eq!(parse(&["run"]).policies("polca").unwrap(), vec![PolicyKind::Polca]);
        // every slug round-trips
        for k in PolicyKind::all() {
            assert_eq!(parse_policy(k.slug()).unwrap(), k);
        }
    }

    #[test]
    fn set_overlays_only_when_present() {
        let a = parse(&["run", "--weeks", "0.5", "--servers", "16", "--step", "bad"]);
        let mut weeks = 1.0;
        let mut servers = 40usize;
        let mut seed = 7u64;
        let mut step = 2u32;
        a.set_f64("weeks", &mut weeks);
        a.set_usize("servers", &mut servers);
        a.set_u64("seed", &mut seed);
        a.set_u32("step", &mut step);
        assert_eq!(weeks, 0.5);
        assert_eq!(servers, 16);
        assert_eq!(seed, 7, "absent option must not disturb the default");
        assert_eq!(step, 2, "unparseable option must not disturb the default");
    }
}
