//! Minimal JSON value model, parser, and writer.
//!
//! Needed because `serde`/`serde_json` are unavailable offline. Two real
//! consumers: [`crate::runtime`] parses `artifacts/manifest.json` produced
//! by the Python AOT path, and [`crate::experiments`] emits figure data as
//! JSON for plotting.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number (all JSON numbers are f64 here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Canonical number constructor: non-finite values become
    /// [`Json::Null`] at construction. The writer already renders a
    /// non-finite `Json::Num` as `null` (JSON has no inf/nan), but a
    /// value built through `num` also *compares* and parses back as
    /// null — use this in `to_json` impls for any quantity that can be
    /// non-finite (e.g. an uncontained incident's time-to-contain).
    pub fn num(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }

    /// Numeric array from a slice (non-finite entries become null,
    /// as with [`Json::num`]).
    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::num(x)).collect())
    }

    /// Object member access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["model", "vocab"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Numeric view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric view truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Numeric view truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Boolean view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the raw input.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| "bad utf8".to_string())?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.as_arr(), None);
        assert_eq!(
            v.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\nb\tA é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\tA é"));
        // writer escapes control chars
        let s = Json::Str("x\ny".into()).to_string();
        assert_eq!(s, "\"x\\ny\"");
    }

    #[test]
    fn numbers_with_exponents() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-2.5e-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_real_manifest_shape() {
        let src = r#"{"format_version":1,"model":{"vocab":512,"d_model":128},
                      "params":[{"name":"tok_emb","shape":[512,128],"byte_offset":0}],
                      "flops":{"prefill_s16":123456789}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.at(&["model", "vocab"]).unwrap().as_usize(), Some(512));
        assert_eq!(
            v.at(&["params"]).unwrap().as_arr().unwrap()[0].get("name").unwrap().as_str(),
            Some("tok_emb")
        );
        assert_eq!(v.at(&["flops", "prefill_s16"]).unwrap().as_i64(), Some(123456789));
        // pretty-printing round-trips
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn nonfinite_numbers_are_null_everywhere() {
        // The writer renders a raw non-finite Num as null ...
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        // ... and the canonical constructor normalizes at build time,
        // so values round-trip through parse() consistently.
        assert_eq!(Json::num(f64::INFINITY), Json::Null);
        assert_eq!(Json::num(f64::NAN), Json::Null);
        assert_eq!(Json::num(1.5), Json::Num(1.5));
        let arr = Json::num_arr(&[1.0, f64::INFINITY, 3.0]);
        assert_eq!(arr.to_string(), "[1,null,3]");
        assert_eq!(parse(&arr.to_string()).unwrap(), arr);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string(), "[]");
    }
}
