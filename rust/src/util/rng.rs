//! Deterministic pseudo-random number generation and distributions.
//!
//! All simulations in this crate are seeded and fully reproducible: the
//! same seed yields bit-identical traces, which the evaluation relies on
//! when comparing policies on *the same* workload realization (paper §6.3
//! compares POLCA vs baselines on the same five-week trace).
//!
//! Generator: xoshiro256** (Blackman & Vigna), seeded via splitmix64 —
//! high-quality, fast, and trivially portable.

/// Deterministic RNG (xoshiro256**).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box–Muller pair.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-actor RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.fork_seed(stream))
    }

    /// The seed [`fork`](Self::fork) would hand the child for `stream`,
    /// consuming the parent identically. Lets callers memoize work
    /// derived from a fork (key on the seed, construct `Rng::new(seed)`
    /// only on a miss) without perturbing the parent's stream position.
    pub fn fork_seed(&mut self, stream: u64) -> u64 {
        self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli draw with the given success probability.
    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal draw with the given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the given *underlying* normal parameters.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given rate (mean = 1/rate).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Poisson-distributed count. Knuth for small lambda, normal
    /// approximation (rounded, clamped) for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal();
            (lambda + lambda.sqrt() * z).round().max(0.0) as u64
        }
    }

    /// Index sampled from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 800, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_and_large_lambda() {
        let mut r = Rng::new(17);
        for &lambda in &[0.5, 3.0, 20.0, 100.0, 500.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.sqrt() * 0.15 + 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
        assert_eq!(r.poisson(0.0), 0);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let mut counts = [0u32; 3];
        for _ in 0..60_000 {
            counts[r.categorical(&[1.0, 2.0, 3.0])] += 1;
        }
        assert!((counts[0] as f64 / 10_000.0 - 1.0).abs() < 0.1);
        assert!((counts[1] as f64 / 10_000.0 - 2.0).abs() < 0.15);
        assert!((counts[2] as f64 / 10_000.0 - 3.0).abs() < 0.2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
