//! A fast, deterministic, non-cryptographic hasher for hot-path memo
//! tables (FxHash-style multiply-xor, as popularized by the rustc
//! `FxHashMap`).
//!
//! The simulator's exact-input power memo
//! (`simulation::powermemo`) hits its table once per
//! `refresh_power` call — millions of times per run — so the default
//! SipHash-backed `HashMap` hasher (designed for HashDoS resistance,
//! irrelevant for an in-process memo keyed by simulation state) costs
//! more than the lookup it guards. This hasher is a few shifts and one
//! multiply per word, fully deterministic across processes (no random
//! keys), and in-tree because the container forbids external crates.

use std::hash::{BuildHasherDefault, Hasher};

/// `BuildHasher` plugging [`FxHasher`] into `HashMap`.
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-xor hasher (FxHash). Not HashDoS-resistant — use only for
/// in-process tables keyed by trusted data.
#[derive(Debug, Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Word-at-a-time over the byte slice; the tail is padded into
        // one final word. Memo keys in this crate are fixed-width
        // integer tuples, so this path sees whole words anyway.
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(tail));
        }
    }

    #[inline]
    fn write_u8(&mut self, v: u8) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add_to_hash(v);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add_to_hash(v as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_across_instances() {
        let k = (3u8, 1234u64, 5678u64);
        assert_eq!(hash_of(&k), hash_of(&k));
    }

    #[test]
    fn distinct_keys_distinct_hashes() {
        // Not a collision-resistance claim — just a sanity net over the
        // small key alphabets the memo tables actually use.
        let keys: Vec<(u8, u64, u64)> = (0..4u8)
            .flat_map(|t| (0..64u64).map(move |a| (t, a, a.wrapping_mul(977))))
            .collect();
        let hashes: std::collections::HashSet<u64> = keys.iter().map(hash_of).collect();
        assert_eq!(hashes.len(), keys.len());
    }

    #[test]
    fn works_as_hashmap_hasher() {
        let mut m: HashMap<(u8, u64), f64, FxBuildHasher> = HashMap::default();
        m.insert((1, 42), 3.5);
        m.insert((2, 42), 7.0);
        assert_eq!(m.get(&(1, 42)), Some(&3.5));
        assert_eq!(m.get(&(2, 42)), Some(&7.0));
        assert_eq!(m.get(&(3, 42)), None);
    }

    #[test]
    fn byte_slices_hash_stably() {
        let mut h1 = FxHasher::default();
        h1.write(b"hello world, this is a tail");
        let mut h2 = FxHasher::default();
        h2.write(b"hello world, this is a tail");
        assert_eq!(h1.finish(), h2.finish());
        let mut h3 = FxHasher::default();
        h3.write(b"hello world, this is a tai1");
        assert_ne!(h1.finish(), h3.finish());
    }
}
