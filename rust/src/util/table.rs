//! ASCII table formatter: the `polca figure ...` commands print
//! paper-style rows with this.

/// In-memory table with a title and fixed header.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with the given title and column header.
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row (width-checked against the header).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render the table as aligned ASCII.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |", w = w));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with `digits` decimal places.
pub fn f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format as percentage with `digits` decimals.
pub fn pct(x: f64, digits: usize) -> String {
    format!("{:.digits$}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("| long-name | 22 |"));
        assert!(r.contains("| a         | 1  |"));
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.295, 1), "29.5%");
    }
}
