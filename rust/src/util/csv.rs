//! Tiny CSV writer for figure/bench data emission.

use std::fmt::Display;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// In-memory CSV builder with a fixed header.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    /// Empty CSV with the given header.
    pub fn new(header: &[&str]) -> Self {
        Csv { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Push a row of displayable cells (width-checked).
    pub fn row<D: Display>(&mut self, cells: &[D]) {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells.iter().map(|c| escape(&c.to_string())).collect());
    }

    /// Push a row of heterogeneous, already-formatted cells.
    pub fn row_strs(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells.iter().map(|c| escape(c)).collect());
    }

    /// Data-row count (excluding the header).
    pub fn len(&self) -> usize {
        self.rows.len()
    }
    /// Whether no data rows were pushed.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render the full CSV text, header first.
    pub fn to_string(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    /// Write the CSV to `path`, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }
}

fn escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_csv() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&[1.0, 2.5]);
        c.row_strs(&["x,y".into(), "q\"z".into()]);
        let s = c.to_string();
        assert_eq!(s, "a,b\n1,2.5\n\"x,y\",\"q\"\"z\"\n");
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut c = Csv::new(&["a"]);
        c.row(&[1, 2]);
    }
}
