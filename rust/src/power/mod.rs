//! Power models: GPU phase/frequency power, server component breakdown,
//! capping semantics, and training-iteration power.
//!
//! Calibration sources (all from the paper, since the real A100 testbed is
//! unavailable — see DESIGN.md §2 substitution table):
//!   * Fig 2  — server component budget (GPUs ≈ half of provisioned power),
//!   * Fig 4/5 — prompt-spike vs token-phase magnitudes per model/config,
//!   * Fig 6  — reactive power-cap vs proactive frequency-cap semantics,
//!   * Fig 7/9 — frequency→power and frequency→performance sensitivity,
//!   * Fig 8  — training iteration phase structure.

pub mod gpu;
pub mod server;
pub mod training;

pub use gpu::{CapMode, GpuPowerCalib, Phase};
pub use server::ServerPowerModel;
pub use training::TrainingPowerModel;
