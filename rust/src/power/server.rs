//! Server-level power: DGX-A100 component budget (Fig 2) and the
//! GPU-fraction-of-server relationship the paper measures in production
//! (§3.2 / Fig 11: GPUs ≈ 60% of consumed server power; peak server power
//! highly correlated with peak GPU power).

use super::gpu::{CapMode, GpuPowerCalib, Phase};

/// One component of the provisioned server budget (Fig 2).
#[derive(Debug, Clone, PartialEq)]
pub struct Component {
    /// Component label (Fig 2 row name).
    pub name: &'static str,
    /// Provisioned (worst-case) wattage of this component.
    pub provisioned_w: f64,
    /// Fraction of the provisioned wattage drawn when the server idles.
    pub idle_fraction: f64,
    /// Whether the draw scales with GPU activity (fans/PSU loss do; the
    /// NVMe mostly does not).
    pub tracks_gpu: bool,
}

/// DGX-A100-class server power model.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerPowerModel {
    /// TDP of each GPU, watts.
    pub gpu_tdp_each_w: f64,
    /// Number of GPUs in the server (8 for DGX/HGX chassis).
    pub n_gpus: usize,
    /// Non-GPU component budget (Fig 2 rows).
    pub components: Vec<Component>,
    /// GPU power calibration (phase anchors, idle floor, clock ceiling).
    pub calib: GpuPowerCalib,
}

impl Default for ServerPowerModel {
    fn default() -> Self {
        // 8×A100-80GB SXM (400 W each) + host. Totals ~6.5 kW provisioned,
        // matching the DGX A100 max system power; GPUs ≈ 49% of the
        // provisioned budget (Fig 2) and ≈60% of *consumed* power under
        // load (Fig 11), because fixed components idle below provisioning.
        ServerPowerModel {
            gpu_tdp_each_w: 400.0,
            n_gpus: 8,
            components: vec![
                Component { name: "cpus", provisioned_w: 560.0, idle_fraction: 0.35, tracks_gpu: true },
                Component { name: "dram", provisioned_w: 380.0, idle_fraction: 0.40, tracks_gpu: true },
                Component { name: "nvswitch", provisioned_w: 300.0, idle_fraction: 0.30, tracks_gpu: true },
                Component { name: "nvme+nic", provisioned_w: 360.0, idle_fraction: 0.45, tracks_gpu: false },
                Component { name: "fans", provisioned_w: 800.0, idle_fraction: 0.25, tracks_gpu: true },
                Component { name: "psu-loss", provisioned_w: 900.0, idle_fraction: 0.20, tracks_gpu: true },
            ],
            calib: GpuPowerCalib::default(),
        }
    }
}

impl ServerPowerModel {
    /// Aggregate GPU TDP (the denominator of all GPU power fractions).
    pub fn gpu_tdp_w(&self) -> f64 {
        self.gpu_tdp_each_w * self.n_gpus as f64
    }

    /// Provisioned (breaker-facing) server power.
    pub fn provisioned_w(&self) -> f64 {
        self.gpu_tdp_w() + self.components.iter().map(|c| c.provisioned_w).sum::<f64>()
    }

    /// GPU share of the provisioned budget (Fig 2 headline: ~half).
    pub fn gpu_provisioned_share(&self) -> f64 {
        self.gpu_tdp_w() / self.provisioned_w()
    }

    /// Non-GPU draw given the GPUs' current utilization level (0..~1.2).
    fn non_gpu_w(&self, gpu_activity: f64) -> f64 {
        self.components
            .iter()
            .map(|c| {
                if c.tracks_gpu {
                    let a = gpu_activity.clamp(0.0, 1.0);
                    c.provisioned_w * (c.idle_fraction + (0.9 - c.idle_fraction) * a)
                } else {
                    c.provisioned_w * c.idle_fraction
                }
            })
            .sum()
    }

    /// Non-GPU draw at an explicit GPU-activity level (0..1) — used by
    /// the training-row model where the waveform drives the GPUs
    /// directly (Table 2 / Fig 8 aggregation).
    pub fn non_gpu_at(&self, activity: f64) -> f64 {
        self.non_gpu_w(activity)
    }

    /// Total server wall power for a phase under a cap.
    pub fn server_power_w(&self, phase: Phase, cap: CapMode, spike_escaping: bool) -> f64 {
        let gpu_frac = self.calib.phase_power(phase, cap, spike_escaping);
        let gpu_w = gpu_frac * self.gpu_tdp_w();
        // GPU "activity" proxy for the tracking components: utilization
        // above idle normalized to the idle→TDP band.
        let activity = ((gpu_frac - self.calib.idle_frac) / (1.0 - self.calib.idle_frac)).clamp(0.0, 1.0);
        gpu_w + self.non_gpu_w(activity)
    }

    /// Total server wall power when the GPUs draw `gpu_frac` of their
    /// aggregate TDP directly — the entry point for the training
    /// waveform ([`crate::power::training`]), whose §2.4 phase levels
    /// drive the GPUs without an inference phase in between. Tracking
    /// components follow GPU activity exactly as under serving.
    pub fn training_power_w(&self, gpu_frac: f64) -> f64 {
        let activity =
            ((gpu_frac - self.calib.idle_frac) / (1.0 - self.calib.idle_frac)).clamp(0.0, 1.0);
        gpu_frac * self.gpu_tdp_w() + self.non_gpu_at(activity)
    }

    /// GPU share of *consumed* power in a phase (paper: ~60% under load).
    pub fn gpu_consumed_share(&self, phase: Phase) -> f64 {
        let total = self.server_power_w(phase, CapMode::None, false);
        let gpu_w = self.calib.phase_power_nominal(phase) * self.gpu_tdp_w();
        gpu_w / total
    }

    /// Fig 2 rows: (component, provisioned watts, share of total).
    pub fn breakdown(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.provisioned_w();
        let mut rows = vec![("gpus (8x)", self.gpu_tdp_w(), self.gpu_tdp_w() / total)];
        for c in &self.components {
            rows.push((c.name, c.provisioned_w, c.provisioned_w / total));
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn provisioned_total_matches_dgx_class() {
        let m = ServerPowerModel::default();
        let p = m.provisioned_w();
        assert!((6000.0..7000.0).contains(&p), "provisioned={p}");
    }

    #[test]
    fn gpu_share_of_provisioned_near_half() {
        // Fig 2: "GPUs make around 50% of the server power [budget]".
        let m = ServerPowerModel::default();
        let share = m.gpu_provisioned_share();
        assert!((0.45..0.55).contains(&share), "share={share}");
    }

    #[test]
    fn gpu_share_of_consumed_near_sixty_pct_loaded() {
        // §3.2: GPUs ≈ 60% of consumed server power in production.
        let m = ServerPowerModel::default();
        let share = m.gpu_consumed_share(Phase::Prompt { total_input: 4096.0 });
        assert!((0.52..0.68).contains(&share), "share={share}");
    }

    #[test]
    fn server_power_ordering_idle_token_prompt() {
        let m = ServerPowerModel::default();
        let idle = m.server_power_w(Phase::Idle, CapMode::None, false);
        let token = m.server_power_w(Phase::Token { batch: 4.0 }, CapMode::None, false);
        let prompt = m.server_power_w(Phase::Prompt { total_input: 4096.0 }, CapMode::None, false);
        assert!(idle < token && token < prompt, "{idle} {token} {prompt}");
        assert!(idle > 0.15 * m.provisioned_w());
        // peak server power can exceed provisioned GPU share but stays
        // below total provisioned (provisioning is for worst case)
        assert!(prompt <= m.provisioned_w() * 1.02);
    }

    #[test]
    fn freq_cap_reduces_server_power() {
        let m = ServerPowerModel::default();
        let phase = Phase::Prompt { total_input: 8192.0 };
        let uncapped = m.server_power_w(phase, CapMode::None, false);
        let capped = m.server_power_w(phase, CapMode::FreqCap { mhz: 1110.0 }, false);
        let red = 1.0 - capped / uncapped;
        // server-level reduction is smaller than GPU-level (non-GPU floor)
        assert!((0.08..0.22).contains(&red), "red={red}");
    }

    #[test]
    fn training_power_spans_idle_to_above_tdp() {
        let m = ServerPowerModel::default();
        let idle = m.training_power_w(m.calib.idle_frac);
        let trough = m.training_power_w(0.50);
        let peak = m.training_power_w(1.05);
        assert!(idle < trough && trough < peak, "{idle} {trough} {peak}");
        // At TDP-level GPU draw the server approaches its provisioned
        // budget (§2.4: "training can easily reach the TDP").
        assert!(m.training_power_w(1.0) > 0.85 * m.provisioned_w());
        assert!(peak < 1.1 * m.provisioned_w());
    }

    #[test]
    fn breakdown_sums_to_provisioned() {
        let m = ServerPowerModel::default();
        let total: f64 = m.breakdown().iter().map(|(_, w, _)| w).sum();
        assert!((total - m.provisioned_w()).abs() < 1e-9);
        let share: f64 = m.breakdown().iter().map(|(_, _, s)| s).sum();
        assert!((share - 1.0).abs() < 1e-12);
    }
}
