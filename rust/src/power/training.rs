//! Training-iteration power model (§2.4, Figs 8/9, Table 2 training column).
//!
//! Training power has a phase structure *within* each iteration:
//!   compute (fwd) → small dip (fwd/bwd boundary sync) → compute (bwd)
//!   → deep trough (cross-GPU gradient synchronization).
//! The trough level is model-dependent: RoBERTa stays at ~75% of TDP,
//! GPT-NeoX drops to ~50%, Flan-T5 falls to idle (~20%). Because large
//! jobs synchronize *across servers*, these swings are coordinated at the
//! row level — the paper's core argument for why training clusters offer
//! little oversubscription headroom (max 2s swing: 37.5% of provisioned).

use super::gpu::{CapMode, GpuPowerCalib};

/// Phase positions inside one training iteration (fractions of iter time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainingProfile {
    /// Iteration wall time at nominal frequency, seconds.
    pub iter_time_s: f64,
    /// Peak compute power as fraction of GPU TDP (can exceed 1.0;
    /// Fig 8 shows GPT-NeoX and Flan-T5 beyond TDP).
    pub peak_frac: f64,
    /// Power level during the fwd/bwd boundary dip.
    pub mid_dip_frac: f64,
    /// Power level during the end-of-iteration synchronization trough.
    pub sync_trough_frac: f64,
    /// Fraction of the iteration spent in the mid dip.
    pub mid_dip_width: f64,
    /// Fraction of the iteration spent in the sync trough.
    pub sync_width: f64,
    /// Fraction of iteration time that is compute-bound (scales ~1/f).
    pub compute_time_frac: f64,
}

impl TrainingProfile {
    /// A large-LLM training job: GPT-NeoX-like power levels (§2.4) on a
    /// multi-second iteration, the shape of the frontier-scale jobs the
    /// §7 colocation discussion mixes into inference rows. The long
    /// iteration matters operationally: its 2 s synchronization trough
    /// survives PDU window averaging, so the row-level telemetry sees
    /// the coordinated swing the paper warns about.
    pub fn large_llm() -> TrainingProfile {
        TrainingProfile {
            iter_time_s: 6.0,
            peak_frac: 1.0,
            mid_dip_frac: 0.78,
            sync_trough_frac: 0.50,
            mid_dip_width: 0.05,
            sync_width: 1.0 / 3.0,
            compute_time_frac: 0.80,
        }
    }

    /// Waveform phase boundaries as fractions of the (possibly
    /// stretched) iteration time: `[start, mid-dip start, mid-dip end,
    /// sync-trough start, end]`. The mid dip sits ~55% through the
    /// iteration (the fwd/bwd boundary) and is clamped so it never
    /// overlaps the end-of-iteration trough.
    pub fn phase_bounds(&self) -> [f64; 5] {
        let sync_start = (1.0 - self.sync_width).clamp(0.0, 1.0);
        let mid_start = (0.55 - self.mid_dip_width / 2.0).clamp(0.0, sync_start);
        let mid_end = (0.55 + self.mid_dip_width / 2.0).clamp(mid_start, sync_start);
        [0.0, mid_start, mid_end, sync_start, 1.0]
    }

    /// Nominal GPU power level (fraction of TDP) of each of the four
    /// waveform phases delimited by [`Self::phase_bounds`]: compute
    /// plateau, mid dip, compute plateau, synchronization trough.
    pub fn phase_levels(&self) -> [f64; 4] {
        [self.peak_frac, self.mid_dip_frac, self.peak_frac, self.sync_trough_frac]
    }
}

/// Training power model for one model on one server.
#[derive(Debug, Clone, Copy)]
pub struct TrainingPowerModel {
    /// The iteration waveform (§2.4 phase structure).
    pub profile: TrainingProfile,
    /// GPU calibration supplying the idle floor, clock ceiling, and
    /// power–frequency curve (per SKU in heterogeneous fleets).
    pub calib: GpuPowerCalib,
}

impl TrainingPowerModel {
    /// Model with the default (DGX-A100) calibration.
    pub fn new(profile: TrainingProfile) -> Self {
        TrainingPowerModel { profile, calib: GpuPowerCalib::default() }
    }

    /// Model with an explicit per-SKU calibration (see
    /// [`crate::fleet::sku::SkuSpec::training_model`]).
    pub fn with_calib(profile: TrainingProfile, calib: GpuPowerCalib) -> Self {
        TrainingPowerModel { profile, calib }
    }

    /// Iteration time under a frequency cap (compute part stretches 1/f).
    pub fn iter_time_s(&self, cap: CapMode) -> f64 {
        let ratio = match cap {
            CapMode::None => 1.0,
            CapMode::FreqCap { mhz } => (mhz / self.calib.max_freq_mhz).clamp(0.05, 1.0),
            // A power cap reacts to sustained compute power; its effective
            // slowdown uses the inverted power curve at the peak level.
            CapMode::PowerCap { frac_of_tdp } => {
                let avail = (frac_of_tdp - self.calib.idle_frac).max(0.0);
                let need = (self.profile.peak_frac - self.calib.idle_frac).max(1e-9);
                (avail / need).powf(1.0 / self.calib.power_freq_alpha).clamp(0.05, 1.0)
            }
        };
        let p = &self.profile;
        p.iter_time_s * (p.compute_time_frac / ratio + (1.0 - p.compute_time_frac))
    }

    /// Throughput (iterations/s) relative to uncapped.
    pub fn relative_throughput(&self, cap: CapMode) -> f64 {
        self.iter_time_s(CapMode::None) / self.iter_time_s(cap)
    }

    /// GPU power fraction at a point `t` (seconds) inside the iteration
    /// cycle, under a cap. The waveform: compute plateau, mid dip at the
    /// fwd/bwd boundary (~55% through), sync trough at the end.
    pub fn power_frac_at(&self, t_in_iter_s: f64, cap: CapMode) -> f64 {
        let p = &self.profile;
        let iter = self.iter_time_s(cap);
        let x = (t_in_iter_s / iter).rem_euclid(1.0);
        let b = p.phase_bounds();
        let l = p.phase_levels();
        let nominal = if x >= b[3] {
            l[3]
        } else if x >= b[2] {
            l[2]
        } else if x >= b[1] {
            l[1]
        } else {
            l[0]
        };
        self.capped_level(nominal, cap)
    }

    /// Apply a cap to a nominal waveform level — the per-phase form of
    /// [`Self::power_frac_at`]. Delegates to
    /// [`GpuPowerCalib::capped_level`], the single definition of
    /// cap-on-level semantics shared with the discrete-event training
    /// driver.
    pub fn capped_level(&self, nominal: f64, cap: CapMode) -> f64 {
        self.calib.capped_level(nominal, cap)
    }

    /// Peak power over a full iteration under a cap.
    pub fn peak_frac(&self, cap: CapMode) -> f64 {
        match cap {
            CapMode::None => self.profile.peak_frac,
            CapMode::FreqCap { mhz } => self.calib.apply_freq(self.profile.peak_frac, mhz),
            CapMode::PowerCap { frac_of_tdp } => {
                // Reactive: transient spikes escape by ~5% before clamping.
                (frac_of_tdp * 1.05).min(self.profile.peak_frac)
            }
        }
    }

    /// Power swing (peak - trough) within one iteration — the quantity
    /// the paper identifies as the training-side challenge (§2.4).
    pub fn swing_frac(&self, cap: CapMode) -> f64 {
        let trough = match cap {
            CapMode::None => self.profile.sync_trough_frac,
            CapMode::FreqCap { mhz } => self.calib.apply_freq(self.profile.sync_trough_frac, mhz),
            CapMode::PowerCap { frac_of_tdp } => {
                self.profile.sync_trough_frac.min(frac_of_tdp.max(self.calib.idle_frac))
            }
        };
        (self.peak_frac(cap) - trough).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn neox_like() -> TrainingPowerModel {
        TrainingPowerModel::new(TrainingProfile {
            iter_time_s: 2.0,
            peak_frac: 1.05,
            mid_dip_frac: 0.80,
            sync_trough_frac: 0.50,
            mid_dip_width: 0.06,
            sync_width: 0.15,
            compute_time_frac: 0.80,
        })
    }

    fn flant5_like() -> TrainingPowerModel {
        TrainingPowerModel::new(TrainingProfile {
            iter_time_s: 3.0,
            peak_frac: 1.08,
            mid_dip_frac: 0.60,
            sync_trough_frac: 0.20,
            mid_dip_width: 0.08,
            sync_width: 0.20,
            compute_time_frac: 0.75,
        })
    }

    #[test]
    fn waveform_has_plateau_dip_trough() {
        let m = neox_like();
        let plateau = m.power_frac_at(0.2, CapMode::None);
        let dip = m.power_frac_at(0.55 * 2.0, CapMode::None);
        let trough = m.power_frac_at(1.95, CapMode::None);
        assert_eq!(plateau, 1.05);
        assert_eq!(dip, 0.80);
        assert_eq!(trough, 0.50);
    }

    #[test]
    fn training_reaches_tdp() {
        // §2.4 takeaway: "training can easily reach the TDP of the system".
        assert!(neox_like().peak_frac(CapMode::None) >= 1.0);
    }

    #[test]
    fn freq_cap_reduces_peak_but_also_trough_for_neox() {
        // §2.4: for models with busy sync phases (RoBERTa/NeoX), capping
        // lowers the trough too — so it does NOT fix the swing.
        let m = neox_like();
        let cap = CapMode::FreqCap { mhz: 1110.0 };
        assert!(m.peak_frac(cap) < m.peak_frac(CapMode::None));
        let swing_ratio = m.swing_frac(cap) / m.swing_frac(CapMode::None);
        assert!(swing_ratio > 0.6, "swing should persist, got ratio {swing_ratio}");
    }

    #[test]
    fn flant5_trough_is_idle_and_unaffected() {
        // Flan-T5's trough is at idle; a freq cap cannot push below idle,
        // so capping shrinks the swing from the top only — "reacting well".
        let m = flant5_like();
        let cap = CapMode::FreqCap { mhz: 1110.0 };
        let trough_uncapped = m.power_frac_at(2.95, CapMode::None);
        let trough_capped = m.power_frac_at(2.95, cap);
        assert!((trough_capped - trough_uncapped).abs() < 1e-9);
        assert!(m.swing_frac(cap) < m.swing_frac(CapMode::None));
    }

    #[test]
    fn freq_cap_perf_tradeoff_matches_fig9() {
        // Fig 9: ~22% peak power reduction for ~10% throughput loss.
        let m = flant5_like();
        let cap = CapMode::FreqCap { mhz: 1110.0 };
        let peak_red = 1.0 - m.peak_frac(cap) / m.peak_frac(CapMode::None);
        let perf_loss = 1.0 - m.relative_throughput(cap);
        assert!((0.12..0.25).contains(&peak_red), "peak_red={peak_red}");
        assert!((0.05..0.20).contains(&perf_loss), "perf_loss={perf_loss}");
        assert!(peak_red > perf_loss, "capping must be superlinear");
    }

    #[test]
    fn power_cap_lets_transients_escape() {
        let m = neox_like();
        let cap = CapMode::PowerCap { frac_of_tdp: 0.8 };
        assert!(m.peak_frac(cap) > 0.8);
        assert!(m.peak_frac(cap) <= 0.85);
    }

    #[test]
    fn phase_bounds_consistent_with_waveform() {
        // The event-driven phase decomposition must agree with the
        // continuous waveform at every phase midpoint.
        for m in [neox_like(), flant5_like(), TrainingPowerModel::new(TrainingProfile::large_llm())]
        {
            let b = m.profile.phase_bounds();
            let l = m.profile.phase_levels();
            assert!(b.windows(2).all(|w| w[0] <= w[1]), "{b:?}");
            for k in 0..4 {
                let mid = (b[k] + b[k + 1]) / 2.0;
                let t = mid * m.profile.iter_time_s;
                assert_eq!(m.power_frac_at(t, CapMode::None), l[k], "phase {k}");
            }
        }
    }

    #[test]
    fn capped_level_matches_waveform_cap() {
        let m = neox_like();
        let cap = CapMode::FreqCap { mhz: 1110.0 };
        let t_plateau = 0.2;
        assert_eq!(
            m.power_frac_at(t_plateau, cap),
            m.capped_level(m.profile.peak_frac, cap)
        );
    }

    #[test]
    fn large_llm_trough_survives_two_second_window() {
        // The colocation default must keep a >= 2 s synchronization
        // trough so PDU window averaging cannot hide the row swing.
        let p = TrainingProfile::large_llm();
        assert!(p.sync_width * p.iter_time_s >= 2.0 - 1e-9);
        assert!(p.peak_frac >= 1.0 - 1e-9); // reaches TDP (§2.4)
        assert_eq!(p.sync_trough_frac, 0.50); // NeoX-like trough
    }

    #[test]
    fn iter_time_stretches_under_caps() {
        let m = neox_like();
        let t0 = m.iter_time_s(CapMode::None);
        let t1 = m.iter_time_s(CapMode::FreqCap { mhz: 1110.0 });
        let t2 = m.iter_time_s(CapMode::FreqCap { mhz: 288.0 });
        assert!(t0 < t1 && t1 < t2);
        assert_eq!(t0, 2.0);
    }
}
