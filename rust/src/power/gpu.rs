//! Per-server GPU power model: inference phases, frequency scaling, and
//! the reactive-vs-proactive capping semantics of §2.3 / Fig 6.
//!
//! All powers are expressed as a fraction of the server's aggregate GPU
//! TDP (8 × 400 W for a DGX-A100-80GB); [`crate::power::server`] converts
//! to watts and adds the non-GPU components.

/// Execution phase of an inference server (drives its power draw).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Phase {
    /// No request in flight.
    Idle,
    /// Prompt processing: `total_input` = input tokens × batch — the
    /// parallel, compute-bound burst that produces the Fig 4 spikes.
    Prompt { total_input: f64 },
    /// Autoregressive token sampling at the given batch size.
    Token { batch: f64 },
}

/// GPU frequency/power control applied to a server.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapMode {
    /// No cap: GPUs run at max SM clock.
    None,
    /// Proactive frequency cap (the paper's chosen mechanism): bounds
    /// power *before* it is drawn; affects all phases.
    FreqCap { mhz: f64 },
    /// Reactive power cap: clamps sustained power but the prompt-phase
    /// spike escapes for the cap-reaction latency (Fig 6's key flaw).
    PowerCap { frac_of_tdp: f64 },
}

/// Per-model power calibration (fractions of aggregate GPU TDP).
///
/// Interpolation anchors follow the paper's sweep axes: prompt peak vs
/// total input tokens (Fig 5a, log2 scale 256→8192) and token-phase mean
/// vs batch (Fig 5c, log2 scale 1→16).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuPowerCalib {
    /// Idle draw (≈20% of TDP — the Flan-T5 training trough, §2.4).
    pub idle_frac: f64,
    /// Prompt-phase peak at total input = 256 tokens.
    pub prompt_peak_at_256: f64,
    /// Prompt-phase peak at total input = 8192 tokens (may exceed 1.0:
    /// the paper observes spikes beyond TDP).
    pub prompt_peak_at_8192: f64,
    /// Token-phase mean at batch 1.
    pub token_mean_at_b1: f64,
    /// Token-phase mean at batch 16.
    pub token_mean_at_b16: f64,
    /// Exponent of the dynamic-power vs frequency curve:
    /// `P = idle + (P_nom - idle) · (f/f_max)^alpha`. Dynamic power goes
    /// as f·V² and V scales with f on the DVFS ladder, so alpha > 1;
    /// 1.4 calibrates a 1110 MHz cap (from 1410) to reclaim ≈15–23% of
    /// peak power (Fig 6/7's "up to 20%" band).
    pub power_freq_alpha: f64,
    /// Max SM clock (A100: 1410 MHz).
    pub max_freq_mhz: f64,
}

impl Default for GpuPowerCalib {
    fn default() -> Self {
        GpuPowerCalib {
            idle_frac: 0.20,
            prompt_peak_at_256: 0.72,
            prompt_peak_at_8192: 1.10,
            token_mean_at_b1: 0.45,
            token_mean_at_b16: 0.62,
            power_freq_alpha: 1.4,
            max_freq_mhz: 1410.0,
        }
    }
}

impl GpuPowerCalib {
    /// Prompt-phase peak power fraction at nominal frequency, as a
    /// function of total input tokens (input × batch). Log2-linear
    /// between the anchors, clamped outside, floored at the token level.
    pub fn prompt_peak_frac(&self, total_input: f64) -> f64 {
        let lo = 256.0_f64.log2();
        let hi = 8192.0_f64.log2();
        let x = total_input.max(1.0).log2().clamp(lo, hi);
        let t = (x - lo) / (hi - lo);
        let peak = self.prompt_peak_at_256 + t * (self.prompt_peak_at_8192 - self.prompt_peak_at_256);
        peak.max(self.token_mean_at_b1)
    }

    /// Token-phase mean power fraction at nominal frequency vs batch.
    pub fn token_mean_frac(&self, batch: f64) -> f64 {
        let lo = 1.0_f64.log2(); // 0
        let hi = 16.0_f64.log2();
        let x = batch.max(1.0).log2().clamp(lo, hi);
        let t = (x - lo) / (hi - lo);
        self.token_mean_at_b1 + t * (self.token_mean_at_b16 - self.token_mean_at_b1)
    }

    /// Scale a nominal power fraction by a frequency cap:
    /// dynamic component scales as (f/f_max)^alpha, idle floor unaffected.
    pub fn apply_freq(&self, nominal_frac: f64, freq_mhz: f64) -> f64 {
        let ratio = (freq_mhz / self.max_freq_mhz).clamp(0.0, 1.0);
        let dynamic = (nominal_frac - self.idle_frac).max(0.0);
        self.idle_frac + dynamic * ratio.powf(self.power_freq_alpha)
    }

    /// Nominal (uncapped) power for a phase.
    pub fn phase_power_nominal(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Idle => self.idle_frac,
            Phase::Prompt { total_input } => self.prompt_peak_frac(total_input),
            Phase::Token { batch } => self.token_mean_frac(batch),
        }
    }

    /// Power for a phase under a cap.
    ///
    /// * `FreqCap` is proactive: it bounds every phase, including the
    ///   prompt spike.
    /// * `PowerCap` is reactive: if `spike_escaping` is true (the start of
    ///   a prompt burst, within the cap loop's reaction latency) the draw
    ///   passes through uncapped — Fig 6's "initial peaks go beyond the
    ///   power cap". Sustained draw clamps to the cap.
    pub fn phase_power(&self, phase: Phase, cap: CapMode, spike_escaping: bool) -> f64 {
        let nominal = self.phase_power_nominal(phase);
        match cap {
            CapMode::None => nominal,
            CapMode::FreqCap { mhz } => self.apply_freq(nominal, mhz),
            CapMode::PowerCap { frac_of_tdp } => {
                if spike_escaping && matches!(phase, Phase::Prompt { .. }) {
                    nominal
                } else {
                    nominal.min(frac_of_tdp.max(self.idle_frac))
                }
            }
        }
    }

    /// Apply a cap to a phase-constant nominal power level: a frequency
    /// cap scales the dynamic component ([`Self::apply_freq`]); a
    /// reactive power cap clamps to the cap (floored at idle). This is
    /// the level-based form of [`Self::phase_power`] used by waveform
    /// consumers (the training model and the discrete-event training
    /// driver) that hold one nominal level per phase.
    pub fn capped_level(&self, nominal: f64, cap: CapMode) -> f64 {
        match cap {
            CapMode::None => nominal,
            CapMode::FreqCap { mhz } => self.apply_freq(nominal, mhz),
            CapMode::PowerCap { frac_of_tdp } => nominal.min(frac_of_tdp.max(self.idle_frac)),
        }
    }

    /// Effective frequency ratio a *power* cap induces once it reacts
    /// (used for its performance impact): invert the power curve.
    pub fn power_cap_freq_ratio(&self, phase: Phase, frac_of_tdp: f64) -> f64 {
        let nominal = self.phase_power_nominal(phase);
        if nominal <= frac_of_tdp {
            return 1.0;
        }
        let avail = (frac_of_tdp - self.idle_frac).max(0.0);
        let need = (nominal - self.idle_frac).max(1e-9);
        (avail / need).powf(1.0 / self.power_freq_alpha).clamp(0.05, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> GpuPowerCalib {
        GpuPowerCalib::default()
    }

    #[test]
    fn prompt_peak_monotonic_in_input() {
        let c = cal();
        let mut prev = 0.0;
        for &inp in &[64.0, 256.0, 512.0, 1024.0, 4096.0, 8192.0, 20000.0] {
            let p = c.prompt_peak_frac(inp);
            assert!(p >= prev, "input={inp}");
            prev = p;
        }
        // paper: spikes can exceed TDP at large inputs
        assert!(c.prompt_peak_frac(8192.0) > 1.0);
        // clamped outside the anchor range
        assert_eq!(c.prompt_peak_frac(100_000.0), c.prompt_peak_frac(8192.0));
    }

    #[test]
    fn token_mean_monotonic_in_batch() {
        let c = cal();
        assert!(c.token_mean_frac(1.0) < c.token_mean_frac(4.0));
        assert!(c.token_mean_frac(4.0) < c.token_mean_frac(16.0));
        assert_eq!(c.token_mean_frac(16.0), c.token_mean_frac(64.0));
    }

    #[test]
    fn prompt_spike_exceeds_token_mean() {
        // The paper's core phase asymmetry (Fig 4).
        let c = cal();
        assert!(c.prompt_peak_frac(2048.0) > c.token_mean_frac(16.0));
    }

    #[test]
    fn freq_cap_reclaims_paper_range() {
        // Fig 7: capping 1410 -> 1110 MHz reclaims roughly 13-20% of peak.
        let c = cal();
        let peak = c.prompt_peak_frac(8192.0);
        let capped = c.apply_freq(peak, 1110.0);
        let reduction = 1.0 - capped / peak;
        assert!(
            (0.10..=0.25).contains(&reduction),
            "reduction {reduction} outside paper band"
        );
        // base-frequency cap (1275) reclaims less
        let capped_base = c.apply_freq(peak, 1275.0);
        assert!(capped_base > capped);
    }

    #[test]
    fn brake_freq_brings_power_near_idle() {
        let c = cal();
        let braked = c.apply_freq(c.prompt_peak_frac(8192.0), 288.0);
        assert!(braked < c.idle_frac + 0.25, "braked={braked}");
    }

    #[test]
    fn freq_cap_is_proactive_power_cap_is_reactive() {
        // Fig 6: the prompt spike escapes a power cap but not a freq cap.
        let c = cal();
        let phase = Phase::Prompt { total_input: 8192.0 };
        let nominal = c.phase_power_nominal(phase);
        let under_freq = c.phase_power(phase, CapMode::FreqCap { mhz: 1110.0 }, true);
        let under_power_escaping =
            c.phase_power(phase, CapMode::PowerCap { frac_of_tdp: 0.8 }, true);
        let under_power_reacted =
            c.phase_power(phase, CapMode::PowerCap { frac_of_tdp: 0.8 }, false);
        assert!(under_freq < nominal);
        assert_eq!(under_power_escaping, nominal); // spike escapes
        assert!((under_power_reacted - 0.8).abs() < 1e-12); // then clamps
    }

    #[test]
    fn token_phase_respects_power_cap_immediately() {
        let c = cal();
        let p = c.phase_power(Phase::Token { batch: 16.0 }, CapMode::PowerCap { frac_of_tdp: 0.3 }, true);
        assert!((p - 0.3).abs() < 1e-12);
    }

    #[test]
    fn capped_level_semantics() {
        let c = cal();
        let nominal = c.token_mean_frac(16.0);
        assert_eq!(c.capped_level(nominal, CapMode::None), nominal);
        assert_eq!(
            c.capped_level(nominal, CapMode::FreqCap { mhz: 1110.0 }),
            c.apply_freq(nominal, 1110.0)
        );
        assert_eq!(c.capped_level(nominal, CapMode::PowerCap { frac_of_tdp: 0.3 }), 0.3);
        // a power cap never pushes below the idle floor
        assert_eq!(
            c.capped_level(nominal, CapMode::PowerCap { frac_of_tdp: 0.05 }),
            c.idle_frac
        );
    }

    #[test]
    fn power_cap_freq_ratio_inverts() {
        let c = cal();
        let phase = Phase::Prompt { total_input: 8192.0 };
        // uncapped if cap above nominal
        assert_eq!(c.power_cap_freq_ratio(phase, 1.5), 1.0);
        let r = c.power_cap_freq_ratio(phase, 0.8);
        assert!(r < 1.0 && r > 0.3);
        // applying that ratio as a freq cap should land near the cap power
        let p = c.apply_freq(c.phase_power_nominal(phase), r * c.max_freq_mhz);
        assert!((p - 0.8).abs() < 0.02, "p={p}");
    }

    #[test]
    fn idle_unaffected_by_freq() {
        let c = cal();
        assert_eq!(c.apply_freq(c.idle_frac, 288.0), c.idle_frac);
    }
}
