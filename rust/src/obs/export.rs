//! Trace serialization: JSONL, CSV, Chrome trace-event, and the
//! incident-timeline renderer.
//!
//! All writers consume the *record* form of a trace — a flat list of
//! [`Json`] objects, each tagged with a `"type"` of `meta`, `event`,
//! `sample`, `counter`, or `span` (see `docs/OBSERVABILITY.md` for the
//! full schema). Both an in-process [`Trace`](crate::obs::Trace)
//! (via [`Trace::records`](crate::obs::Trace::records)) and a JSONL
//! file loaded with [`parse_jsonl`] produce the same record list, so
//! `polca trace summarize|timeline|export` works identically on live
//! and saved traces.

use crate::util::csv::Csv;
use crate::util::json::{parse, Json};

/// Maximum entries rendered per incident before eliding the middle.
const MAX_TIMELINE_ENTRIES: usize = 40;

/// Serialize records as JSON Lines (one compact object per line).
pub fn to_jsonl(records: &[Json]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_string());
        out.push('\n');
    }
    out
}

/// Parse a JSON Lines trace back into records (blank lines skipped).
pub fn parse_jsonl(text: &str) -> Result<Vec<Json>, String> {
    let mut records = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        records.push(parse(line).map_err(|e| format!("line {}: {e}", i + 1))?);
    }
    Ok(records)
}

fn num(rec: &Json, key: &str) -> Option<f64> {
    rec.get(key).and_then(Json::as_f64)
}

fn text<'a>(rec: &'a Json, key: &str) -> Option<&'a str> {
    rec.get(key).and_then(Json::as_str)
}

/// Long-format CSV (`t_s,kind,name,value`): events, series samples,
/// spans, and counters; meta records are summary-only and are skipped.
pub fn to_csv(records: &[Json]) -> Csv {
    let mut csv = Csv::new(&["t_s", "kind", "name", "value"]);
    for r in records {
        let (t_s, kind, name, value) = match text(r, "type") {
            Some("event") => {
                let value = ["mhz", "over_w", "reported", "level", "wall_s"]
                    .iter()
                    .find_map(|k| num(r, k));
                (num(r, "t_s"), "event", text(r, "event").unwrap_or("?"), value)
            }
            Some("sample") => {
                (num(r, "t_s"), "sample", text(r, "series").unwrap_or("?"), num(r, "v"))
            }
            Some("span") => {
                (num(r, "start_s"), "span", text(r, "name").unwrap_or("?"), num(r, "dur_s"))
            }
            Some("counter") => (None, "counter", text(r, "name").unwrap_or("?"), num(r, "v")),
            _ => continue,
        };
        let fmt = |x: Option<f64>| x.map(|x| Json::Num(x).to_string()).unwrap_or_default();
        csv.row_strs(&[fmt(t_s), kind.to_string(), name.to_string(), fmt(value)]);
    }
    csv
}

/// Chrome trace-event document (load via `chrome://tracing` or
/// Perfetto). Sim-time events and series live under pid 1 (`ts` is sim
/// microseconds); wall-clock spans live under pid 2, one lane per
/// worker.
pub fn to_chrome(records: &[Json]) -> Json {
    let mut tes: Vec<Json> = Vec::new();
    for r in records {
        match text(r, "type") {
            Some("event") => {
                let mut args: Vec<(&str, Json)> = Vec::new();
                if let Json::Obj(m) = r {
                    for (k, v) in m {
                        if !matches!(k.as_str(), "type" | "t_s" | "event") {
                            args.push((k, v.clone()));
                        }
                    }
                }
                tes.push(Json::obj(vec![
                    ("name", Json::Str(text(r, "event").unwrap_or("?").to_string())),
                    ("cat", Json::Str("sim".to_string())),
                    ("ph", Json::Str("i".to_string())),
                    ("s", Json::Str("t".to_string())),
                    ("ts", Json::num(num(r, "t_s").unwrap_or(0.0) * 1e6)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(1.0)),
                    ("args", Json::obj(args)),
                ]));
            }
            Some("sample") => {
                tes.push(Json::obj(vec![
                    ("name", Json::Str(text(r, "series").unwrap_or("?").to_string())),
                    ("cat", Json::Str("sim".to_string())),
                    ("ph", Json::Str("C".to_string())),
                    ("ts", Json::num(num(r, "t_s").unwrap_or(0.0) * 1e6)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(0.0)),
                    ("args", Json::obj(vec![("value", Json::num(num(r, "v").unwrap_or(0.0)))])),
                ]));
            }
            Some("span") => {
                tes.push(Json::obj(vec![
                    ("name", Json::Str(text(r, "name").unwrap_or("?").to_string())),
                    ("cat", Json::Str("wall".to_string())),
                    ("ph", Json::Str("X".to_string())),
                    ("ts", Json::num(num(r, "start_s").unwrap_or(0.0) * 1e6)),
                    ("dur", Json::num(num(r, "dur_s").unwrap_or(0.0) * 1e6)),
                    ("pid", Json::Num(2.0)),
                    ("tid", Json::num(num(r, "worker").unwrap_or(0.0))),
                ]));
            }
            _ => {}
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(tes)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// One line of an incident timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineEntry {
    /// Sim time of the entry, seconds.
    pub t_s: f64,
    /// Human rendering (event label plus key fields).
    pub what: String,
}

/// The control-loop activity attributed to one incident window.
#[derive(Debug, Clone, PartialEq)]
pub struct IncidentTimeline {
    /// Fault-kind label, or `violation` for fault-free excursions.
    pub label: String,
    /// Incident start (sim seconds).
    pub start_s: f64,
    /// Scheduled end of the episode; `inf` if it never ended in-trace.
    pub end_s: f64,
    /// Whether the excursion was contained inside the window.
    pub contained: bool,
    /// Attributed events, in time order (middle elided past
    /// [`MAX_TIMELINE_ENTRIES`]).
    pub entries: Vec<TimelineEntry>,
    /// Entries dropped by elision.
    pub elided: usize,
}

impl IncidentTimeline {
    /// JSON form used by `ScenarioReport`'s optional `timeline` field.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("start_s", Json::num(self.start_s)),
            ("end_s", Json::num(self.end_s)),
            ("contained", Json::Bool(self.contained)),
            ("elided", Json::num(self.elided as f64)),
            (
                "entries",
                Json::arr(self.entries.iter().map(|e| {
                    Json::obj(vec![
                        ("t_s", Json::num(e.t_s)),
                        ("what", Json::Str(e.what.clone())),
                    ])
                })),
            ),
        ])
    }
}

struct RawEvent<'a> {
    t_s: f64,
    label: &'a str,
    rec: &'a Json,
}

fn describe_record(r: &Json) -> String {
    let label = text(r, "event").unwrap_or("?");
    let mut s = label.to_string();
    if let Some(c) = text(r, "class") {
        s.push(' ');
        s.push_str(c);
    }
    if let Some(mhz) = num(r, "mhz") {
        s.push_str(&format!(" {mhz:.0}MHz"));
    }
    if let Some(l) = text(r, "label") {
        s.push(' ');
        s.push_str(l);
    }
    if let Some(w) = num(r, "over_w") {
        s.push_str(&format!(" (+{w:.0}W over budget)"));
    }
    s
}

fn push_window(
    out: &mut Vec<IncidentTimeline>,
    events: &[RawEvent<'_>],
    label: &str,
    start_s: f64,
    end_s: f64,
    window_end: f64,
) {
    let mut entries: Vec<TimelineEntry> = Vec::new();
    let mut violating = false;
    let mut saw_violation = false;
    for e in events {
        if e.t_s < start_s || e.t_s >= window_end {
            continue;
        }
        match e.label {
            "telemetry" | "train-phase" | "train-iter" => continue,
            "violation-start" => {
                violating = true;
                saw_violation = true;
            }
            "violation-contained" => violating = false,
            _ => {}
        }
        entries.push(TimelineEntry { t_s: e.t_s, what: describe_record(e.rec) });
    }
    let elided = entries.len().saturating_sub(MAX_TIMELINE_ENTRIES);
    if elided > 0 {
        // Keep the head and tail of the window; the middle is churn.
        let tail = entries.split_off(entries.len() - MAX_TIMELINE_ENTRIES / 2);
        entries.truncate(MAX_TIMELINE_ENTRIES / 2);
        entries.extend(tail);
    }
    let contained = !saw_violation || !violating;
    out.push(IncidentTimeline {
        label: label.to_string(),
        start_s,
        end_s,
        contained,
        entries,
        elided,
    });
}

/// Group trace events into per-incident timelines.
///
/// With fault episodes in the trace, each `fault-start` opens an
/// incident window that runs until the next `fault-start` (or the end
/// of the trace); every non-telemetry event inside the window is
/// attributed to it. Without faults, each `violation-start` ..
/// `violation-contained` pair forms its own `violation` incident.
pub fn incident_timeline(records: &[Json]) -> Vec<IncidentTimeline> {
    let mut events: Vec<RawEvent<'_>> = records
        .iter()
        .filter(|r| text(r, "type") == Some("event"))
        .filter_map(|r| {
            Some(RawEvent { t_s: num(r, "t_s")?, label: text(r, "event")?, rec: r })
        })
        .collect();
    events.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap_or(std::cmp::Ordering::Equal));

    let starts: Vec<(f64, f64, String)> = events
        .iter()
        .filter(|e| e.label == "fault-start")
        .map(|e| {
            let id = num(e.rec, "fault").unwrap_or(-1.0);
            let end = events
                .iter()
                .find(|x| x.label == "fault-end" && num(x.rec, "fault") == Some(id))
                .map(|x| x.t_s)
                .unwrap_or(f64::INFINITY);
            (e.t_s, end, text(e.rec, "label").unwrap_or("fault").to_string())
        })
        .collect();

    let mut out = Vec::new();
    if starts.is_empty() {
        // Fault-free trace: violation windows become the incidents.
        let mut open: Option<f64> = None;
        for e in &events {
            match (e.label, open) {
                ("violation-start", None) => open = Some(e.t_s),
                ("violation-contained", Some(s)) => {
                    push_window(&mut out, &events, "violation", s, e.t_s, e.t_s + 1e-9);
                    open = None;
                }
                _ => {}
            }
        }
        if let Some(s) = open {
            push_window(&mut out, &events, "violation", s, f64::INFINITY, f64::INFINITY);
        }
        return out;
    }
    for (i, (start_s, end_s, label)) in starts.iter().enumerate() {
        let window_end = starts.get(i + 1).map(|s| s.0).unwrap_or(f64::INFINITY);
        push_window(&mut out, &events, label, *start_s, *end_s, window_end);
    }
    out
}

/// Text rendering of [`incident_timeline`] output.
pub fn render_timeline(timelines: &[IncidentTimeline]) -> String {
    let mut out = String::new();
    for (i, tl) in timelines.iter().enumerate() {
        let end = if tl.end_s.is_finite() {
            format!("{:.0}s", tl.end_s)
        } else {
            "end".to_string()
        };
        let verdict = if tl.contained { "contained" } else { "NOT contained" };
        out.push_str(&format!(
            "incident {}: {} [{:.0}s .. {end}] — {verdict}\n",
            i + 1,
            tl.label,
            tl.start_s
        ));
        let head = tl.entries.len() - tl.entries.len().min(MAX_TIMELINE_ENTRIES / 2);
        for (j, e) in tl.entries.iter().enumerate() {
            if tl.elided > 0 && j == head {
                out.push_str(&format!("    ... {} entries elided ...\n", tl.elided));
            }
            out.push_str(&format!("  {:>10.1}s  {}\n", e.t_s, e.what));
        }
        if tl.entries.is_empty() {
            out.push_str("  (no control-loop activity in window)\n");
        }
    }
    out
}

/// Human summary of a record list: counts by type, events by label,
/// sim-time range, per-series retention, counters.
pub fn summarize(records: &[Json]) -> String {
    use std::collections::BTreeMap;
    let mut by_type: BTreeMap<&str, usize> = BTreeMap::new();
    let mut by_label: BTreeMap<String, usize> = BTreeMap::new();
    let mut by_series: BTreeMap<String, usize> = BTreeMap::new();
    let mut counters: Vec<(String, f64)> = Vec::new();
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    for r in records {
        let ty = text(r, "type").unwrap_or("?");
        *by_type.entry(ty).or_insert(0) += 1;
        if let Some(t) = num(r, "t_s") {
            t_min = t_min.min(t);
            t_max = t_max.max(t);
        }
        match ty {
            "event" => {
                *by_label.entry(text(r, "event").unwrap_or("?").to_string()).or_insert(0) += 1;
            }
            "sample" => {
                *by_series.entry(text(r, "series").unwrap_or("?").to_string()).or_insert(0) += 1;
            }
            "counter" => {
                counters
                    .push((text(r, "name").unwrap_or("?").to_string(), num(r, "v").unwrap_or(0.0)));
            }
            _ => {}
        }
    }
    let mut out = format!("trace: {} records", records.len());
    if t_max >= t_min {
        out.push_str(&format!(", sim time {t_min:.0}s .. {t_max:.0}s"));
    }
    out.push('\n');
    for (ty, n) in &by_type {
        out.push_str(&format!("  {ty:>8}: {n}\n"));
    }
    if !by_label.is_empty() {
        let mut labels: Vec<_> = by_label.into_iter().collect();
        labels.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        out.push_str("events by label:\n");
        for (label, n) in labels {
            out.push_str(&format!("  {label:>22}: {n}\n"));
        }
    }
    if !by_series.is_empty() {
        out.push_str("series (retained samples):\n");
        for (name, n) in &by_series {
            out.push_str(&format!("  {name:>22}: {n}\n"));
        }
    }
    if !counters.is_empty() {
        out.push_str("counters:\n");
        for (name, v) in &counters {
            out.push_str(&format!("  {name:>22}: {v:.0}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_s: f64, label: &str, extra: Vec<(&str, Json)>) -> Json {
        let mut pairs = vec![
            ("type", Json::Str("event".to_string())),
            ("t_s", Json::num(t_s)),
            ("event", Json::Str(label.to_string())),
        ];
        pairs.extend(extra);
        Json::obj(pairs)
    }

    fn sample(t_s: f64, series: &str, v: f64) -> Json {
        Json::obj(vec![
            ("type", Json::Str("sample".to_string())),
            ("t_s", Json::num(t_s)),
            ("series", Json::Str(series.to_string())),
            ("v", Json::num(v)),
        ])
    }

    fn fault_records() -> Vec<Json> {
        vec![
            ev(100.0, "fault-start", vec![
                ("fault", Json::num(0.0)),
                ("label", Json::Str("feed-loss".to_string())),
            ]),
            ev(110.0, "violation-start", vec![("over_w", Json::num(500.0))]),
            ev(120.0, "cap-issued", vec![
                ("class", Json::Str("lp".to_string())),
                ("mhz", Json::num(990.0)),
            ]),
            ev(125.0, "cap-acked", vec![
                ("class", Json::Str("lp".to_string())),
                ("mhz", Json::num(990.0)),
            ]),
            ev(130.0, "violation-contained", vec![]),
            ev(400.0, "fault-end", vec![
                ("fault", Json::num(0.0)),
                ("label", Json::Str("feed-loss".to_string())),
            ]),
            sample(115.0, "row-power", 1.1),
        ]
    }

    #[test]
    fn jsonl_roundtrips() {
        let records = fault_records();
        let text = to_jsonl(&records);
        assert_eq!(text.lines().count(), records.len());
        let back = parse_jsonl(&text).unwrap();
        assert_eq!(back, records);
    }

    #[test]
    fn parse_jsonl_reports_the_bad_line() {
        let err = parse_jsonl("{\"type\":\"meta\"}\nnot json\n").unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }

    #[test]
    fn timeline_groups_events_under_their_fault() {
        let tls = incident_timeline(&fault_records());
        assert_eq!(tls.len(), 1);
        let tl = &tls[0];
        assert_eq!(tl.label, "feed-loss");
        assert_eq!(tl.start_s, 100.0);
        assert_eq!(tl.end_s, 400.0);
        assert!(tl.contained);
        let whats: Vec<&str> = tl.entries.iter().map(|e| e.what.as_str()).collect();
        assert!(whats.iter().any(|w| w.contains("cap-issued lp 990MHz")), "{whats:?}");
        assert!(whats.iter().any(|w| w.contains("violation-contained")), "{whats:?}");
        let rendered = render_timeline(&tls);
        assert!(rendered.contains("incident 1: feed-loss [100s .. 400s] — contained"), "{rendered}");
    }

    #[test]
    fn uncontained_violation_is_flagged() {
        let mut records = fault_records();
        // Drop the containment event: the window stays violating.
        records.retain(|r| r.get("event").and_then(Json::as_str) != Some("violation-contained"));
        let tls = incident_timeline(&records);
        assert!(!tls[0].contained);
        assert!(render_timeline(&tls).contains("NOT contained"));
    }

    #[test]
    fn faultfree_traces_build_violation_incidents() {
        let records = vec![
            ev(10.0, "violation-start", vec![("over_w", Json::num(100.0))]),
            ev(12.0, "cap-issued", vec![
                ("class", Json::Str("lp".to_string())),
                ("mhz", Json::num(990.0)),
            ]),
            ev(20.0, "violation-contained", vec![]),
        ];
        let tls = incident_timeline(&records);
        assert_eq!(tls.len(), 1);
        assert_eq!(tls[0].label, "violation");
        assert!(tls[0].contained);
        assert_eq!(tls[0].entries.len(), 3);
    }

    #[test]
    fn chrome_export_has_trace_events() {
        let doc = to_chrome(&fault_records());
        let tes = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(tes.len(), 7);
        let first = &tes[0];
        assert_eq!(first.get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(first.get("ts").unwrap().as_f64(), Some(100.0 * 1e6));
        // Counter samples carry args.value.
        let counter = tes.iter().find(|t| t.get("ph").unwrap().as_str() == Some("C")).unwrap();
        assert_eq!(counter.at(&["args", "value"]).unwrap().as_f64(), Some(1.1));
    }

    #[test]
    fn csv_is_long_format() {
        let csv = to_csv(&fault_records()).to_string();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("t_s,kind,name,value"));
        assert!(csv.contains("120,event,cap-issued,990"), "{csv}");
        assert!(csv.contains("115,sample,row-power,1.1"), "{csv}");
    }

    #[test]
    fn summarize_counts_types_and_labels() {
        let s = summarize(&fault_records());
        assert!(s.contains("7 records"), "{s}");
        assert!(s.contains("event: 6") || s.contains("event:    6") || s.contains("event: 6\n"), "{s}");
        assert!(s.contains("cap-issued"), "{s}");
    }
}
