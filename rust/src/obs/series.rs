//! Ring-buffered, decimating time-series recorders.
//!
//! A [`SeriesRecorder`] stores at most `capacity` points over an
//! arbitrarily long run: it keeps every `stride`-th offered sample, and
//! whenever the buffer fills it drops every other retained point and
//! doubles the stride. The result is a uniformly-thinned view whose
//! resolution degrades gracefully (never a hard truncation at the front
//! or back of the run). Finished recorders detach into [`Series`]
//! values that are usable without re-running a `Sim`.

use crate::util::json::Json;

/// Identity of a built-in recorded series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeriesId {
    /// True scaled row power as a fraction of the nominal budget.
    RowPower,
    /// Power as the policy sees it (meter bias × averaging window),
    /// normalized to the effective budget.
    ReportedPower,
    /// Effective budget fraction (feed loss pulls it below 1.0).
    BudgetFrac,
    /// Servers with a request queued behind an in-flight one.
    QueueDepth,
    /// Servers currently under a frequency cap (all of them while the
    /// brake is engaged).
    ActiveCaps,
}

impl SeriesId {
    /// Every built-in series, in storage order.
    pub const ALL: [SeriesId; 5] = [
        SeriesId::RowPower,
        SeriesId::ReportedPower,
        SeriesId::BudgetFrac,
        SeriesId::QueueDepth,
        SeriesId::ActiveCaps,
    ];

    /// Stable kebab-case name used in exports.
    pub fn name(self) -> &'static str {
        match self {
            SeriesId::RowPower => "row-power",
            SeriesId::ReportedPower => "reported-power",
            SeriesId::BudgetFrac => "budget-frac",
            SeriesId::QueueDepth => "queue-depth",
            SeriesId::ActiveCaps => "active-caps",
        }
    }
}

/// Bounded time-series recorder (see module docs for the decimation
/// scheme).
#[derive(Debug, Clone)]
pub struct SeriesRecorder {
    capacity: usize,
    stride: u64,
    seen: u64,
    points: Vec<(f64, f64)>,
}

impl SeriesRecorder {
    /// New recorder bounded to `capacity` retained points (min 8).
    pub fn new(capacity: usize) -> SeriesRecorder {
        SeriesRecorder { capacity: capacity.max(8), stride: 1, seen: 0, points: Vec::new() }
    }

    /// Offer one `(t_s, value)` sample; retained iff it falls on the
    /// current stride.
    pub fn push(&mut self, t_s: f64, value: f64) {
        if self.seen % self.stride == 0 {
            self.points.push((t_s, value));
            if self.points.len() >= self.capacity {
                let mut i = 0usize;
                self.points.retain(|_| {
                    let keep = i % 2 == 0;
                    i += 1;
                    keep
                });
                self.stride *= 2;
            }
        }
        self.seen += 1;
    }

    /// Retained `(t_s, value)` points, in time order.
    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// Current decimation stride (1 = every sample retained).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Total samples offered, before decimation.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Detach into a first-class [`Series`].
    pub fn into_series(self, id: SeriesId) -> Series {
        Series {
            name: id.name().to_string(),
            stride: self.stride,
            seen: self.seen,
            points: self.points,
        }
    }
}

/// A finished, owned time series detached from any `Sim`.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    /// Export name (kebab-case, see [`SeriesId::name`]).
    pub name: String,
    /// Final decimation stride (1 = every sample retained).
    pub stride: u64,
    /// Total samples offered, before decimation.
    pub seen: u64,
    /// Retained `(t_s, value)` points, in time order.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Summary object (name, stride, seen, retained count) used in the
    /// trace meta record; the points themselves export as `sample`
    /// records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("stride", Json::num(self.stride as f64)),
            ("seen", Json::num(self.seen as f64)),
            ("retained", Json::num(self.points.len() as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_runs_keep_every_sample() {
        let mut r = SeriesRecorder::new(64);
        for i in 0..50 {
            r.push(i as f64, i as f64 * 2.0);
        }
        assert_eq!(r.points().len(), 50);
        assert_eq!(r.stride(), 1);
        assert_eq!(r.seen(), 50);
    }

    #[test]
    fn long_runs_decimate_under_the_capacity_bound() {
        let cap = 64;
        let mut r = SeriesRecorder::new(cap);
        for i in 0..100_000u64 {
            r.push(i as f64, 0.0);
        }
        assert!(r.points().len() < cap, "len {} >= cap {cap}", r.points().len());
        assert!(r.stride() > 1);
        assert_eq!(r.seen(), 100_000);
        // Retained points stay uniformly spread: strictly increasing
        // timestamps from near the start to near the end of the run.
        let pts = r.points();
        assert!(pts.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(pts[0].0 < 1024.0, "front of run dropped: first t = {}", pts[0].0);
        assert!(pts[pts.len() - 1].0 > 90_000.0, "tail of run dropped");
    }

    #[test]
    fn retained_points_fall_on_the_stride() {
        let mut r = SeriesRecorder::new(8);
        for i in 0..1000u64 {
            r.push(i as f64, 0.0);
        }
        let stride = r.stride() as f64;
        for &(t, _) in r.points() {
            // Sample i carries t = i here, so every retained t must be
            // a multiple of the final stride.
            assert_eq!(t % stride, 0.0, "t {t} not on stride {stride}");
        }
    }

    #[test]
    fn series_detaches_with_metadata() {
        let mut r = SeriesRecorder::new(8);
        r.push(0.0, 1.0);
        r.push(1.0, 2.0);
        let s = r.into_series(SeriesId::RowPower);
        assert_eq!(s.name, "row-power");
        assert_eq!(s.points, vec![(0.0, 1.0), (1.0, 2.0)]);
        assert_eq!(s.to_json().get("retained").unwrap().as_usize(), Some(2));
    }
}
