//! Wall-clock spans: profiling the batch-executor hot path.
//!
//! Unlike [`events`](crate::obs::events) and
//! [`series`](crate::obs::series) (which carry *simulation* time),
//! spans carry *wall-clock* time relative to a profile start. They are
//! produced per-item by [`crate::exec::run_batch_profiled`] and
//! establish the raw-speed baseline the ROADMAP's event-loop
//! optimization item is judged against. Chrome trace export renders
//! them as `X` (complete) events, one lane per worker thread.

use crate::util::json::Json;

/// One wall-clock span.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Label, e.g. `item-7` for the 8th batch item.
    pub name: String,
    /// Seconds from profile start to span start.
    pub start_s: f64,
    /// Span duration in seconds.
    pub dur_s: f64,
    /// Worker thread index that executed the span (0 when serial).
    pub worker: usize,
}

impl Span {
    /// Seconds from profile start to span end.
    pub fn end_s(&self) -> f64 {
        self.start_s + self.dur_s
    }

    /// Serialize to one trace record (`{"type": "span", ...}`).
    pub fn to_record(&self) -> Json {
        Json::obj(vec![
            ("type", Json::Str("span".to_string())),
            ("name", Json::Str(self.name.clone())),
            ("start_s", Json::num(self.start_s)),
            ("dur_s", Json::num(self.dur_s)),
            ("worker", Json::num(self.worker as f64)),
        ])
    }
}

/// Aggregate utilization over one profiled batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchProfile {
    /// Number of spans (batch items).
    pub items: usize,
    /// Wall-clock seconds from profile start to the last span end.
    pub wall_s: f64,
    /// Busy seconds summed across all workers.
    pub busy_s: f64,
    /// Worker count the utilization is computed against.
    pub workers: usize,
    /// `busy_s / (wall_s × workers)`; 1.0 means perfectly packed.
    pub busy_frac: f64,
}

/// Summarize the spans of one profiled batch against `workers` lanes.
pub fn batch_stats(spans: &[Span], workers: usize) -> BatchProfile {
    let workers = workers.max(1);
    let wall_s = spans.iter().map(Span::end_s).fold(0.0f64, f64::max);
    let busy_s = spans.iter().map(|s| s.dur_s).sum::<f64>();
    let denom = wall_s * workers as f64;
    let busy_frac = if denom > 0.0 { busy_s / denom } else { 0.0 };
    BatchProfile { items: spans.len(), wall_s, busy_s, workers, busy_frac }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(start_s: f64, dur_s: f64, worker: usize) -> Span {
        Span { name: format!("item-{worker}"), start_s, dur_s, worker }
    }

    #[test]
    fn batch_stats_measures_wall_and_busy_time() {
        let spans = vec![span(0.0, 1.0, 0), span(0.0, 2.0, 1), span(1.0, 1.0, 0)];
        let p = batch_stats(&spans, 2);
        assert_eq!(p.items, 3);
        assert_eq!(p.wall_s, 2.0);
        assert_eq!(p.busy_s, 4.0);
        assert_eq!(p.busy_frac, 1.0);
    }

    #[test]
    fn empty_batch_is_all_zero_not_nan() {
        let p = batch_stats(&[], 4);
        assert_eq!(p.wall_s, 0.0);
        assert_eq!(p.busy_frac, 0.0);
    }

    #[test]
    fn span_record_shape() {
        let r = span(0.5, 0.25, 3).to_record();
        assert_eq!(r.get("type").unwrap().as_str(), Some("span"));
        assert_eq!(r.get("start_s").unwrap().as_f64(), Some(0.5));
        assert_eq!(r.get("dur_s").unwrap().as_f64(), Some(0.25));
        assert_eq!(r.get("worker").unwrap().as_usize(), Some(3));
    }
}
