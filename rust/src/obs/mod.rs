//! Observability: a zero-cost-when-off trace/metrics layer for the
//! whole control loop.
//!
//! The simulator's layers emit structured [`events`](self::events),
//! decimated time [`series`](self::series), and hot-path counters
//! through the passive [`Observer`] trait. The default
//! [`NoopObserver`] sets [`Observer::ENABLED`] to `false`, so every
//! emission site — guarded by `if O::ENABLED` — monomorphizes away and
//! the unobserved simulation is bit-identical to (and as fast as) one
//! with no observability compiled in. A [`Recorder`] captures
//! everything into a first-class [`Trace`] value that outlives the
//! run; [`export`](self::export) serializes traces to JSONL, CSV, and
//! Chrome trace-event form, and renders per-incident timelines.
//!
//! Observation is strictly read-only: an observer receives copies of
//! values the simulation already computed and has no channel back into
//! it, which is what makes the passivity property testable
//! (`tests/integration_obs.rs` proves recording never perturbs a
//! `RunReport`).
//!
//! The module also hosts the library's quiet-by-default diagnostic
//! hook ([`set_diag_handler`]): rare, human-relevant notices (like a
//! one-time calibration fit) go through [`DiagEvent`] instead of
//! `eprintln!`, so embedding applications control the channel.

pub mod events;
pub mod export;
pub mod series;
pub mod spans;

use std::collections::VecDeque;
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::json::Json;

pub use events::{Event, EventKind};
pub use series::{Series, SeriesId, SeriesRecorder};
pub use spans::{batch_stats, BatchProfile, Span};

/// Passive sink for simulation observations.
///
/// All hooks have empty default bodies; implementors override what
/// they need. Emission sites in the simulator are guarded by
/// [`Observer::ENABLED`], so with [`NoopObserver`] the compiler
/// removes them entirely — the trait is threaded as a generic (not a
/// trait object) for exactly this reason.
pub trait Observer {
    /// Whether emission sites should run at all. `true` for every real
    /// observer; [`NoopObserver`] overrides it to `false`.
    const ENABLED: bool = true;

    /// A control-loop lifecycle event at sim time `t_s`.
    fn event(&mut self, _t_s: f64, _kind: EventKind) {}

    /// One sample of a built-in time series at sim time `t_s`.
    fn sample(&mut self, _id: SeriesId, _t_s: f64, _value: f64) {}

    /// The accounting layer settled an energy segment (hot-path
    /// counter; called very frequently).
    fn settle(&mut self) {}

    /// A named end-of-run counter (e.g. total events dispatched).
    fn counter(&mut self, _name: &'static str, _value: u64) {}
}

/// The default do-nothing observer; disables every emission site at
/// compile time.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopObserver;

impl Observer for NoopObserver {
    const ENABLED: bool = false;
}

/// Fan one observation stream out to two observers — e.g. a
/// [`Recorder`] capturing a trace while a live broadcaster forwards
/// the same records to streaming subscribers (`polca gateway`).
///
/// `ENABLED` is the OR of the two sides, and every hook re-checks each
/// side's own `ENABLED`, so teeing onto a [`NoopObserver`] costs that
/// side nothing. Both sides receive identical copies; the tee adds no
/// channel back into the simulation, so the passivity property holds
/// exactly as it does for a single observer.
#[derive(Debug)]
pub struct Tee<'a, A, B>(pub &'a mut A, pub &'a mut B);

impl<A: Observer, B: Observer> Observer for Tee<'_, A, B> {
    const ENABLED: bool = A::ENABLED || B::ENABLED;

    fn event(&mut self, t_s: f64, kind: EventKind) {
        if A::ENABLED {
            self.0.event(t_s, kind);
        }
        if B::ENABLED {
            self.1.event(t_s, kind);
        }
    }

    fn sample(&mut self, id: SeriesId, t_s: f64, value: f64) {
        if A::ENABLED {
            self.0.sample(id, t_s, value);
        }
        if B::ENABLED {
            self.1.sample(id, t_s, value);
        }
    }

    fn settle(&mut self) {
        if A::ENABLED {
            self.0.settle();
        }
        if B::ENABLED {
            self.1.settle();
        }
    }

    fn counter(&mut self, name: &'static str, value: u64) {
        if A::ENABLED {
            self.0.counter(name, value);
        }
        if B::ENABLED {
            self.1.counter(name, value);
        }
    }
}

/// Capacity bounds for a [`Recorder`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecorderConfig {
    /// Event ring capacity; the oldest events drop past this (the drop
    /// count is kept and exported in the trace meta record).
    pub max_events: usize,
    /// Per-series retained-point bound before decimation kicks in
    /// (see [`SeriesRecorder`]).
    pub series_capacity: usize,
}

impl Default for RecorderConfig {
    fn default() -> RecorderConfig {
        RecorderConfig { max_events: 1 << 20, series_capacity: 4096 }
    }
}

/// An [`Observer`] that records everything into memory, bounded by a
/// [`RecorderConfig`]; detach the result with [`Recorder::into_trace`].
#[derive(Debug)]
pub struct Recorder {
    cfg: RecorderConfig,
    started: Instant,
    events: VecDeque<Event>,
    dropped_events: u64,
    series: Vec<SeriesRecorder>,
    settle_calls: u64,
    counters: Vec<(&'static str, u64)>,
}

impl Recorder {
    /// New recorder with the given bounds.
    pub fn new(cfg: RecorderConfig) -> Recorder {
        Recorder {
            cfg,
            started: Instant::now(),
            events: VecDeque::new(),
            dropped_events: 0,
            series: SeriesId::ALL.iter().map(|_| SeriesRecorder::new(cfg.series_capacity)).collect(),
            settle_calls: 0,
            counters: Vec::new(),
        }
    }

    /// Events recorded so far (ring-bounded), in emission order.
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    /// Detach into a first-class [`Trace`] named `name`.
    pub fn into_trace(self, name: &str) -> Trace {
        let wall_s = self.started.elapsed().as_secs_f64();
        let mut counters: Vec<(String, u64)> =
            self.counters.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        counters.push(("settle-calls".to_string(), self.settle_calls));
        Trace {
            name: name.to_string(),
            events: self.events.into_iter().collect(),
            dropped_events: self.dropped_events,
            series: self
                .series
                .into_iter()
                .zip(SeriesId::ALL)
                .map(|(r, id)| r.into_series(id))
                .collect(),
            counters,
            spans: Vec::new(),
            wall_s,
        }
    }
}

impl Observer for Recorder {
    fn event(&mut self, t_s: f64, kind: EventKind) {
        if self.events.len() >= self.cfg.max_events {
            self.events.pop_front();
            self.dropped_events += 1;
        }
        self.events.push_back(Event { t_s, kind });
    }

    fn sample(&mut self, id: SeriesId, t_s: f64, value: f64) {
        let idx = SeriesId::ALL.iter().position(|&s| s == id).unwrap_or(0);
        self.series[idx].push(t_s, value);
    }

    fn settle(&mut self) {
        self.settle_calls += 1;
    }

    fn counter(&mut self, name: &'static str, value: u64) {
        self.counters.push((name, value));
    }
}

/// A finished recording, detached from any `Sim`.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Trace name (scenario name for CLI-produced traces).
    pub name: String,
    /// Recorded events in emission order (oldest dropped past the
    /// recorder's ring bound).
    pub events: Vec<Event>,
    /// Events lost to the ring bound.
    pub dropped_events: u64,
    /// One decimated series per [`SeriesId`], in `SeriesId::ALL` order.
    pub series: Vec<Series>,
    /// End-of-run counters (name, value).
    pub counters: Vec<(String, u64)>,
    /// Wall-clock spans, when the trace came from a profiled batch
    /// (empty for single runs).
    pub spans: Vec<Span>,
    /// Wall-clock seconds the recording covered.
    pub wall_s: f64,
}

impl Trace {
    /// The canonical serialized form: a flat record list (meta first,
    /// then counters, spans, series samples, and events) consumed by
    /// every [`export`](self::export) writer. A JSONL file written
    /// from these records and re-loaded with
    /// [`export::parse_jsonl`] yields the same list.
    pub fn records(&self) -> Vec<Json> {
        let mut out = Vec::with_capacity(
            2 + self.counters.len()
                + self.spans.len()
                + self.events.len()
                + self.series.iter().map(|s| s.points.len()).sum::<usize>(),
        );
        out.push(Json::obj(vec![
            ("type", Json::Str("meta".to_string())),
            ("name", Json::Str(self.name.clone())),
            ("wall_s", Json::num(self.wall_s)),
            ("dropped_events", Json::num(self.dropped_events as f64)),
            ("series", Json::arr(self.series.iter().map(|s| s.to_json()))),
        ]));
        for (name, v) in &self.counters {
            out.push(Json::obj(vec![
                ("type", Json::Str("counter".to_string())),
                ("name", Json::Str(name.clone())),
                ("v", Json::num(*v as f64)),
            ]));
        }
        for span in &self.spans {
            out.push(span.to_record());
        }
        for s in &self.series {
            for &(t_s, v) in &s.points {
                out.push(Json::obj(vec![
                    ("type", Json::Str("sample".to_string())),
                    ("t_s", Json::num(t_s)),
                    ("series", Json::Str(s.name.clone())),
                    ("v", Json::num(v)),
                ]));
            }
        }
        for e in &self.events {
            out.push(e.to_record());
        }
        out
    }

    /// Serialize as JSON Lines (see [`Trace::records`]).
    pub fn to_jsonl(&self) -> String {
        export::to_jsonl(&self.records())
    }
}

/// A rare, human-relevant library notice (not a per-run trace event).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DiagEvent {
    /// A one-time power-scale calibration fit is starting (it costs a
    /// one-day baseline simulation; the result is cached afterwards).
    CalibrationFit {
        /// Row size (server count) being fitted.
        baseline_servers: usize,
    },
    /// A region plan finished: `sites` sites were allocated using only
    /// `archetype_sims` discrete-event simulations and
    /// `candidate_evals` closed-form trace evaluations (the count pair
    /// that demonstrates planning cost is independent of region size).
    RegionPlanned {
        /// Sites in the planned region.
        sites: usize,
        /// Simulations run to fill the archetype cache.
        archetype_sims: usize,
        /// Closed-form candidate evaluations performed.
        candidate_evals: usize,
    },
    /// The adaptive controller moved a knob (the announcement a human
    /// watching a long run wants; the per-window detail stays in the
    /// trace as `retune-*` events).
    RetuneApplied {
        /// Simulation time of the retune.
        t_s: f64,
        /// Active-server level after the step.
        added: f64,
        /// T1 after the step.
        t1: f64,
        /// T2 after the step.
        t2: f64,
    },
    /// The gateway daemon bound its listener and is accepting
    /// submissions (`polca gateway`).
    GatewayStarted {
        /// TCP port the daemon is listening on.
        port: u16,
        /// HTTP worker threads serving connections.
        http_workers: usize,
        /// Run-queue worker threads executing scenarios.
        run_workers: usize,
    },
    /// The gateway accepted a scenario submission into its run queue.
    RunAccepted {
        /// Submission sequence number (run id `run-{seq:06}`).
        run_seq: u64,
        /// Runs waiting in the queue after this one was enqueued.
        queued: usize,
    },
    /// A gateway event-stream subscriber fell behind its bounded queue
    /// and was dropped (slow consumers never backpressure the run).
    SubscriberDropped {
        /// Submission sequence number of the run being streamed.
        run_seq: u64,
        /// Records pending for the subscriber when it was dropped.
        pending: usize,
    },
}

static DIAG: OnceLock<Box<dyn Fn(&DiagEvent) + Send + Sync>> = OnceLock::new();

/// Install the process-wide diagnostic handler. The library default is
/// quiet (no handler, notices dropped); the CLI installs a stderr
/// printer at startup. Returns `false` if a handler was already set
/// (the first installation wins).
pub fn set_diag_handler(handler: Box<dyn Fn(&DiagEvent) + Send + Sync>) -> bool {
    DIAG.set(handler).is_ok()
}

/// Emit a diagnostic notice to the installed handler, if any.
pub fn emit_diag(event: &DiagEvent) {
    if let Some(handler) = DIAG.get() {
        handler(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_observer_is_disabled_at_compile_time() {
        assert!(!NoopObserver::ENABLED);
        assert!(Recorder::ENABLED);
    }

    #[test]
    fn recorder_ring_drops_oldest_events() {
        let mut rec = Recorder::new(RecorderConfig { max_events: 4, series_capacity: 64 });
        for i in 0..10 {
            rec.event(i as f64, EventKind::BrakeEngaged);
        }
        assert_eq!(rec.events().count(), 4);
        let trace = rec.into_trace("ring");
        assert_eq!(trace.dropped_events, 6);
        assert_eq!(trace.events[0].t_s, 6.0);
    }

    #[test]
    fn trace_records_cover_every_stream() {
        let mut rec = Recorder::new(RecorderConfig::default());
        rec.event(1.0, EventKind::BrakeEngaged);
        rec.sample(SeriesId::RowPower, 1.0, 0.9);
        rec.settle();
        rec.settle();
        rec.counter("events-dispatched", 42);
        let mut trace = rec.into_trace("t");
        trace.spans.push(Span { name: "item-0".to_string(), start_s: 0.0, dur_s: 0.1, worker: 0 });
        let records = trace.records();
        let types: Vec<&str> =
            records.iter().filter_map(|r| r.get("type").and_then(Json::as_str)).collect();
        for need in ["meta", "counter", "span", "sample", "event"] {
            assert!(types.contains(&need), "missing {need} in {types:?}");
        }
        assert_eq!(records.len(), 1 + 2 + 1 + 1 + 1);
        // settle-calls is folded into the counters.
        assert!(records.iter().any(|r| {
            r.get("name").and_then(Json::as_str) == Some("settle-calls")
                && r.get("v").and_then(Json::as_f64) == Some(2.0)
        }));
        // Round-trip through JSONL is lossless at the record level.
        let back = export::parse_jsonl(&trace.to_jsonl()).unwrap();
        assert_eq!(back, records);
    }

    #[test]
    fn tee_fans_out_to_both_observers() {
        let mut a = Recorder::new(RecorderConfig::default());
        let mut b = Recorder::new(RecorderConfig::default());
        {
            let mut tee = Tee(&mut a, &mut b);
            tee.event(1.0, EventKind::BrakeEngaged);
            tee.sample(SeriesId::RowPower, 1.0, 0.5);
            tee.counter("events-dispatched", 3);
            tee.settle();
        }
        for rec in [&a, &b] {
            assert_eq!(rec.events().count(), 1);
        }
        let ta = a.into_trace("a");
        let tb = b.into_trace("b");
        assert_eq!(ta.events, tb.events);
        assert_eq!(ta.counters, tb.counters);
        assert!(<Tee<'static, Recorder, NoopObserver> as Observer>::ENABLED);
        assert!(!<Tee<'static, NoopObserver, NoopObserver> as Observer>::ENABLED);
    }

    #[test]
    fn diag_is_quiet_without_a_handler_and_single_install() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEEN: AtomicUsize = AtomicUsize::new(0);
        // No handler yet: must not panic, just drop.
        emit_diag(&DiagEvent::CalibrationFit { baseline_servers: 7 });
        let first = set_diag_handler(Box::new(|_| {
            SEEN.fetch_add(1, Ordering::SeqCst);
        }));
        emit_diag(&DiagEvent::CalibrationFit { baseline_servers: 7 });
        if first {
            assert!(SEEN.load(Ordering::SeqCst) >= 1);
            // A second installation is rejected; the first handler stays.
            assert!(!set_diag_handler(Box::new(|_| {})));
        }
    }
}
