//! Structured control-loop events with simulation timestamps.
//!
//! [`EventKind`] enumerates every lifecycle transition the control loop
//! makes: command issue/ack through the OOB channel, brake engage and
//! release, fault episodes, budget-violation windows, telemetry reads,
//! and training phase changes. Events are cheap `Copy` values stamped
//! with sim-time seconds by the emitting layer; the
//! [`Recorder`](crate::obs::Recorder) ring-buffers them and
//! [`export`](crate::obs::export) serializes them to JSONL / CSV /
//! Chrome trace-event form.

use crate::cluster::hierarchy::Priority;
use crate::util::json::Json;

/// Export name for a priority class.
fn class_str(p: Priority) -> &'static str {
    match p {
        Priority::Low => "lp",
        Priority::High => "hp",
    }
}

/// One lifecycle transition in the control loop.
///
/// Fault labels and entity ids are `Copy`-friendly (`&'static str` /
/// indices) so emission sites stay allocation-free.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A frequency-cap command entered the OOB channel.
    CapIssued {
        /// Priority class being capped.
        class: Priority,
        /// Commanded frequency ceiling.
        mhz: f64,
    },
    /// An uncap command entered the OOB channel.
    UncapIssued {
        /// Priority class being released.
        class: Priority,
    },
    /// A command timed out without an ack and was re-issued
    /// (the re-issue itself also appears as its own issue event).
    CapReissued {
        /// Priority class of the stale intent.
        class: Priority,
        /// The re-commanded ceiling; `None` when the intent is uncap.
        mhz: Option<f64>,
    },
    /// A frequency-cap command was delivered and acknowledged.
    CapAcked {
        /// Priority class that acknowledged.
        class: Priority,
        /// Acknowledged frequency ceiling.
        mhz: f64,
    },
    /// An uncap command was delivered and acknowledged.
    UncapAcked {
        /// Priority class that acknowledged.
        class: Priority,
    },
    /// A power-brake command entered the OOB channel.
    BrakeIssued,
    /// A brake-release command entered the OOB channel.
    BrakeReleaseIssued,
    /// The row-wide power brake took effect.
    BrakeEngaged,
    /// The row-wide power brake was released.
    BrakeReleased,
    /// An injected fault episode began.
    FaultStart {
        /// Index of the episode in the run's fault plan.
        fault: u32,
        /// Fault-kind label (e.g. `feed-loss`).
        label: &'static str,
    },
    /// An injected fault episode ended.
    FaultEnd {
        /// Index of the episode in the run's fault plan.
        fault: u32,
        /// Fault-kind label (e.g. `feed-loss`).
        label: &'static str,
    },
    /// Scaled row power crossed above the effective budget.
    ///
    /// Stamped at the start of the settled segment that first exceeded
    /// the budget, which can precede the emission instant.
    ViolationStart {
        /// Watts over the effective budget when the window opened.
        over_w: f64,
    },
    /// Scaled row power dropped back under the effective budget.
    ViolationContained,
    /// The control plane read the averaged power meter.
    Telemetry {
        /// Reading as seen by the policy (normalized to budget; includes
        /// meter bias and the averaging window).
        reported: f64,
    },
    /// A training job moved to a new iteration phase.
    TrainPhase {
        /// Training job index.
        job: u32,
        /// Phase index within the iteration (0-based).
        phase: u32,
        /// Relative power level the phase pushes to its servers.
        level: f64,
    },
    /// A training job completed one full iteration.
    TrainIter {
        /// Training job index.
        job: u32,
        /// Wall-clock (sim) seconds the iteration took.
        wall_s: f64,
    },
    /// The adaptive controller evaluated a control window and held.
    RetuneEval {
        /// The window's peak normalized row-power reading.
        peak: f64,
    },
    /// The adaptive controller moved a knob.
    RetuneApply {
        /// Active-server level after the step.
        added: f64,
        /// T1 after the step.
        t1: f64,
        /// T2 after the step.
        t2: f64,
    },
    /// An eligible raise was blocked by the post-violation safety clamp.
    RetuneVeto {
        /// The level the clamp held the row at.
        added: f64,
    },
}

impl EventKind {
    /// Stable kebab-case label used in exports and timelines.
    pub fn label(self) -> &'static str {
        match self {
            EventKind::CapIssued { .. } => "cap-issued",
            EventKind::UncapIssued { .. } => "uncap-issued",
            EventKind::CapReissued { .. } => "cap-reissued",
            EventKind::CapAcked { .. } => "cap-acked",
            EventKind::UncapAcked { .. } => "uncap-acked",
            EventKind::BrakeIssued => "brake-issued",
            EventKind::BrakeReleaseIssued => "brake-release-issued",
            EventKind::BrakeEngaged => "brake-engaged",
            EventKind::BrakeReleased => "brake-released",
            EventKind::FaultStart { .. } => "fault-start",
            EventKind::FaultEnd { .. } => "fault-end",
            EventKind::ViolationStart { .. } => "violation-start",
            EventKind::ViolationContained => "violation-contained",
            EventKind::Telemetry { .. } => "telemetry",
            EventKind::TrainPhase { .. } => "train-phase",
            EventKind::TrainIter { .. } => "train-iter",
            EventKind::RetuneEval { .. } => "retune-eval",
            EventKind::RetuneApply { .. } => "retune-apply",
            EventKind::RetuneVeto { .. } => "retune-veto",
        }
    }
}

/// A timestamped [`EventKind`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Simulation time of the transition, in seconds.
    pub t_s: f64,
    /// What happened.
    pub kind: EventKind,
}

impl Event {
    /// Stable kebab-case label of the underlying kind.
    pub fn label(&self) -> &'static str {
        self.kind.label()
    }

    /// Serialize to one trace record (`{"type": "event", ...}`).
    pub fn to_record(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("type", Json::Str("event".to_string())),
            ("t_s", Json::num(self.t_s)),
            ("event", Json::Str(self.label().to_string())),
        ];
        match self.kind {
            EventKind::CapIssued { class, mhz } | EventKind::CapAcked { class, mhz } => {
                pairs.push(("class", Json::Str(class_str(class).to_string())));
                pairs.push(("mhz", Json::num(mhz)));
            }
            EventKind::UncapIssued { class } | EventKind::UncapAcked { class } => {
                pairs.push(("class", Json::Str(class_str(class).to_string())));
            }
            EventKind::CapReissued { class, mhz } => {
                pairs.push(("class", Json::Str(class_str(class).to_string())));
                if let Some(mhz) = mhz {
                    pairs.push(("mhz", Json::num(mhz)));
                }
            }
            EventKind::BrakeIssued
            | EventKind::BrakeReleaseIssued
            | EventKind::BrakeEngaged
            | EventKind::BrakeReleased
            | EventKind::ViolationContained => {}
            EventKind::FaultStart { fault, label } | EventKind::FaultEnd { fault, label } => {
                pairs.push(("fault", Json::num(fault as f64)));
                pairs.push(("label", Json::Str(label.to_string())));
            }
            EventKind::ViolationStart { over_w } => {
                pairs.push(("over_w", Json::num(over_w)));
            }
            EventKind::Telemetry { reported } => {
                pairs.push(("reported", Json::num(reported)));
            }
            EventKind::TrainPhase { job, phase, level } => {
                pairs.push(("job", Json::num(job as f64)));
                pairs.push(("phase", Json::num(phase as f64)));
                pairs.push(("level", Json::num(level)));
            }
            EventKind::TrainIter { job, wall_s } => {
                pairs.push(("job", Json::num(job as f64)));
                pairs.push(("wall_s", Json::num(wall_s)));
            }
            EventKind::RetuneEval { peak } => {
                pairs.push(("peak", Json::num(peak)));
            }
            EventKind::RetuneApply { added, t1, t2 } => {
                pairs.push(("added", Json::num(added)));
                pairs.push(("t1", Json::num(t1)));
                pairs.push(("t2", Json::num(t2)));
            }
            EventKind::RetuneVeto { added } => {
                pairs.push(("added", Json::num(added)));
            }
        }
        Json::obj(pairs)
    }

    /// One-line human rendering used by timelines (label plus the
    /// fields that matter at a glance).
    pub fn describe(&self) -> String {
        match self.kind {
            EventKind::CapIssued { class, mhz } => {
                format!("cap-issued {} {:.0}MHz", class_str(class), mhz)
            }
            EventKind::UncapIssued { class } => format!("uncap-issued {}", class_str(class)),
            EventKind::CapReissued { class, mhz } => match mhz {
                Some(mhz) => format!("cap-reissued {} {:.0}MHz", class_str(class), mhz),
                None => format!("cap-reissued {} (uncap)", class_str(class)),
            },
            EventKind::CapAcked { class, mhz } => {
                format!("cap-acked {} {:.0}MHz", class_str(class), mhz)
            }
            EventKind::UncapAcked { class } => format!("uncap-acked {}", class_str(class)),
            EventKind::FaultStart { label, .. } => format!("fault-start {label}"),
            EventKind::FaultEnd { label, .. } => format!("fault-end {label}"),
            EventKind::ViolationStart { over_w } => {
                format!("violation-start (+{over_w:.0}W over budget)")
            }
            EventKind::Telemetry { reported } => format!("telemetry {reported:.3}"),
            EventKind::TrainPhase { job, phase, level } => {
                format!("train-phase job {job} phase {phase} level {level:.2}")
            }
            EventKind::TrainIter { job, wall_s } => {
                format!("train-iter job {job} done in {wall_s:.1}s")
            }
            EventKind::RetuneEval { peak } => format!("retune-eval peak {peak:.3}"),
            EventKind::RetuneApply { added, t1, t2 } => format!(
                "retune-apply +{:.0}% T1 {:.0}% T2 {:.0}%",
                added * 100.0,
                t1 * 100.0,
                t2 * 100.0
            ),
            EventKind::RetuneVeto { added } => {
                format!("retune-veto held at +{:.0}%", added * 100.0)
            }
            _ => self.label().to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_carry_label_time_and_fields() {
        let e = Event {
            t_s: 12.5,
            kind: EventKind::CapIssued { class: Priority::Low, mhz: 990.0 },
        };
        let r = e.to_record();
        assert_eq!(r.get("type").unwrap().as_str(), Some("event"));
        assert_eq!(r.get("t_s").unwrap().as_f64(), Some(12.5));
        assert_eq!(r.get("event").unwrap().as_str(), Some("cap-issued"));
        assert_eq!(r.get("class").unwrap().as_str(), Some("lp"));
        assert_eq!(r.get("mhz").unwrap().as_f64(), Some(990.0));
    }

    #[test]
    fn every_kind_has_a_distinct_label() {
        let kinds = [
            EventKind::CapIssued { class: Priority::Low, mhz: 1.0 },
            EventKind::UncapIssued { class: Priority::Low },
            EventKind::CapReissued { class: Priority::Low, mhz: None },
            EventKind::CapAcked { class: Priority::High, mhz: 1.0 },
            EventKind::UncapAcked { class: Priority::High },
            EventKind::BrakeIssued,
            EventKind::BrakeReleaseIssued,
            EventKind::BrakeEngaged,
            EventKind::BrakeReleased,
            EventKind::FaultStart { fault: 0, label: "feed-loss" },
            EventKind::FaultEnd { fault: 0, label: "feed-loss" },
            EventKind::ViolationStart { over_w: 1.0 },
            EventKind::ViolationContained,
            EventKind::Telemetry { reported: 0.5 },
            EventKind::TrainPhase { job: 0, phase: 0, level: 1.0 },
            EventKind::TrainIter { job: 0, wall_s: 1.0 },
            EventKind::RetuneEval { peak: 0.5 },
            EventKind::RetuneApply { added: 0.1, t1: 0.8, t2: 0.89 },
            EventKind::RetuneVeto { added: 0.1 },
        ];
        let labels: std::collections::BTreeSet<&str> = kinds.iter().map(|k| k.label()).collect();
        assert_eq!(labels.len(), kinds.len());
    }

    #[test]
    fn describe_is_nonempty_for_every_kind() {
        let e = Event { t_s: 0.0, kind: EventKind::BrakeEngaged };
        assert_eq!(e.describe(), "brake-engaged");
        let e = Event { t_s: 0.0, kind: EventKind::ViolationStart { over_w: 321.7 } };
        assert!(e.describe().contains("322W"));
    }
}
