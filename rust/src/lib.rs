//! # POLCA — Power Oversubscription in LLM Cloud Providers
//!
//! Full-system reproduction of the POLCA paper (Patel et al., Microsoft
//! Azure, cs.DC 2023): an end-to-end framework for *safe power
//! oversubscription in LLM inference clusters*.
//!
//! The crate is organized bottom-up (see DESIGN.md for the complete
//! inventory and per-experiment index):
//!
//! * **Substrates** — [`util`] (RNG/stats/JSON/CLI, all in-tree because the
//!   build is offline), [`config`], [`sim`] (discrete-event engine),
//!   [`benchkit`] and [`testing`] (bench + property-test harnesses).
//! * **Domain models** — [`power`] (GPU/server/training power, capping
//!   semantics), [`characterize`] (the paper's §2 model catalog),
//!   [`perfmodel`] (latency & frequency-sensitivity), [`workload`]
//!   (Table-4 mixes, diurnal arrivals, production-trace replication),
//!   [`cluster`] (PDU/UPS/BMC hierarchy with the paper's OOB latencies).
//! * **The contribution** — [`policy`] (POLCA Algorithm 1 + baselines +
//!   tuner), [`metrics`] (SLO accounting), [`simulation`] (row-level
//!   cluster simulator, the paper's §6 evaluation vehicle — a layered
//!   package: core event loop / servers / control / training / faults /
//!   accounting, plus the memoized power-scale calibration).
//! * **Batch execution** — [`exec`]: the parallel scenario executor —
//!   every multi-run surface (fault matrix, policy and mixed sweeps,
//!   fleet cluster fan-out) runs its batch through one scoped-thread
//!   work-stealing pool, bit-identical to the serial reference path.
//! * **Observability** — [`obs`]: the zero-cost-when-off trace layer —
//!   a passive observer threaded through every simulation layer records
//!   control-loop events, decimated time series, and hot-path counters
//!   into first-class traces with JSONL/CSV/Chrome exporters and an
//!   incident-timeline renderer (`polca run --trace`, `polca trace`;
//!   schema in `docs/OBSERVABILITY.md`).
//! * **Fleet layer** — [`fleet`] (heterogeneous SKU registry, site
//!   topology with compositional power traces, parallel multi-cluster
//!   execution, and the site-level capacity planner behind
//!   `polca fleet`).
//! * **Resilience** — [`faults`] (deterministic fault-injection plans
//!   over the whole control loop, the scenario × policy containment
//!   matrix, and the containment SLO that derates the planner; runbook
//!   in `docs/RELIABILITY.md`).
//! * **Serving path** — [`runtime`] (PJRT executables AOT-compiled from
//!   JAX/Pallas), [`coordinator`] (router, batcher, KV-cache slots) — the
//!   real-model end-to-end driver with POLCA in the loop.
//! * **Control-plane daemon** — [`gateway`]: the live HTTP service
//!   around the telemetry→policy→OOB loop — std-only hand-rolled
//!   HTTP/1.1, scenario submission over the TOML codec or a JSON
//!   envelope, wall-clock-paced runs at a configurable time-warp,
//!   Server-Sent-Events streaming of control decisions, Prometheus
//!   metrics, and a built-in loopback load generator
//!   (`polca gateway`, `polca gateway bench`; wire reference in
//!   `docs/GATEWAY.md`).
//! * **Scenario layer** — [`scenario`]: one declarative [`scenario::Scenario`]
//!   spec composing workload, cluster shape, SKU, policy knobs, training
//!   mix, fault plan, and site topology; fluent builder, lossless TOML
//!   round-trip, named presets, and a single `run()` dispatching to the
//!   engines above. Every CLI surface and experiment generator
//!   constructs runs through it.
//! * **Reproduction** — [`experiments`] regenerates every table and figure
//!   in the paper's evaluation by enumerating scenario values.
//!
//! A paper-section → module map with the control-loop dataflow lives in
//! `docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod benchkit;
pub mod characterize;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod experiments;
pub mod faults;
pub mod fleet;
pub mod gateway;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod policy;
pub mod power;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod simulation;
pub mod testing;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
