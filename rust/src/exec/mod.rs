//! Parallel scenario executor: fan any batch of independent runs out
//! across scoped threads, bit-identically to running them serially.
//!
//! This generalizes the pattern [`crate::fleet::parallel`] proved for
//! site runs — derive any per-item seeds *serially* before spawning,
//! give every item a pre-allocated result slot keyed by its index, and
//! let scheduling affect only wall-clock, never results — to any
//! `Vec<SimConfig>` / `Vec<Scenario>`-shaped batch: the fault matrix,
//! policy/threshold sweeps, training-fraction sweeps, and the fleet
//! layer's per-cluster runs all execute through [`run_batch`].
//!
//! # Determinism contract
//!
//! `run_batch(items, cfg, f)` returns exactly
//! `items.iter().enumerate().map(f).collect()` — the serial reference
//! path *is* that expression, and the parallel path is pinned to it by
//! a property test over randomized batches and thread counts
//! (`tests/integration_exec.rs`, full `Debug`-render equality of
//! simulation reports). This only holds when `f` is a pure function of
//! `(index, item)` — true for every simulator entry point, which takes
//! its entire universe (workload realization included) from the config
//! value. Items needing distinct randomness derive per-item seeds up
//! front with [`item_seeds`].
//!
//! # Scheduling
//!
//! Workers pull the next unclaimed index from a shared atomic counter
//! (work stealing), so a batch of uneven runs (a fault matrix mixing
//! NoCap and braked cells, say) load-balances instead of convoying
//! behind the slowest contiguous chunk. Results are written to their
//! slots by index after each worker drains, so the output order is the
//! input order regardless of which thread ran what.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread;
use std::time::Instant;

use crate::obs::Span;
use crate::util::rng::Rng;

/// How to execute one batch.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    /// Run items on scoped threads (false = the serial reference path,
    /// every CLI surface's `--serial` flag).
    pub parallel: bool,
    /// Worker-thread cap; 0 = the machine's available parallelism.
    pub threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { parallel: true, threads: 0 }
    }
}

impl ExecConfig {
    /// The serial reference path.
    pub fn serial() -> ExecConfig {
        ExecConfig { parallel: false, threads: 0 }
    }

    /// Parallel (or not) at the default thread cap — the one-liner CLI
    /// surfaces use to honor a `--serial` flag.
    pub fn with_parallel(parallel: bool) -> ExecConfig {
        ExecConfig { parallel, ..Default::default() }
    }

    /// Worker threads to use for a batch of `n` items.
    fn workers(&self, n: usize) -> usize {
        let cap = if self.threads > 0 {
            self.threads
        } else {
            thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        };
        cap.clamp(1, n.max(1))
    }
}

/// Deterministic per-item seeds, derived serially from a root seed
/// before any thread exists — the same pattern as
/// [`crate::fleet::parallel::cluster_seeds`], offered generically for
/// new batch surfaces: item `i` of a batch gets the same seed whether
/// the batch runs serially, in parallel, or is re-sliced into
/// sub-batches of the same order. (`cluster_seeds` keeps its own
/// domain-separation constant on purpose: historical site runs must
/// stay bit-identical, so the two derivations are distinct forever.)
pub fn item_seeds(root_seed: u64, n: usize) -> Vec<u64> {
    let mut root = Rng::new(root_seed ^ 0xE8EC_5EED_0000_0001);
    (0..n).map(|i| root.fork(i as u64).next_u64()).collect()
}

/// Run `f` over every item, returning results in input order —
/// bit-identical between the serial and parallel paths (see the module
/// docs for the contract `f` must satisfy).
pub fn run_batch<I, O, F>(items: &[I], cfg: &ExecConfig, f: F) -> Vec<O>
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let n = items.len();
    if !cfg.parallel || n <= 1 {
        return items.iter().enumerate().map(|(i, item)| f(i, item)).collect();
    }
    let workers = cfg.workers(n);
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
    thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                s.spawn(move || {
                    let mut local: Vec<(usize, O)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, out) in h.join().expect("executor worker panicked") {
                slots[i] = Some(out);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("every batch slot filled")).collect()
}

/// [`run_batch`] plus a wall-clock [`Span`] per item (the hot-path
/// profile behind `bench_sim`'s traced-overhead numbers and the
/// ROADMAP's raw-speed baseline).
///
/// The returned outputs are exactly `run_batch`'s: spans are recorded
/// on the side, so the executor's bit-identity contract is untouched —
/// but note the spans themselves are wall-clock measurements and NOT
/// deterministic. Spans are returned sorted by start time; `span.name`
/// is `item-<i>` and `span.worker` is the worker lane that ran it (0
/// on the serial path).
pub fn run_batch_profiled<I, O, F>(
    items: &[I],
    cfg: &ExecConfig,
    f: F,
) -> (Vec<O>, Vec<Span>)
where
    I: Sync,
    O: Send,
    F: Fn(usize, &I) -> O + Sync,
{
    let t0 = Instant::now();
    let n = items.len();
    if !cfg.parallel || n <= 1 {
        let mut outs = Vec::with_capacity(n);
        let mut spans = Vec::with_capacity(n);
        for (i, item) in items.iter().enumerate() {
            let start_s = t0.elapsed().as_secs_f64();
            outs.push(f(i, item));
            let dur_s = t0.elapsed().as_secs_f64() - start_s;
            spans.push(Span { name: format!("item-{i}"), start_s, dur_s, worker: 0 });
        }
        return (outs, spans);
    }
    let workers = cfg.workers(n);
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<O>> = (0..n).map(|_| None).collect();
    let mut all_spans: Vec<Span> = Vec::with_capacity(n);
    thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let next = &next;
                let f = &f;
                let t0 = &t0;
                s.spawn(move || {
                    let mut local: Vec<(usize, O, Span)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let start_s = t0.elapsed().as_secs_f64();
                        let out = f(i, &items[i]);
                        let dur_s = t0.elapsed().as_secs_f64() - start_s;
                        local.push((
                            i,
                            out,
                            Span { name: format!("item-{i}"), start_s, dur_s, worker: w },
                        ));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, out, span) in h.join().expect("executor worker panicked") {
                slots[i] = Some(out);
                all_spans.push(span);
            }
        }
    });
    all_spans
        .sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap_or(std::cmp::Ordering::Equal));
    let outs = slots.into_iter().map(|s| s.expect("every batch slot filled")).collect();
    (outs, all_spans)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_batch(n: usize, cfg: &ExecConfig) -> Vec<usize> {
        let items: Vec<usize> = (0..n).collect();
        run_batch(&items, cfg, |i, &x| {
            assert_eq!(i, x, "index must match the item's position");
            x * x
        })
    }

    #[test]
    fn serial_and_parallel_agree_in_order() {
        let want: Vec<usize> = (0..57).map(|x| x * x).collect();
        assert_eq!(square_batch(57, &ExecConfig::serial()), want);
        for threads in [0, 1, 2, 3, 8, 64] {
            let cfg = ExecConfig { parallel: true, threads };
            assert_eq!(square_batch(57, &cfg), want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_batches() {
        assert_eq!(square_batch(0, &ExecConfig::default()), Vec::<usize>::new());
        assert_eq!(square_batch(1, &ExecConfig::default()), vec![0]);
    }

    #[test]
    fn more_threads_than_items_is_fine() {
        let cfg = ExecConfig { parallel: true, threads: 32 };
        assert_eq!(square_batch(3, &cfg), vec![0, 1, 4]);
    }

    #[test]
    fn item_seeds_are_deterministic_distinct_and_prefix_stable() {
        let a = item_seeds(42, 16);
        assert_eq!(a, item_seeds(42, 16));
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "colliding item seeds: {a:?}");
        // A longer derivation shares the common prefix (sub-batching
        // a sweep must not reshuffle the seeds of the items kept).
        assert_eq!(&a[..5], &item_seeds(42, 5)[..]);
        assert_ne!(item_seeds(43, 5), item_seeds(42, 5));
    }

    #[test]
    fn profiled_batch_matches_plain_outputs_with_one_span_per_item() {
        let items: Vec<u64> = (0..37).collect();
        for cfg in [ExecConfig::serial(), ExecConfig { parallel: true, threads: 4 }] {
            let (out, spans) = run_batch_profiled(&items, &cfg, |_, &x| x * 3);
            assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<u64>>());
            assert_eq!(spans.len(), items.len());
            let mut idxs: Vec<usize> = spans
                .iter()
                .map(|s| s.name.strip_prefix("item-").unwrap().parse().unwrap())
                .collect();
            idxs.sort();
            assert_eq!(idxs, (0..items.len()).collect::<Vec<usize>>());
            assert!(spans.iter().all(|s| s.dur_s >= 0.0 && s.start_s >= 0.0));
            assert!(spans.windows(2).all(|w| w[0].start_s <= w[1].start_s), "sorted by start");
        }
    }

    #[test]
    fn every_item_runs_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let calls = AtomicU64::new(0);
        let items: Vec<u64> = (0..200).collect();
        let out = run_batch(&items, &ExecConfig { parallel: true, threads: 7 }, |_, &x| {
            calls.fetch_add(1, Ordering::SeqCst);
            x + 1
        });
        assert_eq!(calls.load(Ordering::SeqCst), 200);
        assert_eq!(out, (1..=200).collect::<Vec<u64>>());
    }
}
