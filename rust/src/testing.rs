//! Property-based testing helper (proptest is unavailable offline).
//!
//! A thin, seeded harness: generate N random cases from a generator
//! closure, run the property, and on failure report the case index, the
//! seed, and a Debug rendering of the failing input so the case can be
//! replayed deterministically. Used by the coordinator/policy invariant
//! tests (DESIGN.md §6).

use crate::util::rng::Rng;

/// Number of cases per property (kept moderate: single-core CI budget).
pub const DEFAULT_CASES: u32 = 256;

/// Run `prop` over `cases` random inputs drawn by `gen`.
///
/// Panics with a replayable report on the first failure. The property
/// returns `Result<(), String>` so failures carry a domain message.
pub fn check<T, G, P>(name: &str, seed: u64, cases: u32, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed\n  case:  {case}/{cases}\n  seed:  {seed}\n  \
                 error: {msg}\n  input: {input:#?}\n  replay: check(\"{name}\", {seed}, ..)"
            );
        }
    }
}

/// Convenience: property with the default case count.
pub fn check_default<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(name, 0xC0FFEE, DEFAULT_CASES, gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 1, 64, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 2, 8, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seen_a = Vec::new();
        check("collect-a", 7, 16, |r| r.next_u64(), |&x| {
            seen_a.push(x);
            Ok(())
        });
        let mut seen_b = Vec::new();
        check("collect-b", 7, 16, |r| r.next_u64(), |&x| {
            seen_b.push(x);
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
