//! Property-based testing helper (proptest is unavailable offline).
//!
//! A thin, seeded harness: generate N random cases from a generator
//! closure, run the property, and on failure report the case index, the
//! seed, and a Debug rendering of the failing input so the case can be
//! replayed deterministically. Used by the coordinator/policy invariant
//! tests (DESIGN.md §6).
//!
//! Also home to the scaffolding the integration suites share instead of
//! carrying private copies: seeded [`SimConfig`]/[`Scenario`]
//! generators ([`random_sim_config`], [`random_scenario`]), the small
//! fixed-row config ([`base_sim_config`]), the Debug-render
//! bit-identity assertion ([`assert_bit_identical`]), and the
//! quick/full test-tier switch ([`full_suite`], `POLCA_TEST_FULL=1`).

use crate::faults::FaultPlan;
use crate::policy::engine::PolicyKind;
use crate::scenario::Scenario;
use crate::simulation::{MixedRowConfig, SimConfig};
use crate::util::rng::Rng;

/// Number of cases per property (kept moderate: single-core CI budget).
pub const DEFAULT_CASES: u32 = 256;

/// Whether the full (slow) test tier was requested. The integration
/// suites gate their exhaustive grids on `POLCA_TEST_FULL=1`; the
/// default run is the quick tier `scripts/ci.sh` times separately.
pub fn full_suite() -> bool {
    matches!(std::env::var("POLCA_TEST_FULL"), Ok(v) if !v.is_empty() && v != "0")
}

/// Assert two values render identically under `{:?}` — the repo's
/// bit-identity contract (Debug prints every counter, percentile
/// buffer, and f64 at round-trip precision).
///
/// Panics with `ctx` and both renders on divergence.
pub fn assert_bit_identical<T: std::fmt::Debug>(a: &T, b: &T, ctx: &str) {
    let (da, db) = (format!("{a:?}"), format!("{b:?}"));
    assert_eq!(da, db, "{ctx}: Debug renders diverged");
}

/// A small fixed row on an explicit calibration: the base config the
/// fault-injection tests build on (deployed == baseline; oversubscribe
/// by raising `deployed_servers` afterwards).
pub fn base_sim_config(servers: usize, weeks: f64, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.weeks = weeks;
    cfg.exp.row.num_servers = servers;
    cfg.deployed_servers = servers;
    cfg.exp.seed = seed;
    cfg.power_scale = 1.35; // small-row calibration (see simulation tests)
    cfg
}

/// A randomized quick config: small rows and short horizons keep each
/// case cheap while still exercising capping, mixes, and faults.
/// `power_scale` is always explicit so no case depends on the
/// calibration cache. Shared by the executor and observability
/// bit-identity properties (one generator, one distribution).
pub fn random_sim_config(rng: &mut Rng) -> SimConfig {
    let mut cfg = SimConfig::default();
    let servers = rng.range_usize(8, 12);
    cfg.exp.row.num_servers = servers;
    cfg.deployed_servers = servers + rng.range_usize(0, servers / 2);
    cfg.weeks = rng.range_f64(0.008, 0.02);
    cfg.exp.seed = rng.next_u64() >> 1;
    cfg.power_scale = 1.35;
    let policies = PolicyKind::all();
    cfg.policy_kind = policies[rng.range_usize(0, policies.len() - 1)];
    if rng.bool(0.3) {
        cfg.mixed = Some(MixedRowConfig {
            training_fraction: rng.range_f64(0.2, 0.8),
            servers_per_job: rng.range_usize(0, 4),
            job_stagger_s: rng.range_f64(0.0, 5.0),
            ..Default::default()
        });
    }
    if rng.bool(0.3) {
        let horizon_s = cfg.weeks * 7.0 * 86_400.0;
        cfg.faults = Some(FaultPlan::random(rng.next_u64(), horizon_s, rng.range_usize(1, 3)));
        cfg.brake_escalation_s = Some(120.0);
    }
    cfg
}

/// A deterministic pseudo-random scenario touching optional fields with
/// varying shapes — row, site, and region dispatches, SKUs, training
/// mixes, fault plans. The generator is seeded, so failures replay.
/// Used by the TOML round-trip property.
pub fn random_scenario(rng: &mut Rng, i: usize) -> Scenario {
    let policies = PolicyKind::all();
    let added = rng.range_f64(0.0, 0.6);
    let mut b = Scenario::builder(&format!("rand-{i}"))
        .description("randomized round-trip scenario")
        .policy(policies[rng.range_usize(0, policies.len() - 1)])
        .servers(rng.range_usize(4, 64))
        .added(added)
        .weeks(rng.range_f64(0.01, 3.0))
        .seed(rng.fork(i as u64).next_u64() >> 1)
        .peak_utilization(rng.range_f64(0.5, 1.0))
        .power_mult(rng.range_f64(0.9, 1.2))
        .thresholds(rng.range_f64(0.6, 0.8), rng.range_f64(0.85, 0.97));
    if rng.bool(0.5) {
        b = b.lp_fraction(rng.range_f64(0.1, 0.9));
    }
    if rng.bool(0.3) {
        b = b.power_scale(rng.range_f64(1.0, 2.0));
    }
    let with_training = rng.bool(0.5);
    if with_training {
        b = b
            .training(rng.range_f64(0.0, 1.0))
            .training_jobs(rng.range_usize(0, 8), rng.range_f64(0.0, 10.0));
    }
    if rng.bool(0.4) {
        b = b.escalate(rng.range_f64(30.0, 300.0));
    }
    // Dispatch shape first: fault plans are only drawn for non-region
    // scenarios (validate() rejects region + faults).
    let region_shape = rng.bool(0.2);
    let site_shape = !region_shape && rng.bool(0.3);
    if !region_shape {
        match rng.below(3) {
            0 => {}
            1 => {
                let names = FaultPlan::scenario_names();
                b = b.faults_scenario(names[rng.range_usize(0, names.len() - 1)]);
            }
            _ => {
                let plan = FaultPlan::random(rng.next_u64(), 86_400.0, rng.range_usize(1, 6));
                b = b.faults(plan);
            }
        }
    }
    if region_shape {
        b = b
            .region(rng.range_usize(2, 12))
            .region_clusters(rng.range_usize(1, 4))
            .region_grid(rng.range_f64(0.6, 1.0))
            .region_search(
                rng.range_usize(10, 50) as u32,
                rng.range_usize(5, 10) as u32,
            );
        if rng.bool(0.5) {
            b = b.serial();
        }
    } else if site_shape {
        b = b.site(rng.range_usize(1, 6)).site_search(
            rng.range_usize(10, 50) as u32,
            rng.range_usize(1, 10) as u32,
        );
        if rng.bool(0.5) {
            b = b.serial();
        }
    } else {
        if rng.bool(0.3) {
            // SKUs only on row scenarios (a site cycles the registry).
            let skus = crate::fleet::sku::registry();
            b = b.sku(skus[rng.range_usize(0, skus.len() - 1)].name);
        }
        // Drift and the adaptive controller are row-only knobs; the
        // controller additionally excludes training colocation and must
        // fit its level range inside the racked oversubscription.
        if rng.bool(0.4) {
            b = b.drift(
                rng.range_f64(-0.05, 0.10),
                rng.range_f64(0.0, 0.4),
                rng.range_f64(1.0, 8.0),
            );
        }
        if !with_training && rng.bool(0.4) {
            let max = rng.range_f64(0.0, added);
            let initial = rng.range_f64(0.0, max);
            let min = rng.range_f64(0.0, initial);
            b = b
                .adaptive(rng.range_f64(600.0, 43_200.0))
                .adapt_levels(min, initial, max)
                .adapt_pacing(rng.range_usize(1, 4) as u32, rng.range_usize(1, 5) as u32);
        }
    }
    b.build()
}

/// Run `prop` over `cases` random inputs drawn by `gen`.
///
/// Panics with a replayable report on the first failure. The property
/// returns `Result<(), String>` so failures carry a domain message.
pub fn check<T, G, P>(name: &str, seed: u64, cases: u32, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork(case as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed\n  case:  {case}/{cases}\n  seed:  {seed}\n  \
                 error: {msg}\n  input: {input:#?}\n  replay: check(\"{name}\", {seed}, ..)"
            );
        }
    }
}

/// Convenience: property with the default case count.
pub fn check_default<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(name, 0xC0FFEE, DEFAULT_CASES, gen, prop)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 1, 64, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check("always-fails", 2, 8, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn random_scenarios_are_well_formed_and_cover_every_shape() {
        let mut rng = Rng::new(0xBEEF);
        let (mut rows, mut sites, mut regions, mut adaptive) = (0, 0, 0, 0);
        for i in 0..80 {
            let sc = random_scenario(&mut rng, i);
            match (&sc.site, &sc.region) {
                (Some(_), None) => sites += 1,
                (None, Some(_)) => regions += 1,
                (None, None) => rows += 1,
                (Some(_), Some(_)) => panic!("scenario #{i} has both site and region"),
            }
            if sc.adapt.is_some() {
                adaptive += 1;
            }
            sc.validate().unwrap_or_else(|e| panic!("scenario #{i}: {e:#}"));
        }
        assert!(
            rows > 0 && sites > 0 && regions > 0 && adaptive > 0,
            "{rows}/{sites}/{regions}/{adaptive}"
        );
    }

    #[test]
    fn bit_identity_assert_accepts_equal_and_full_suite_reads_env() {
        assert_bit_identical(&vec![1.0_f64, 2.5], &vec![1.0_f64, 2.5], "same vectors");
        // Whatever the ambient env says, the function must not panic.
        let _ = full_suite();
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seen_a = Vec::new();
        check("collect-a", 7, 16, |r| r.next_u64(), |&x| {
            seen_a.push(x);
            Ok(())
        });
        let mut seen_b = Vec::new();
        check("collect-b", 7, 16, |r| r.next_u64(), |&x| {
            seen_b.push(x);
            Ok(())
        });
        assert_eq!(seen_a, seen_b);
    }
}
