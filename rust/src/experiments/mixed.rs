//! Beyond-paper experiment: the §2.4 training-vs-inference headroom
//! contrast driven end-to-end through the discrete-event simulator.
//!
//! The paper's headline asymmetry — inference rows leave capping
//! headroom (79% mean peak utilization, Table 2) while training rows
//! synchronize row-level swings and idle near TDP (max 2 s swing ≈
//! 37.5% of provisioned) — is reproduced here as a *sweep over the
//! training fraction* of one row: 0% (the paper's inference row),
//! 100% (a training row), and the colocation mixes §7 proposes in
//! between. Headroom must interpolate monotonically between the two
//! regimes for mixing to be a usable planning knob.

use crate::exec::{run_batch, ExecConfig};
use crate::policy::engine::PolicyKind;
use crate::scenario::Scenario;
use crate::simulation::{run, MixedRowConfig, SimConfig};
use crate::util::csv::Csv;
use crate::util::table::{f, pct, Table};

use super::{Depth, FigureOutput};

/// One row of the sweep: the observables at a single training fraction.
#[derive(Debug, Clone)]
pub struct MixPoint {
    /// Fraction of deployed servers running training.
    pub training_fraction: f64,
    /// Peak normalized row power.
    pub power_peak: f64,
    /// Mean normalized row power.
    pub power_mean: f64,
    /// Max 2 s power rise (the §2.4 swing observable).
    pub spike_2s: f64,
    /// Oversubscription headroom: 1 − peak.
    pub headroom: f64,
    /// Training iterations completed.
    pub train_iters: u64,
    /// Iteration-time inflation vs nominal.
    pub train_inflation: f64,
    /// Inference requests completed (HP + LP).
    pub completed: u64,
}

/// Row parameters shared by `polca figure mixed-row`, `polca mixed
/// sweep`, and `polca mixed run` — [`SweepConfig::sim_config`] is the
/// single place the oversubscription/mixed wiring happens, so the
/// modes cannot diverge and no CLI knob is silently ignored.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Power-management policy driving the row.
    pub policy: PolicyKind,
    /// Simulated horizon, weeks.
    pub weeks: f64,
    /// Seed (shared across fractions: one workload realization).
    pub seed: u64,
    /// Baseline (budget) server count of the row.
    pub servers: usize,
    /// Added-server fraction (oversubscription).
    pub added: f64,
    /// Template mixed config; `training_fraction` is overwritten per
    /// sweep point, the job structure (size/stagger/profile) is kept.
    pub mixed: MixedRowConfig,
    /// Fan sweep points out across the parallel scenario executor
    /// (false = the serial reference path; bit-identical either way).
    pub parallel: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            policy: PolicyKind::NoCap,
            weeks: 0.3,
            seed: 1,
            servers: 40,
            added: 0.0,
            mixed: MixedRowConfig::default(),
            parallel: true,
        }
    }
}

impl SweepConfig {
    /// The declarative [`Scenario`] for one training fraction — the
    /// sweep is an enumeration of scenario values, so `polca mixed`
    /// and `polca run mixed-row` cannot diverge.
    pub fn scenario(&self, training_fraction: f64) -> Scenario {
        Scenario::builder("mixed-row-sweep")
            .policy(self.policy)
            .weeks(self.weeks)
            .seed(self.seed)
            .servers(self.servers)
            .added(self.added)
            .training(training_fraction)
            .training_jobs(self.mixed.servers_per_job, self.mixed.job_stagger_s)
            .build()
    }

    /// The simulation config for one training fraction — derived from
    /// [`SweepConfig::scenario`], so rounding/oversubscription/
    /// calibration semantics live in exactly one place (the scenario
    /// layer). The template's waveform profile rides along for callers
    /// that customized it.
    pub fn sim_config(&self, training_fraction: f64) -> SimConfig {
        let mut cfg = self.scenario(training_fraction).sim_config();
        if let Some(m) = &mut cfg.mixed {
            m.profile = self.mixed.profile;
        }
        cfg
    }
}

/// Sweep the training fraction of one row. All fractions share the
/// same inference workload realization (training servers are carved
/// off the tail), so the points are directly comparable. The per-point
/// simulations are independent, so the sweep fans out through the
/// parallel scenario executor ([`crate::exec`]) unless
/// [`SweepConfig::parallel`] opts for the serial reference path.
pub fn sweep_training_fractions(fractions: &[f64], sc: &SweepConfig) -> Vec<MixPoint> {
    let configs: Vec<(f64, SimConfig)> =
        fractions.iter().map(|&frac| (frac, sc.sim_config(frac))).collect();
    run_batch(&configs, &ExecConfig::with_parallel(sc.parallel), |_, (frac, cfg)| {
        let report = run(cfg);
        MixPoint {
            training_fraction: *frac,
            power_peak: report.power_peak,
            power_mean: report.power_mean,
            spike_2s: report.spike_2s,
            headroom: 1.0 - report.power_peak,
            train_iters: report.train.iters,
            train_inflation: report.train.inflation(),
            completed: report.hp.completed + report.lp.completed,
        }
    })
}

/// The §2.4 bound the pure-training endpoint is checked against: the
/// paper's "max 2 s swing is 37.5% of provisioned power" — a training
/// row's only short-horizon slack, hence the ceiling on any headroom
/// an oversubscription planner may claim from it.
pub const TRAINING_HEADROOM_BOUND: f64 = 0.375;

/// The §2.4-contrast verdict over a sweep — one definition shared by
/// `polca figure mixed-row` and `polca mixed sweep`, so both surfaces
/// always agree on the bounds and the monotonicity tolerance.
#[derive(Debug, Clone, Copy)]
pub struct ContrastVerdict {
    /// Headroom of the highest-training-fraction point.
    pub train_headroom: f64,
    /// Row-level 2 s swing of that point — the §2.4 observable itself
    /// (the paper reports ≈37.5% of provisioned for training rows).
    pub train_swing_2s: f64,
    /// Peak of the pure-inference point.
    pub inference_peak: f64,
    /// Headroom of the pure-inference point.
    pub inference_headroom: f64,
    /// Whether the training endpoint's headroom obeys
    /// [`TRAINING_HEADROOM_BOUND`] (the ISSUE acceptance criterion —
    /// a loose bound, since training rows idle near TDP).
    pub bound_ok: bool,
    /// Whether the training endpoint's 2 s swing is of the paper's
    /// order (coordinated troughs actually visible at row level) —
    /// the check that would catch a de-synchronized-swing regression
    /// the headroom bound cannot. Only meaningful on uncapped sweeps;
    /// caps legitimately shave the swing.
    pub swing_ok: bool,
    /// Whether headroom decreases monotonically across the sweep
    /// (within a 1-point sampling tolerance).
    pub monotone: bool,
}

/// Evaluate the contrast checks over a fraction-ascending sweep.
pub fn contrast_verdict(points: &[MixPoint]) -> ContrastVerdict {
    let first = points.first().expect("non-empty sweep");
    let last = points.last().expect("non-empty sweep");
    ContrastVerdict {
        train_headroom: last.headroom,
        train_swing_2s: last.spike_2s,
        inference_peak: first.power_peak,
        inference_headroom: first.headroom,
        bound_ok: last.headroom <= TRAINING_HEADROOM_BOUND,
        // Same order as the paper's 37.5%: well above inference's ~9%
        // 2 s spikes, below the full idle-to-peak range.
        swing_ok: (0.25..=0.55).contains(&last.spike_2s),
        monotone: points.windows(2).all(|w| w[1].headroom <= w[0].headroom + 0.01),
    }
}

/// Rendered sweep table — shared by the experiment and the CLI.
pub fn sweep_table(points: &[MixPoint]) -> Table {
    let mut t = Table::new(
        "Training-fraction sweep",
        &["training", "peak", "mean", "2s swing", "headroom", "iters", "inflation", "done reqs"],
    );
    for p in points {
        t.row(vec![
            pct(p.training_fraction, 0),
            pct(p.power_peak, 1),
            pct(p.power_mean, 1),
            pct(p.spike_2s, 1),
            pct(p.headroom, 1),
            p.train_iters.to_string(),
            pct(p.train_inflation, 1),
            p.completed.to_string(),
        ]);
    }
    t
}

/// `mixed-row`: training-fraction sweep of one 40-server row (NoCap, so
/// the raw power envelope is observed, as in Table 2's measurement).
pub fn mixed_row(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "mixed-row",
        "Mixed-workload row: training-vs-inference headroom contrast (§2.4)",
    );
    let fractions = [0.0, 0.25, 0.50, 0.75, 1.0];
    let sc = SweepConfig { weeks: depth.weeks(1.0), seed, ..Default::default() };
    let points = sweep_training_fractions(&fractions, &sc);

    let mut csv = Csv::new(&[
        "training_fraction", "power_peak", "power_mean", "spike_2s", "headroom",
        "train_iters", "train_inflation", "completed",
    ]);
    for p in &points {
        csv.row_strs(&[
            f(p.training_fraction, 2),
            f(p.power_peak, 4),
            f(p.power_mean, 4),
            f(p.spike_2s, 4),
            f(p.headroom, 4),
            p.train_iters.to_string(),
            f(p.train_inflation, 4),
            p.completed.to_string(),
        ]);
    }
    out.tables.push(sweep_table(&points));
    out.csvs.push(("mixed_row_sweep.csv".into(), csv));

    let v = contrast_verdict(&points);
    out.notes.push(format!(
        "pure-training headroom {:.1}% (bound: <= {:.1}% of provisioned, §2.4): {}; \
         pure-inference peak {:.1}% (paper: 79% mean peak); \
         headroom interpolates monotonically: {}",
        v.train_headroom * 100.0,
        TRAINING_HEADROOM_BOUND * 100.0,
        if v.bound_ok { "ok" } else { "VIOLATED" },
        v.inference_peak * 100.0,
        if v.monotone { "yes" } else { "NO" }
    ));
    out.notes.push(format!(
        "pure-training 2 s row swing {:.1}% — the §2.4 observable (paper: ≈37.5%; one \
         synchronized job, troughs compose at row level): {}",
        v.train_swing_2s * 100.0,
        if v.swing_ok { "in band" } else { "OUT OF BAND" }
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_reproduces_the_headroom_contrast() {
        // The acceptance shape of the PR: pure training bounded by the
        // §2.4 swing bound, pure inference at the PR-1 headroom, and a
        // monotone interpolation between them.
        let sc = SweepConfig { weeks: 0.05, seed: 3, ..Default::default() };
        let points = sweep_training_fractions(&[0.0, 0.5, 1.0], &sc);
        let v = contrast_verdict(&points);
        assert!(v.bound_ok, "training headroom {} above the §2.4 bound", v.train_headroom);
        assert!(
            v.swing_ok,
            "pure-training 2 s swing {} must be of the paper's ~37.5% order \
             (a de-synchronized waveform would flatten it)",
            v.train_swing_2s
        );
        assert!(
            v.inference_headroom > v.train_headroom + 0.05,
            "contrast must be visible: {v:?}"
        );
        assert!(v.monotone, "{points:?}");
        assert_eq!(points[0].train_iters, 0);
        assert!(points[2].train_iters > 0);
        assert_eq!(points[2].completed, 0);
    }
}
