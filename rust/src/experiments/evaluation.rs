//! §6 evaluation experiments: Table 2 and Figs 13–18 — the cluster-level
//! results that carry the paper's headline claim (+30% servers, zero
//! powerbrakes, SLOs held).

use crate::characterize::catalog::find;
use crate::exec::{run_batch, ExecConfig};
use crate::policy::engine::PolicyKind;
use crate::policy::tuner::tune_thresholds;
use crate::power::gpu::CapMode;
use crate::power::training::TrainingPowerModel;
use crate::scenario::{Scenario, ScenarioBuilder};
use crate::simulation::{run, run_with_impact, SimConfig};
use crate::util::csv::Csv;
use crate::util::rng::Rng;
use crate::util::stats::max_rise_within;
use crate::util::table::{f, pct, Table};
use crate::workload::tracegen::target_power_profile;

use super::{Depth, FigureOutput};

/// The shared row scenario every §6 generator enumerates from: the
/// paper's 40-server row at the depth-scaled horizon. Generators chain
/// further builder calls (policy, oversubscription, tuning knobs) —
/// hand-assembled `SimConfig`s are gone from this module.
fn row_scenario(depth: Depth, seed: u64) -> ScenarioBuilder {
    Scenario::builder("eval-row").weeks(depth.weeks(1.0)).seed(seed)
}

fn base_cfg(depth: Depth, seed: u64) -> SimConfig {
    row_scenario(depth, seed).build().sim_config()
}

/// Table 2: LLM cluster power usage in production (training vs inference).
pub fn table2(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("table2", "LLM cluster power usage (training vs inference rows)");

    // Inference row: base simulation, no capping.
    let cfg = row_scenario(depth, seed).policy(PolicyKind::NoCap).build().sim_config();
    let report = run(&cfg);

    // Training row: 40 servers running one synchronized job (NeoX-like).
    // The swing is coordinated across all servers (§2.4) with per-server
    // jitter of a few hundred ms at most.
    let m = find("GPT-NeoX-20B").unwrap();
    let tm = TrainingPowerModel { profile: m.training.unwrap(), calib: m.power };
    let srv = crate::power::server::ServerPowerModel { calib: m.power, ..Default::default() };
    let mut rng = Rng::new(seed ^ 0x22);
    let jitters: Vec<f64> = (0..40).map(|_| rng.range_f64(0.0, 0.15)).collect();
    let dt = 0.5;
    let n = (depth.weeks(1.0) * 7.0 * 86_400.0 / dt).min(400_000.0) as usize;
    let budget = 40.0 * srv.provisioned_w();
    let mut series = Vec::with_capacity(n);
    for i in 0..n {
        let t = i as f64 * dt;
        let total: f64 = jitters
            .iter()
            .map(|&j| {
                // Training waveform drives the GPUs; the host tracks GPU
                // activity (same non-GPU model as the server power model).
                srv.training_power_w(tm.power_frac_at(t + j, CapMode::None))
            })
            .sum();
        series.push(total / budget);
    }
    let train_peak = series.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let train_spike_2s = max_rise_within(&series, (2.0 / dt) as usize);

    let mut t = Table::new("Table 2", &["metric", "training", "inference"]);
    t.row(vec!["Peak power utilization".into(), pct(train_peak, 0), pct(report.power_peak, 0)]);
    t.row(vec![
        "Power usage pattern".into(),
        "coordinated swings every few seconds".into(),
        "diurnal with short-term variations".into(),
    ]);
    t.row(vec!["Max power spike in 2s".into(), pct(train_spike_2s, 1), pct(report.spike_2s, 1)]);
    t.row(vec!["Max power spike in 5s".into(), "-".into(), pct(report.spike_5s, 1)]);
    t.row(vec!["Max power spike in 40s".into(), "-".into(), pct(report.spike_40s, 1)]);
    out.tables.push(t);
    out.notes.push(format!(
        "paper: training 97% peak / 37.5% 2s-swing; inference 79% peak / 9% 2s / 11.8% 40s. mean inference util here: {:.0}%",
        report.power_mean * 100.0
    ));
    let mut csv = Csv::new(&["metric", "training", "inference"]);
    csv.row_strs(&["peak_util".into(), f(train_peak, 4), f(report.power_peak, 4)]);
    csv.row_strs(&["spike_2s".into(), f(train_spike_2s, 4), f(report.spike_2s, 4)]);
    csv.row_strs(&["spike_5s".into(), "".into(), f(report.spike_5s, 4)]);
    csv.row_strs(&["spike_40s".into(), "".into(), f(report.spike_40s, 4)]);
    out.csvs.push(("table2_cluster_power.csv".into(), csv));
    out
}

/// Fig 13: threshold space search.
pub fn fig13(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig13", "Threshold space search (T1-T2 × added servers)");
    let base = base_cfg(depth, seed);
    let combos = [(0.75, 0.85), (0.80, 0.89), (0.85, 0.95)];
    let added = match depth {
        Depth::Quick => vec![0.0, 0.30],
        Depth::Full => vec![0.0, 0.10, 0.20, 0.25, 0.30, 0.325, 0.35, 0.40],
    };
    let outcome = tune_thresholds(&base, &combos, &added, &base.exp.slo);
    let mut t = Table::new(
        "Fig 13",
        &["T1-T2", "added", "HP P50", "HP P99", "LP P50", "LP P99", "brakes", "SLO"],
    );
    let mut csv = Csv::new(&["t1", "t2", "added", "hp_p50", "hp_p99", "lp_p50", "lp_p99", "brakes", "meets_slo"]);
    for p in &outcome.points {
        t.row(vec![
            format!("{:.0}-{:.0}", p.t1 * 100.0, p.t2 * 100.0),
            pct(p.added_frac, 1),
            pct(p.hp_p50, 2),
            pct(p.hp_p99, 2),
            pct(p.lp_p50, 2),
            pct(p.lp_p99, 2),
            p.brakes.to_string(),
            if p.meets_slo { "ok".into() } else { "VIOLATED".into() },
        ]);
        csv.row_strs(&[
            f(p.t1, 2), f(p.t2, 2), f(p.added_frac, 3),
            f(p.hp_p50, 4), f(p.hp_p99, 4), f(p.lp_p50, 4), f(p.lp_p99, 4),
            p.brakes.to_string(), (p.meets_slo as u8).to_string(),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("fig13_threshold_search.csv".into(), csv));
    if let Some((t1, t2, added)) = outcome.best {
        out.notes.push(format!(
            "best SLO-meeting point: T1={:.0}% T2={:.0}% with +{:.1}% servers (paper selects 80-89 and deploys +30%)",
            t1 * 100.0, t2 * 100.0, added * 100.0
        ));
    }
    out
}

/// Fig 14: per-priority throughput under POLCA at +30%.
pub fn fig14(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig14", "Server throughput under POLCA (+30% servers)");
    let cfg = row_scenario(depth, seed).added(0.30).build().sim_config();
    let (_, impact) = run_with_impact(&cfg);
    let mut t = Table::new("Fig 14", &["priority", "throughput vs uncapped", "decline"]);
    t.row(vec!["High".into(), f(impact.hp_throughput, 4), pct(1.0 - impact.hp_throughput, 2)]);
    t.row(vec!["Low".into(), f(impact.lp_throughput, 4), pct(1.0 - impact.lp_throughput, 2)]);
    out.tables.push(t);
    let mut csv = Csv::new(&["priority", "throughput_ratio"]);
    csv.row_strs(&["high".into(), f(impact.hp_throughput, 5)]);
    csv.row_strs(&["low".into(), f(impact.lp_throughput, 5)]);
    out.csvs.push(("fig14_throughput.csv".into(), csv));
    out.notes.push("paper: HP unaffected, LP declines < 2%".into());
    out
}

/// Fig 15a: capping-frequency sweep for LP at T1.
pub fn fig15a(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig15a", "Impact of the T1 capping frequency for LP workloads");
    let mut t = Table::new("Fig 15a", &["lp_freq_T1_MHz", "LP P50", "LP P99", "meets LP SLO"]);
    let mut csv = Csv::new(&["freq_mhz", "lp_p50", "lp_p99", "ok"]);
    // Independent sweep points: build every config, then fan the paired
    // runs out through the parallel scenario executor.
    let freqs = [1005.0, 1110.0, 1200.0, 1275.0, 1395.0];
    let cfgs: Vec<_> = freqs
        .iter()
        .map(|&mhz| {
            row_scenario(depth, seed)
                .added(0.30)
                .policy_config(|p| {
                    p.lp_freq_t1_mhz = mhz;
                    // the deeper T2 cap keeps its offset below T1's
                    p.lp_freq_t2_mhz = (mhz - 165.0).max(500.0);
                })
                .build()
                .sim_config()
        })
        .collect();
    let impacts = run_batch(&cfgs, &ExecConfig::default(), |_, cfg| run_with_impact(cfg).1);
    for ((&mhz, cfg), impact) in freqs.iter().zip(&cfgs).zip(&impacts) {
        let ok = impact.lp_p50 <= cfg.exp.slo.lp_p50_impact
            && impact.lp_p99 <= cfg.exp.slo.lp_p99_impact;
        t.row(vec![f(mhz, 0), pct(impact.lp_p50, 2), pct(impact.lp_p99, 2), ok.to_string()]);
        csv.row_strs(&[f(mhz, 0), f(impact.lp_p50, 4), f(impact.lp_p99, 4), (ok as u8).to_string()]);
    }
    out.tables.push(t);
    out.csvs.push(("fig15a_freq_sweep.csv".into(), csv));
    out.notes.push("paper: below 1275 MHz the LP SLO is missed; 1275 (A100 base clock) is chosen for T1".into());
    out
}

/// Fig 15b: sensitivity to the low-priority workload fraction.
pub fn fig15b(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig15b", "Impact of the low-priority workload fraction");
    let mut t = Table::new("Fig 15b", &["LP fraction", "HP P99", "LP P99", "brakes"]);
    let mut csv = Csv::new(&["lp_fraction", "hp_p99", "lp_p99", "brakes"]);
    let fractions = [0.10, 0.25, 0.50, 0.75];
    let cfgs: Vec<_> = fractions
        .iter()
        .map(|&lp| row_scenario(depth, seed).added(0.30).lp_fraction(lp).build().sim_config())
        .collect();
    let impacts = run_batch(&cfgs, &ExecConfig::default(), |_, cfg| run_with_impact(cfg).1);
    for (&lp, impact) in fractions.iter().zip(&impacts) {
        t.row(vec![pct(lp, 0), pct(impact.hp_p99, 2), pct(impact.lp_p99, 2), impact.brake_events.to_string()]);
        csv.row_strs(&[f(lp, 2), f(impact.hp_p99, 4), f(impact.lp_p99, 4), impact.brake_events.to_string()]);
    }
    out.tables.push(t);
    out.csvs.push(("fig15b_lp_fraction.csv".into(), csv));
    out.notes.push("fewer LP servers → less reclaimable power → HP gets capped (or brakes fire): HP P99 degrades as LP share shrinks".into());
    out
}

/// Fig 16: row power timeseries, base vs +30% under POLCA.
pub fn fig16(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig16", "Row-level power utilization (base vs +30% POLCA)");
    // series_sample_s is plot instrumentation, not part of the spec —
    // it stays a SimConfig knob on top of the scenario.
    let mut base = row_scenario(depth, seed).policy(PolicyKind::NoCap).build().sim_config();
    base.series_sample_s = 300.0;
    let base_report = run(&base);

    let mut over = row_scenario(depth, seed).added(0.30).build().sim_config();
    over.series_sample_s = 300.0;
    let over_report = run(&over);

    let mut csv = Csv::new(&["t_s", "base_power", "polca30_power"]);
    for (a, b) in base_report.power_series.iter().zip(&over_report.power_series) {
        csv.row_strs(&[f(a.0, 0), f(a.1, 4), f(b.1, 4)]);
    }
    out.csvs.push(("fig16_power_series.csv".into(), csv));

    // MAPE of the base run's daily profile against the production-like
    // target (the §6.1 replication fidelity check). The published stats
    // pin the peak (79%); the diurnal floor is unpublished, so it is a
    // fitted calibration parameter — exactly like the paper fitting its
    // synthetic trace's free parameters to the production series.
    let series: Vec<f64> = base_report.power_series.iter().map(|&(_, p)| p).collect();
    let daily = crate::workload::tracegen::daily_profile_of(&series, 300.0, 24);
    let floor = daily.iter().cloned().fold(f64::INFINITY, f64::min);
    let target = target_power_profile(depth.weeks(1.0), 300.0, floor, 0.79, seed ^ 0x7);
    let mape = target.mape_daily(&series, 300.0, 24);

    let mut t = Table::new("Fig 16 summary", &["series", "peak", "mean", "5min-avg pattern"]);
    t.row(vec!["base (40 srv)".into(), f(base_report.power_peak, 3), f(base_report.power_mean, 3), "diurnal".into()]);
    t.row(vec!["POLCA +30%".into(), f(over_report.power_peak, 3), f(over_report.power_mean, 3), "diurnal, higher offset".into()]);
    out.tables.push(t);
    out.notes.push(format!(
        "daily-profile MAPE vs production-like target: {mape:.1}% (paper achieves <3% vs its production trace)"
    ));
    out.notes.push("spikes grow with +30%: more workloads can trigger together (paper insight 2)".into());
    out
}

/// Fig 17: POLCA vs baselines, default and power-intensive workloads.
pub fn fig17(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig17", "Policy comparison at +30% (default and +5% power)");
    let mut t = Table::new(
        "Fig 17",
        &["policy", "scenario", "HP P99", "LP P99", "LP thrpt", "brakes", "SLO"],
    );
    let mut csv = Csv::new(&["policy", "scenario", "hp_p99", "lp_p99", "lp_throughput", "brakes", "meets_slo"]);
    // The 4-policy × 2-scenario grid is the slowest §6 sweep (long
    // horizons, paired baselines) — exactly what the executor is for.
    let mut cells = Vec::new();
    for kind in PolicyKind::all() {
        for (scenario, mult) in [("default", 1.0), ("power+5%", 1.05)] {
            let cfg = row_scenario(depth, seed)
                .weeks(depth.weeks(5.0).min(2.0)) // eval weeks (capped for runtime)
                .policy(kind)
                .added(0.30)
                .power_mult(mult)
                .build()
                .sim_config();
            cells.push((kind, scenario, cfg));
        }
    }
    let impacts = run_batch(&cells, &ExecConfig::default(), |_, (_, _, cfg)| {
        run_with_impact(cfg).1
    });
    for ((kind, scenario, cfg), impact) in cells.iter().zip(&impacts) {
        let ok = impact.meets_slo(&cfg.exp.slo);
        t.row(vec![
            kind.name().into(),
            (*scenario).into(),
            pct(impact.hp_p99, 2),
            pct(impact.lp_p99, 2),
            f(impact.lp_throughput, 3),
            impact.brake_events.to_string(),
            if ok { "ok".into() } else { "VIOLATED".into() },
        ]);
        csv.row_strs(&[
            kind.name().into(),
            (*scenario).into(),
            f(impact.hp_p99, 4),
            f(impact.lp_p99, 4),
            f(impact.lp_throughput, 4),
            impact.brake_events.to_string(),
            (ok as u8).to_string(),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("fig17_policy_comparison.csv".into(), csv));
    out.notes.push("POLCA holds SLOs in both scenarios; No-cap relies on brakes; 1-Thresh variants cap abruptly".into());
    out
}

/// Fig 18: powerbrake events per policy.
pub fn fig18(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig18", "Powerbrake events per policy (+30%)");
    let mut t = Table::new("Fig 18", &["policy", "default", "power+5%"]);
    let mut csv = Csv::new(&["policy", "default_brakes", "power5_brakes"]);
    let mut cfgs = Vec::new();
    for kind in PolicyKind::all() {
        for mult in [1.0, 1.05] {
            cfgs.push(
                row_scenario(depth, seed)
                    .weeks(depth.weeks(5.0).min(2.0))
                    .policy(kind)
                    .added(0.30)
                    .power_mult(mult)
                    .build()
                    .sim_config(),
            );
        }
    }
    let counts = run_batch(&cfgs, &ExecConfig::default(), |_, cfg| run(cfg).brake_events);
    for (pi, kind) in PolicyKind::all().into_iter().enumerate() {
        let (a, b) = (counts[pi * 2], counts[pi * 2 + 1]);
        t.row(vec![kind.name().into(), a.to_string(), b.to_string()]);
        csv.row_strs(&[kind.name().into(), a.to_string(), b.to_string()]);
    }
    out.tables.push(t);
    out.csvs.push(("fig18_brake_events.csv".into(), csv));
    out.notes.push("POLCA targets zero brakes (the Table 5 SLO); No-cap accumulates them, increasingly so for power-hungry workloads".into());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_quick_matches_paper_shape() {
        let out = table2(Depth::Quick, 3);
        let csv = out.csvs[0].1.to_string();
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        let train_peak: f64 = row[1].parse().unwrap();
        let infer_peak: f64 = row[2].parse().unwrap();
        // training peaks higher than inference (97% vs 79%)
        assert!(train_peak > infer_peak, "{train_peak} vs {infer_peak}");
        let spikes: Vec<&str> = csv.lines().nth(2).unwrap().split(',').collect();
        let train_spike: f64 = spikes[1].parse().unwrap();
        let infer_spike: f64 = spikes[2].parse().unwrap();
        // training swings are much larger than inference's (37.5% vs 9%)
        assert!(train_spike > 2.0 * infer_spike, "{train_spike} vs {infer_spike}");
    }

    #[test]
    fn fig14_quick_holds_throughput() {
        let out = fig14(Depth::Quick, 5);
        let csv = out.csvs[0].1.to_string();
        let hp: f64 = csv.lines().nth(1).unwrap().split(',').nth(1).unwrap().parse().unwrap();
        let lp: f64 = csv.lines().nth(2).unwrap().split(',').nth(1).unwrap().parse().unwrap();
        assert!(hp > 0.98, "HP throughput {hp}");
        assert!(lp > 0.95, "LP throughput {lp}");
    }

    #[test]
    fn fig18_polca_brakes_least() {
        let out = fig18(Depth::Quick, 7);
        let csv = out.csvs[0].1.to_string();
        let mut polca = u64::MAX;
        let mut nocap = 0;
        for line in csv.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let total: u64 = cells[1].parse::<u64>().unwrap() + cells[2].parse::<u64>().unwrap();
            if cells[0] == "POLCA" {
                polca = total;
            }
            if cells[0] == "No-cap" {
                nocap = total;
            }
        }
        assert!(polca <= nocap, "POLCA {polca} vs No-cap {nocap}");
    }
}
