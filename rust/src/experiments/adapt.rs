//! Beyond-paper experiment: static vs adaptive oversubscription under
//! demand drift — the provisioning→runtime loop closed online.
//!
//! The paper tunes (T1, T2) and the added-server level *offline* from
//! week-one data (§6.2) and argues robustness to workload change
//! (§5.1) from margin left in that static choice. This experiment asks
//! the follow-on question: when demand keeps growing week over week
//! (with a seasonal swing on top), how does a row frozen at its
//! week-one level compare to the same row driven by the
//! [`crate::policy::adapt`] outer loop, which re-walks the tuner grid
//! every window and claims headroom only while the feedback stays
//! calm?
//!
//! The comparison the `adaptive-drift` id prints: one static arm per
//! provisioning level (the row deployed at that level, no controller)
//! against one adaptive arm racked at the search ceiling but *started*
//! at the lowest static level. Adaptive dominance = no more violation
//! minutes than the matched static arm while claiming at least its
//! mean added level — the acceptance bar `tests/integration_adapt.rs`
//! pins.

use crate::exec::{run_batch, ExecConfig};
use crate::policy::engine::PolicyKind;
use crate::scenario::Scenario;
use crate::simulation::run_with_impact;
use crate::util::csv::Csv;
use crate::util::table::{f, pct, Table};

use super::{Depth, FigureOutput};

/// The study's fixed shape — one place for both the experiment and the
/// long-horizon regression tests, so the arms cannot drift apart.
#[derive(Debug, Clone)]
pub struct DriftStudy {
    /// Simulated horizon, weeks.
    pub weeks: f64,
    /// Root seed (shared across arms).
    pub seed: u64,
    /// Baseline (budget) server count.
    pub servers: usize,
    /// The adaptive arm's racked ceiling (added fraction).
    pub racked: f64,
    /// Static provisioning levels to compare against (ascending; the
    /// first is also the adaptive arm's starting level).
    pub static_levels: Vec<f64>,
    /// Retune window, seconds.
    pub window_s: f64,
    /// Demand growth per week (fraction).
    pub growth_per_week: f64,
    /// Seasonal modulation amplitude (fraction).
    pub season_amp: f64,
    /// Explicit row-power calibration (`None` = the shared row fit).
    pub power_scale: Option<f64>,
    /// Fan arms out across the parallel scenario executor.
    pub parallel: bool,
}

impl Default for DriftStudy {
    fn default() -> Self {
        DriftStudy {
            weeks: 2.0,
            seed: 1,
            servers: 16,
            racked: 0.40,
            static_levels: vec![0.10, 0.20, 0.30],
            window_s: 21_600.0,
            growth_per_week: 0.025,
            season_amp: 0.15,
            power_scale: None,
            parallel: true,
        }
    }
}

impl DriftStudy {
    fn base(&self, name: &str) -> crate::scenario::ScenarioBuilder {
        let mut b = Scenario::builder(name)
            .policy(PolicyKind::Polca)
            .servers(self.servers)
            .weeks(self.weeks)
            .seed(self.seed)
            .drift(self.growth_per_week, self.season_amp, 4.0);
        if let Some(scale) = self.power_scale {
            b = b.power_scale(scale);
        }
        b
    }

    /// A row frozen at its week-one provisioning level: deployed at
    /// `level`, no controller (the §6.2 static answer).
    pub fn static_scenario(&self, level: f64) -> Scenario {
        self.base("drift-static").added(level).build()
    }

    /// The same row racked to the ceiling and driven by the adaptive
    /// controller, started at the lowest static level. `min_added` is
    /// pinned to the start level so the adaptive arm never provisions
    /// *below* its static counterpart — which is what makes the
    /// mean-added dominance check meaningful rather than vacuous.
    pub fn adaptive_scenario(&self) -> Scenario {
        let start = self.static_levels.first().copied().unwrap_or(0.0);
        self.base("drift-adaptive")
            .added(self.racked)
            .adaptive(self.window_s)
            .adapt_levels(start, start, self.racked)
            .adapt_pacing(2, 3)
            .build()
    }
}

/// One arm's observables.
#[derive(Debug, Clone)]
pub struct DriftPoint {
    /// Arm label ("static +10%" / "adaptive").
    pub label: String,
    /// Time-weighted mean added-server level over the horizon.
    pub mean_added: f64,
    /// Added level at the horizon.
    pub final_added: f64,
    /// Ground-truth budget-violation seconds.
    pub violation_s: f64,
    /// Powerbrake engagements.
    pub brake_events: u64,
    /// Peak normalized row power.
    pub power_peak: f64,
    /// HP p99 latency impact vs the unthrottled baseline.
    pub hp_p99_impact: f64,
    /// Whether the Table-5 SLOs held.
    pub slo_ok: bool,
    /// Controller activity: (evals, applies, vetoes); zeros for static.
    pub retunes: (u64, u64, u64),
}

/// Run every arm (static levels plus the adaptive row) and collect the
/// observables. Arms are independent simulations, so the batch fans
/// out through [`crate::exec`].
pub fn run_drift_study(study: &DriftStudy) -> Vec<DriftPoint> {
    let mut arms: Vec<(String, Scenario)> = study
        .static_levels
        .iter()
        .map(|&l| (format!("static +{:.0}%", l * 100.0), study.static_scenario(l)))
        .collect();
    arms.push(("adaptive".to_string(), study.adaptive_scenario()));
    run_batch(&arms, &ExecConfig::with_parallel(study.parallel), |_, (label, sc)| {
        let cfg = sc.sim_config();
        let (report, impact) = run_with_impact(&cfg);
        let slo_ok = impact.slo_violations(&sc.exp.slo).is_empty();
        let (mean_added, final_added, retunes) = match &report.adapt {
            Some(a) => (a.mean_added, a.final_added, (a.evals, a.applies, a.vetoes)),
            None => (sc.added_frac, sc.added_frac, (0, 0, 0)),
        };
        DriftPoint {
            label: label.clone(),
            mean_added,
            final_added,
            violation_s: report.resilience.violation_s,
            brake_events: report.brake_events,
            power_peak: report.power_peak,
            hp_p99_impact: impact.hp_p99,
            slo_ok,
            retunes,
        }
    })
}

/// The dominance verdict: the adaptive arm against the static arm at
/// its own starting level (the matched comparison).
#[derive(Debug, Clone, Copy)]
pub struct DriftVerdict {
    /// Matched static arm's violation seconds.
    pub static_violation_s: f64,
    /// Adaptive arm's violation seconds.
    pub adaptive_violation_s: f64,
    /// Matched static arm's mean added level.
    pub static_mean_added: f64,
    /// Adaptive arm's mean added level.
    pub adaptive_mean_added: f64,
    /// Violation minutes no worse AND mean added level no lower.
    pub dominates: bool,
    /// Both arms kept the Table-5 SLOs.
    pub slo_ok_both: bool,
}

/// Evaluate the verdict over [`run_drift_study`] output (the static
/// arms in study order, the adaptive arm last).
pub fn drift_verdict(points: &[DriftPoint]) -> DriftVerdict {
    let adaptive = points.last().expect("non-empty study");
    let matched = points.first().expect("non-empty study");
    DriftVerdict {
        static_violation_s: matched.violation_s,
        adaptive_violation_s: adaptive.violation_s,
        static_mean_added: matched.mean_added,
        adaptive_mean_added: adaptive.mean_added,
        dominates: adaptive.violation_s <= matched.violation_s + 1e-9
            && adaptive.mean_added >= matched.mean_added - 1e-9,
        slo_ok_both: adaptive.slo_ok && matched.slo_ok,
    }
}

/// `adaptive-drift`: static-vs-adaptive headroom under demand growth.
pub fn adaptive_drift(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "adaptive-drift",
        "Static vs adaptive oversubscription under demand drift (§5.1/§6.2 online)",
    );
    let study = DriftStudy { weeks: depth.weeks(2.0), seed, ..Default::default() };
    let points = run_drift_study(&study);

    let mut t = Table::new(
        "Drift study",
        &["arm", "mean added", "final added", "violation s", "brakes", "peak", "hp p99", "slo"],
    );
    let mut csv = Csv::new(&[
        "arm", "mean_added", "final_added", "violation_s", "brakes", "power_peak",
        "hp_p99_impact", "slo_ok", "retune_evals", "retune_applies", "retune_vetoes",
    ]);
    for p in &points {
        t.row(vec![
            p.label.clone(),
            pct(p.mean_added, 1),
            pct(p.final_added, 1),
            f(p.violation_s, 1),
            p.brake_events.to_string(),
            pct(p.power_peak, 1),
            pct(p.hp_p99_impact, 2),
            if p.slo_ok { "ok".into() } else { "VIOLATED".into() },
        ]);
        csv.row_strs(&[
            p.label.clone(),
            f(p.mean_added, 4),
            f(p.final_added, 4),
            f(p.violation_s, 2),
            p.brake_events.to_string(),
            f(p.power_peak, 4),
            f(p.hp_p99_impact, 4),
            (p.slo_ok as u8).to_string(),
            p.retunes.0.to_string(),
            p.retunes.1.to_string(),
            p.retunes.2.to_string(),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("adaptive_drift.csv".into(), csv));

    let v = drift_verdict(&points);
    out.notes.push(format!(
        "adaptive vs matched static (+{:.0}%): violation {:.1}s vs {:.1}s, mean added \
         {:.1}% vs {:.1}% — adaptive {} the static arm (SLOs held on both: {})",
        v.static_mean_added * 100.0,
        v.adaptive_violation_s,
        v.static_violation_s,
        v.adaptive_mean_added * 100.0,
        v.static_mean_added * 100.0,
        if v.dominates { "dominates" } else { "DOES NOT dominate" },
        if v.slo_ok_both { "yes" } else { "NO" }
    ));
    let a = points.last().unwrap();
    out.notes.push(format!(
        "controller activity: {} evals, {} applies, {} vetoes over {:.1} weeks",
        a.retunes.0, a.retunes.1, a.retunes.2, study.weeks
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_arms_have_the_intended_shapes() {
        let study = DriftStudy::default();
        let s = study.static_scenario(0.10);
        assert!(s.adapt.is_none() && s.drift.is_some());
        assert_eq!(s.added_frac, 0.10);
        let a = study.adaptive_scenario();
        assert!(a.validate().is_ok());
        let cfg = a.adapt.unwrap();
        // Starting level pinned as the floor: the adaptive arm never
        // provisions below the matched static arm.
        assert_eq!((cfg.min_added, cfg.initial_added), (0.10, 0.10));
        assert_eq!(cfg.max_added, study.racked);
    }

    #[test]
    fn quick_study_produces_a_dominance_verdict() {
        // A tiny horizon with a fast window: enough for the controller
        // to evaluate several windows while staying CI-cheap.
        let study = DriftStudy {
            weeks: 0.05,
            seed: 5,
            servers: 12,
            static_levels: vec![0.10],
            window_s: 1800.0,
            power_scale: Some(1.35),
            ..Default::default()
        };
        let points = run_drift_study(&study);
        assert_eq!(points.len(), 2);
        let a = points.last().unwrap();
        assert!(a.retunes.0 > 0, "controller never evaluated: {a:?}");
        // The floor construction makes mean-added dominance structural.
        let v = drift_verdict(&points);
        assert!(
            v.adaptive_mean_added >= v.static_mean_added - 1e-9,
            "{points:#?}"
        );
    }
}
