//! Beyond-paper experiment: the fault matrix (see [`crate::faults`]).
//! The paper's evaluation scores policies on a well-behaved control
//! plane; this grid scores *containment* when the control plane itself
//! misbehaves — the §6/§7 robustness claim made falsifiable.

use crate::faults::{run_matrix, MatrixConfig};

use super::{Depth, FigureOutput};

/// `fault-matrix`: scenario × policy containment grid on one
/// +30%-oversubscribed 16-server row.
pub fn fault_matrix(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "fault-matrix",
        "Fault matrix: containment per scenario × policy (§6/§7 robustness)",
    );
    let mut mc = MatrixConfig::default();
    mc.seed = seed;
    mc.weeks = depth.weeks(0.5);
    let grid = run_matrix(&mc).expect("built-in scenarios must resolve");

    out.tables.push(grid.table());
    out.csvs.push(("fault_matrix.csv".into(), grid.csv()));

    out.notes.push(format!(
        "no-fault column == clean run (empty plan is inert): {}",
        if grid.clean_match { "ok" } else { "VIOLATED" }
    ));
    out.notes.push(format!(
        "every injected-fault scenario contained under at least one policy: {}",
        if grid.scenarios_containable() { "ok" } else { "VIOLATED" }
    ));
    let uncontained: Vec<String> = grid
        .cells
        .iter()
        .filter(|c| !c.contained)
        .map(|c| format!("{}×{}", c.scenario, c.policy.name()))
        .collect();
    if !uncontained.is_empty() {
        out.notes.push(format!(
            "uncontained cells (the matrix falsifies these policy/fault pairs): {}",
            uncontained.join(", ")
        ));
    }
    out.notes.push(format!(
        "{} servers +{:.0}%, {:.2}-week horizon, escalation {:?}; \
         violation accounting is ground truth (a biased meter cannot hide it)",
        mc.servers,
        mc.added * 100.0,
        mc.weeks,
        mc.escalation_s
    ));
    out
}
