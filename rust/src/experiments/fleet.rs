//! Beyond-paper experiment: site-level headroom table (see
//! [`crate::fleet`]). POLCA's Fig 13/17 answer "how many servers fit in
//! one row"; this table answers the infrastructure-planning version —
//! how many fit under one substation when heterogeneous clusters with
//! staggered diurnal peaks share the budget.
//!
//! The generator enumerates one [`Scenario`] per policy over the same
//! site section, so the experiment, `polca fleet plan`, and
//! `polca run site-headroom` all execute the identical spec.

use crate::fleet::planner::PolicyPlan;
use crate::policy::engine::PolicyKind;
use crate::scenario::{Outcome, Scenario};
use crate::util::csv::Csv;
use crate::util::table::{f, pct, Table};

use super::{Depth, FigureOutput};

/// The site-headroom scenario for one policy at the given depth.
fn site_scenario(policy: PolicyKind, depth: Depth, seed: u64) -> Scenario {
    let step = match depth {
        Depth::Quick => 5,
        Depth::Full => 2,
    };
    Scenario::builder("site-headroom")
        .policy(policy)
        .weeks(depth.weeks(1.0))
        .seed(seed)
        .site(4)
        .site_search(50, step)
        .build()
}

/// `site-headroom`: per-policy deployable servers for a demo 4-cluster
/// heterogeneous site.
pub fn site_headroom(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "site-headroom",
        "Site-level deployable servers under a shared substation budget",
    );
    let plans: Vec<PolicyPlan> = PolicyKind::all()
        .into_iter()
        .map(|policy| {
            let sc = site_scenario(policy, depth, seed);
            match sc.run().expect("site scenario must run").outcome {
                Outcome::Site(site) => site.plan,
                Outcome::Row(_) => unreachable!("site scenario dispatches to the planner"),
            }
        })
        .collect();
    let site = site_scenario(PolicyKind::Polca, depth, seed)
        .site_spec()
        .expect("site scenario has a topology");

    let mut t = Table::new(
        "Site headroom",
        &["policy", "deployable", "added", "site peak", "brakes", "caps/day", "HP p99", "LP p99"],
    );
    let mut csv = Csv::new(&[
        "policy", "deployable", "added_frac", "site_peak_norm", "brakes", "caps_per_day",
        "worst_hp_p99", "worst_lp_p99", "feasible",
    ]);
    for p in &plans {
        t.row(vec![
            p.policy.name().to_string(),
            if p.feasible { p.deployable_servers.to_string() } else { "—".into() },
            pct(p.added_pct as f64 / 100.0, 0),
            pct(p.site_peak_w / p.substation_budget_w, 1),
            p.brake_events.to_string(),
            f(p.cap_events_per_day, 1),
            pct(p.worst_hp_p99, 2),
            pct(p.worst_lp_p99, 2),
        ]);
        csv.row_strs(&[
            p.policy.name().to_string(),
            p.deployable_servers.to_string(),
            f(p.added_pct as f64 / 100.0, 2),
            f(p.site_peak_w / p.substation_budget_w, 4),
            p.brake_events.to_string(),
            f(p.cap_events_per_day, 2),
            f(p.worst_hp_p99, 4),
            f(p.worst_lp_p99, 4),
            (p.feasible as u8).to_string(),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("site_headroom.csv".into(), csv));
    out.notes.push(format!(
        "{} clusters ({} baseline servers, {:.0} kW substation); deployable = SLOs held, \
         zero brakes, feeds and substation within budget. Row-level paper headline: +30%.",
        site.clusters.len(),
        site.baseline_servers(),
        site.substation_budget_w / 1e3
    ));
    out
}
