//! Beyond-paper experiment: site-level headroom table (see
//! [`crate::fleet`]). POLCA's Fig 13/17 answer "how many servers fit in
//! one row"; this table answers the infrastructure-planning version —
//! how many fit under one substation when heterogeneous clusters with
//! staggered diurnal peaks share the budget.
//!
//! The generator enumerates one [`Scenario`] per policy over the same
//! site section, so the experiment, `polca fleet plan`, and
//! `polca run site-headroom` all execute the identical spec.
//!
//! `region-headroom` scales the question up one more level: many sites
//! under one shared grid interconnect, planned through the
//! compositional trace algebra ([`crate::fleet::region`]) — the note
//! lines report how many discrete-event simulations the archetype
//! cache actually ran versus what a per-candidate simulating planner
//! would have needed.

use crate::fleet::planner::PolicyPlan;
use crate::fleet::region::plan_region;
use crate::policy::engine::PolicyKind;
use crate::scenario::{Outcome, Scenario};
use crate::util::csv::Csv;
use crate::util::table::{f, pct, Table};

use super::{Depth, FigureOutput};

/// The site-headroom scenario for one policy at the given depth.
fn site_scenario(policy: PolicyKind, depth: Depth, seed: u64) -> Scenario {
    let step = match depth {
        Depth::Quick => 5,
        Depth::Full => 2,
    };
    Scenario::builder("site-headroom")
        .policy(policy)
        .weeks(depth.weeks(1.0))
        .seed(seed)
        .site(4)
        .site_search(50, step)
        .build()
}

/// `site-headroom`: per-policy deployable servers for a demo 4-cluster
/// heterogeneous site.
pub fn site_headroom(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "site-headroom",
        "Site-level deployable servers under a shared substation budget",
    );
    let plans: Vec<PolicyPlan> = PolicyKind::all()
        .into_iter()
        .map(|policy| {
            let sc = site_scenario(policy, depth, seed);
            match sc.run().expect("site scenario must run").outcome {
                Outcome::Site(site) => site.plan,
                Outcome::Row(_) | Outcome::Region(_) => {
                    unreachable!("site scenario dispatches to the planner")
                }
            }
        })
        .collect();
    let site = site_scenario(PolicyKind::Polca, depth, seed)
        .site_spec()
        .expect("site scenario has a topology");

    let mut t = Table::new(
        "Site headroom",
        &["policy", "deployable", "added", "site peak", "brakes", "caps/day", "HP p99", "LP p99"],
    );
    let mut csv = Csv::new(&[
        "policy", "deployable", "added_frac", "site_peak_norm", "brakes", "caps_per_day",
        "worst_hp_p99", "worst_lp_p99", "feasible",
    ]);
    for p in &plans {
        t.row(vec![
            p.policy.name().to_string(),
            if p.feasible { p.deployable_servers.to_string() } else { "—".into() },
            pct(p.added_pct as f64 / 100.0, 0),
            pct(p.site_peak_w / p.substation_budget_w, 1),
            p.brake_events.to_string(),
            f(p.cap_events_per_day, 1),
            pct(p.worst_hp_p99, 2),
            pct(p.worst_lp_p99, 2),
        ]);
        csv.row_strs(&[
            p.policy.name().to_string(),
            p.deployable_servers.to_string(),
            f(p.added_pct as f64 / 100.0, 2),
            f(p.site_peak_w / p.substation_budget_w, 4),
            p.brake_events.to_string(),
            f(p.cap_events_per_day, 2),
            f(p.worst_hp_p99, 4),
            f(p.worst_lp_p99, 4),
            (p.feasible as u8).to_string(),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("site_headroom.csv".into(), csv));
    out.notes.push(format!(
        "{} clusters ({} baseline servers, {:.0} kW substation); deployable = SLOs held, \
         zero brakes, feeds and substation within budget. Row-level paper headline: +30%.",
        site.clusters.len(),
        site.baseline_servers(),
        site.substation_budget_w / 1e3
    ));
    out
}

/// The region-headroom scenario at the given depth (matches the
/// `region-headroom` preset shape; quick shrinks the region and
/// coarsens the search, not the horizon — the one-day horizon is what
/// keeps the analytic phase rotation exact).
fn region_scenario(depth: Depth, seed: u64) -> Scenario {
    let (sites, step) = match depth {
        Depth::Quick => (6, 10),
        Depth::Full => (12, 5),
    };
    Scenario::builder("region-headroom")
        .policy(PolicyKind::Polca)
        .weeks(1.0 / 7.0)
        .seed(seed)
        .region(sites)
        .region_clusters(3)
        .region_grid(0.85)
        .region_search(50, step)
        .build()
}

/// `region-headroom`: joint allocation across a demo region under one
/// shared grid budget, computed from the archetype cache + trace
/// algebra instead of per-candidate simulation.
pub fn region_headroom(depth: Depth, seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new(
        "region-headroom",
        "Region-level deployable servers under a shared grid budget",
    );
    let sc = region_scenario(depth, seed);
    let region = sc.region_spec().expect("region scenario has a topology");
    let pc = sc.region_plan_config().expect("region scenario has a plan config");
    let plan = plan_region(&region, &pc);

    let mut t = Table::new(
        "Region plan (POLCA)",
        &["site", "tz", "added", "peak kW", "budget kW", "util"],
    );
    let mut csv = Csv::new(&[
        "site", "tz_offset_s", "added_pct", "site_peak_w", "site_budget_w", "utilization",
    ]);
    for (i, name) in plan.site_names.iter().enumerate() {
        let util = plan.site_peak_w[i] / plan.site_budget_w[i];
        t.row(vec![
            name.clone(),
            format!("{:+.0}h", region.sites[i].tz_offset_s / 3600.0),
            pct(plan.added_pct[i] as f64 / 100.0, 0),
            f(plan.site_peak_w[i] / 1e3, 0),
            f(plan.site_budget_w[i] / 1e3, 0),
            pct(util, 1),
        ]);
        csv.row_strs(&[
            name.clone(),
            f(region.sites[i].tz_offset_s, 0),
            plan.added_pct[i].to_string(),
            f(plan.site_peak_w[i], 1),
            f(plan.site_budget_w[i], 1),
            f(util, 4),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("region_headroom.csv".into(), csv));
    out.notes.push(format!(
        "{} deployable servers of {} baseline (+{:.1}%); grid peak {:.2} MW / budget \
         {:.2} MW (uniform +{}% before per-site bumps){}.",
        plan.deployed_servers,
        plan.baseline_servers,
        plan.headroom_pct(),
        plan.grid_peak_w / 1e6,
        plan.grid_budget_w / 1e6,
        plan.uniform_added_pct,
        if plan.feasible { "" } else { "; INFEASIBLE at zero added servers" }
    ));
    let region_clusters: usize = region.sites.iter().map(|rs| rs.site.clusters.len()).sum();
    let naive_sims = plan.candidate_evals * region_clusters;
    out.notes.push(format!(
        "trace algebra ran {} archetype simulations for {} closed-form candidate \
         evaluations; a per-candidate simulating planner would have run ~{} cluster \
         simulations for the same search.",
        plan.archetype_sims, plan.candidate_evals, naive_sims
    ));
    out
}
