//! Reproduction harness: one generator per table/figure in the paper's
//! evaluation (see DESIGN.md §4 for the full index). Each generator
//! prints the paper-style rows and emits CSV/JSON under an output
//! directory for plotting.

pub mod adapt;
pub mod characterization;
pub mod evaluation;
pub mod faults;
pub mod fleet;
pub mod mixed;

use std::path::Path;

use crate::util::csv::Csv;
use crate::util::table::Table;

/// Output of one experiment generator.
#[derive(Debug, Clone, Default)]
pub struct FigureOutput {
    /// Experiment id (the `polca figure` key).
    pub id: String,
    /// Human-readable title.
    pub title: String,
    /// Paper-style tables to print.
    pub tables: Vec<Table>,
    /// CSV artifacts: (file name, contents).
    pub csvs: Vec<(String, Csv)>,
    /// Free-form commentary lines (paper-value comparisons etc.).
    pub notes: Vec<String>,
}

impl FigureOutput {
    /// Empty output with an id and title.
    pub fn new(id: &str, title: &str) -> Self {
        FigureOutput { id: id.into(), title: title.into(), ..Default::default() }
    }

    /// Print tables and notes to stdout.
    pub fn print(&self) {
        println!("=== {} — {} ===", self.id, self.title);
        for t in &self.tables {
            println!("{}", t.render());
        }
        for n in &self.notes {
            println!("note: {n}");
        }
    }

    /// Write every CSV artifact under `out_dir`.
    pub fn write(&self, out_dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(out_dir)?;
        for (name, csv) in &self.csvs {
            csv.write_to(&out_dir.join(name))?;
        }
        Ok(())
    }
}

/// Experiment speed: `Quick` shortens simulated horizons for smoke runs;
/// `Full` uses the paper's durations (1-week tuning, 5-week evaluation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Depth {
    /// Shortened horizons for smoke runs.
    Quick,
    /// The paper's durations.
    Full,
}

impl Depth {
    /// The simulated horizon to use given the paper's full duration.
    pub fn weeks(&self, full: f64) -> f64 {
        match self {
            Depth::Quick => (full * 0.15).max(0.1),
            Depth::Full => full,
        }
    }
}

/// All known experiment ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "table1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig11", "table2",
        "table3", "table4", "table5", "fig13", "fig14", "fig15a", "fig15b", "fig16", "fig17",
        "fig18", "fig19", "site-headroom", "region-headroom", "mixed-row", "fault-matrix",
        "adaptive-drift",
    ]
}

/// Run one experiment by id.
pub fn run_experiment(id: &str, depth: Depth, seed: u64) -> anyhow::Result<FigureOutput> {
    use characterization as ch;
    use evaluation as ev;
    Ok(match id {
        "table1" => ch::table1(),
        "fig2" => ch::fig2(),
        "fig4" => ch::fig4(seed),
        "fig5" => ch::fig5(),
        "fig6" => ch::fig6(),
        "fig7" => ch::fig7(),
        "fig8" => ch::fig8(seed),
        "fig9" => ch::fig9(),
        "fig11" => ch::fig11(seed),
        "fig19" => ch::fig19(),
        "table3" => ch::table3(),
        "table4" => ch::table4_fig(),
        "table5" => ch::table5(),
        "table2" => ev::table2(depth, seed),
        "fig13" => ev::fig13(depth, seed),
        "fig14" => ev::fig14(depth, seed),
        "fig15a" => ev::fig15a(depth, seed),
        "fig15b" => ev::fig15b(depth, seed),
        "fig16" => ev::fig16(depth, seed),
        "fig17" => ev::fig17(depth, seed),
        "fig18" => ev::fig18(depth, seed),
        "site-headroom" => fleet::site_headroom(depth, seed),
        "region-headroom" => fleet::region_headroom(depth, seed),
        "mixed-row" => mixed::mixed_row(depth, seed),
        "fault-matrix" => faults::fault_matrix(depth, seed),
        "adaptive-drift" => adapt::adaptive_drift(depth, seed),
        other => anyhow::bail!("unknown experiment '{other}' (see `polca figure list`)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_complete_and_unique() {
        let ids = all_ids();
        assert_eq!(ids.len(), 26);
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
    }

    #[test]
    fn static_experiments_run() {
        for id in ["table1", "fig2", "table3", "table4", "table5"] {
            let out = run_experiment(id, Depth::Quick, 0).unwrap();
            assert!(!out.tables.is_empty(), "{id} produced no tables");
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run_experiment("fig99", Depth::Quick, 0).is_err());
    }
}
