//! §2 characterization figures (Figs 2, 4–9, 11, 19) and the constant
//! tables (1, 3, 4, 5).

use crate::characterize::catalog::{find, inference_models, training_models, vision_models};
use crate::characterize::timeseries::{inference_timeseries, summarize, training_timeseries};
use crate::config::{PolicyConfig, RowConfig, SloConfig};
use crate::power::gpu::{CapMode, Phase};
use crate::power::server::ServerPowerModel;
use crate::power::training::TrainingPowerModel;
use crate::util::csv::Csv;
use crate::util::rng::Rng;
use crate::util::table::{f, pct, Table};

use super::FigureOutput;

/// Table 1: default row-level parameters.
pub fn table1() -> FigureOutput {
    let mut out = FigureOutput::new("table1", "Default row-level parameters");
    let r = RowConfig::default();
    let mut t = Table::new("Table 1", &["parameter", "value"]);
    t.row(vec!["Number of servers".into(), r.num_servers.to_string()]);
    t.row(vec!["Server type".into(), "DGX-A100".into()]);
    t.row(vec!["Power telemetry delay".into(), format!("{}s", r.telemetry_delay_s)]);
    t.row(vec!["Power brake latency".into(), format!("{}s", r.power_brake_latency_s)]);
    t.row(vec!["OOB commands latency".into(), format!("{}s", r.oob_latency_s)]);
    out.tables.push(t);
    out
}

/// Fig 2: provisioned power breakdown of an 8×A100-80GB server.
pub fn fig2() -> FigureOutput {
    let mut out = FigureOutput::new("fig2", "Provisioned power (8×A100-80GB server)");
    let m = ServerPowerModel::default();
    let mut t = Table::new("Fig 2", &["component", "provisioned W", "share"]);
    let mut csv = Csv::new(&["component", "watts", "share"]);
    for (name, w, share) in m.breakdown() {
        t.row(vec![name.into(), f(w, 0), pct(share, 1)]);
        csv.row_strs(&[name.into(), f(w, 0), f(share, 4)]);
    }
    t.row(vec!["TOTAL".into(), f(m.provisioned_w(), 0), "100%".into()]);
    out.tables.push(t);
    out.csvs.push(("fig2_breakdown.csv".into(), csv));
    out.notes.push(format!(
        "GPUs are {:.0}% of the provisioned budget (paper: ~50%); {:.0}% of consumed power under load (paper: ~60%)",
        m.gpu_provisioned_share() * 100.0,
        m.gpu_consumed_share(Phase::Token { batch: 8.0 }) * 100.0
    ));
    out
}

/// Fig 4: inference power timeseries (3 inferences per model).
pub fn fig4(seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig4", "GPU power timeseries, inference (prompt spikes vs token phase)");
    let mut t = Table::new(
        "Fig 4 summary",
        &["model", "peak/TDP", "mean/TDP", "prompt_s", "token_s", "spike>mean"],
    );
    let mut csv = Csv::new(&["model", "t_s", "power_frac"]);
    for m in inference_models() {
        if m.name == "RoBERTa" {
            continue; // encoder-only: no token phase; Fig 4 shows decoders
        }
        let (input, output) = (2048.0, 256.0);
        let ts = inference_timeseries(&m, input, output, 1.0, 3, 0.1, seed);
        let (peak, mean, _) = summarize(&ts);
        for &(ts_t, p) in ts.iter().step_by(5) {
            csv.row_strs(&[m.name.into(), f(ts_t, 1), f(p, 4)]);
        }
        t.row(vec![
            m.name.into(),
            f(peak, 2),
            f(mean, 2),
            f(m.prompt_time_s(input, 1.0), 2),
            f(m.token_time_s(output, 1.0), 1),
            f(peak / mean, 2),
        ]);
    }
    out.tables.push(t);
    out.csvs.push(("fig4_timeseries.csv".into(), csv));
    out.notes.push("power spikes at request start (prompt phase), stable low draw during token sampling".into());
    out
}

/// Fig 5 a–f: power & latency sensitivity to input/batch/output sizes.
pub fn fig5() -> FigureOutput {
    let mut out = FigureOutput::new("fig5", "Power (mean, peak) and latency vs input/batch/output");
    let models = inference_models();

    // (a)+(b): input sweep
    let mut ta = Table::new("Fig 5a/5b — input sweep (batch=1, output=128)", &["model", "input", "peak/TDP", "mean/TDP", "latency_s"]);
    let mut ca = Csv::new(&["model", "input", "peak", "mean", "latency_s"]);
    for m in &models {
        for &input in &[256.0, 1024.0, 4096.0, 8192.0] {
            let peak = m.power.prompt_peak_frac(input);
            let mean = m.power.token_mean_frac(1.0);
            let lat = m.request_latency_s(input, 128.0, 1.0, 1.0);
            ta.row(vec![m.name.into(), f(input, 0), f(peak, 2), f(mean, 2), f(lat, 1)]);
            ca.row_strs(&[m.name.into(), f(input, 0), f(peak, 4), f(mean, 4), f(lat, 2)]);
        }
    }
    out.tables.push(ta);
    out.csvs.push(("fig5ab_input.csv".into(), ca));

    // (c)+(d): batch sweep
    let mut tc = Table::new("Fig 5c/5d — batch sweep (input=1024, output=128)", &["model", "batch", "peak/TDP", "mean/TDP", "latency_s"]);
    let mut cc = Csv::new(&["model", "batch", "peak", "mean", "latency_s"]);
    for m in &models {
        for &batch in &[1.0, 4.0, 16.0] {
            let peak = m.power.prompt_peak_frac(1024.0 * batch);
            let mean = m.power.token_mean_frac(batch);
            let lat = m.request_latency_s(1024.0, 128.0, batch, 1.0);
            tc.row(vec![m.name.into(), f(batch, 0), f(peak, 2), f(mean, 2), f(lat, 1)]);
            cc.row_strs(&[m.name.into(), f(batch, 0), f(peak, 4), f(mean, 4), f(lat, 2)]);
        }
    }
    out.tables.push(tc);
    out.csvs.push(("fig5cd_batch.csv".into(), cc));

    // (e)+(f): output sweep
    let mut te = Table::new("Fig 5e/5f — output sweep (input=1024, batch=1)", &["model", "output", "peak/TDP", "mean/TDP", "latency_s"]);
    let mut ce = Csv::new(&["model", "output", "peak", "mean", "latency_s"]);
    for m in &models {
        for &output in &[128.0, 512.0, 2048.0] {
            let peak = m.power.prompt_peak_frac(1024.0);
            let mean = m.power.token_mean_frac(1.0);
            let lat = m.request_latency_s(1024.0, output, 1.0, 1.0);
            te.row(vec![m.name.into(), f(output, 0), f(peak, 2), f(mean, 2), f(lat, 1)]);
            ce.row_strs(&[m.name.into(), f(output, 0), f(peak, 4), f(mean, 4), f(lat, 2)]);
        }
    }
    out.tables.push(te);
    out.csvs.push(("fig5ef_output.csv".into(), ce));
    out.notes.push("peak rises with input & batch; mean rises with batch only; latency flat in input (<4k), linear in output".into());
    out
}

/// Fig 6: power capping vs frequency capping on BLOOM inference.
pub fn fig6() -> FigureOutput {
    let mut out = FigureOutput::new("fig6", "Power cap vs frequency cap (BLOOM, input=8192, output=128, batch=1)");
    let m = find("BLOOM-176B").unwrap();
    let phase = Phase::Prompt { total_input: 8192.0 };
    let mut t = Table::new(
        "Fig 6",
        &["control", "setting", "observed peak/TDP", "sustained/TDP", "latency_s", "note"],
    );
    let mut csv = Csv::new(&["control", "setting", "peak", "sustained", "latency_s"]);
    let nominal_lat = m.request_latency_s(8192.0, 128.0, 1.0, 1.0);
    t.row(vec!["none".into(), "-".into(), f(m.power.phase_power_nominal(phase), 2), f(m.power.phase_power_nominal(phase), 2), f(nominal_lat, 1), "".into()]);
    for &cap_w in &[400.0, 375.0, 350.0, 325.0] {
        let frac = cap_w / 400.0;
        let cap = CapMode::PowerCap { frac_of_tdp: frac };
        let peak = m.power.phase_power(phase, cap, true); // spike escapes
        let sustained = m.power.phase_power(phase, cap, false);
        let r = m.power.power_cap_freq_ratio(phase, frac);
        let lat = m.request_latency_s(8192.0, 128.0, 1.0, r);
        t.row(vec!["power-cap".into(), format!("{cap_w:.0}W"), f(peak, 2), f(sustained, 2), f(lat, 1), "spike escapes cap".into()]);
        csv.row_strs(&["power".into(), f(cap_w, 0), f(peak, 4), f(sustained, 4), f(lat, 2)]);
    }
    for &mhz in &[1400.0, 1300.0, 1200.0, 1100.0] {
        let cap = CapMode::FreqCap { mhz };
        let peak = m.power.phase_power(phase, cap, true);
        let lat = m.request_latency_s(8192.0, 128.0, 1.0, mhz / m.power.max_freq_mhz);
        t.row(vec!["freq-cap".into(), format!("{mhz:.0}MHz"), f(peak, 2), f(peak, 2), f(lat, 1), "proactive: spike bounded".into()]);
        csv.row_strs(&["freq".into(), f(mhz, 0), f(peak, 4), f(peak, 4), f(lat, 2)]);
    }
    out.tables.push(t);
    out.csvs.push(("fig6_capping.csv".into(), csv));
    out.notes.push("power capping is reactive (prompt spikes exceed the cap); frequency capping is proactive and chosen for POLCA".into());
    out
}

/// Fig 7: peak power reduction vs performance reduction across SM freqs.
pub fn fig7() -> FigureOutput {
    let mut out = FigureOutput::new("fig7", "Peak power vs performance reduction at varying SM frequencies");
    let freqs = [1410.0, 1330.0, 1250.0, 1170.0, 1110.0];
    let mut t = Table::new("Fig 7a — per model (input=2048, output=512, batch=1)", &["model", "freq_MHz", "peak_reduction", "perf_reduction"]);
    let mut csv = Csv::new(&["model", "freq_mhz", "peak_reduction", "perf_reduction"]);
    for m in inference_models() {
        let peak0 = m.power.prompt_peak_frac(2048.0);
        for &mhz in &freqs {
            let peak = m.power.apply_freq(peak0, mhz);
            let perf = m.relative_perf(2048.0, 512.0, 1.0, mhz / m.power.max_freq_mhz);
            t.row(vec![m.name.into(), f(mhz, 0), pct(1.0 - peak / peak0, 1), pct(1.0 - perf, 1)]);
            csv.row_strs(&[m.name.into(), f(mhz, 0), f(1.0 - peak / peak0, 4), f(1.0 - perf, 4)]);
        }
    }
    out.tables.push(t);
    out.csvs.push(("fig7a_models.csv".into(), csv));

    let bloom = find("BLOOM-176B").unwrap();
    let mut tb = Table::new("Fig 7b — BLOOM config sweep", &["input", "batch", "freq_MHz", "peak_reduction", "perf_reduction"]);
    let mut cb = Csv::new(&["input", "batch", "freq_mhz", "peak_reduction", "perf_reduction"]);
    for &(input, batch) in &[(512.0, 1.0), (2048.0, 1.0), (8192.0, 1.0), (2048.0, 8.0)] {
        let peak0 = bloom.power.prompt_peak_frac(input * batch);
        for &mhz in &freqs {
            let peak = bloom.power.apply_freq(peak0, mhz);
            let perf = bloom.relative_perf(input, 512.0, batch, mhz / bloom.power.max_freq_mhz);
            tb.row(vec![f(input, 0), f(batch, 0), f(mhz, 0), pct(1.0 - peak / peak0, 1), pct(1.0 - perf, 1)]);
            cb.row_strs(&[f(input, 0), f(batch, 0), f(mhz, 0), f(1.0 - peak / peak0, 4), f(1.0 - perf, 4)]);
        }
    }
    out.tables.push(tb);
    out.csvs.push(("fig7b_bloom_configs.csv".into(), cb));
    out.notes.push("superlinear: up to ~20% peak power reclaimed for <7% perf loss; larger models & larger inputs more sensitive".into());
    out
}

/// Fig 8: training power timeseries under no cap / power cap / freq cap.
pub fn fig8(seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig8", "Training power timeseries (no cap, power cap, freq cap)");
    let caps = [
        ("none", CapMode::None),
        ("power-325W", CapMode::PowerCap { frac_of_tdp: 0.8125 }),
        ("freq-1110", CapMode::FreqCap { mhz: 1110.0 }),
    ];
    let mut t = Table::new("Fig 8 summary", &["model", "cap", "peak/TDP", "trough/TDP", "swing", "iter_s"]);
    let mut csv = Csv::new(&["model", "cap", "t_s", "power_frac"]);
    for m in training_models() {
        let profile = m.training.unwrap();
        let tm = TrainingPowerModel { profile, calib: m.power };
        for (cap_name, cap) in caps {
            let ts = training_timeseries(&m, cap, 5, 0.1, seed);
            let (peak, _, trough) = summarize(&ts);
            for &(ts_t, p) in ts.iter().step_by(3) {
                csv.row_strs(&[m.name.into(), cap_name.into(), f(ts_t, 1), f(p, 4)]);
            }
            t.row(vec![
                m.name.into(),
                cap_name.into(),
                f(peak, 2),
                f(trough, 2),
                f(tm.swing_frac(cap), 2),
                f(tm.iter_time_s(cap), 2),
            ]);
        }
    }
    out.tables.push(t);
    out.csvs.push(("fig8_training_timeseries.csv".into(), csv));
    out.notes.push("RoBERTa troughs at 75% of TDP, GPT-NeoX at 50%, Flan-T5 at idle (20%); capping shrinks the swing only when the trough is idle".into());
    out
}

/// Fig 9: training peak power vs throughput under capping.
pub fn fig9() -> FigureOutput {
    let mut out = FigureOutput::new("fig9", "Training: peak power vs performance reduction");
    let mut t = Table::new("Fig 9", &["model", "control", "setting", "peak_reduction", "perf_reduction"]);
    let mut csv = Csv::new(&["model", "control", "setting", "peak_reduction", "perf_reduction"]);
    for m in training_models() {
        let tm = TrainingPowerModel { profile: m.training.unwrap(), calib: m.power };
        let p0 = tm.peak_frac(CapMode::None);
        for &mhz in &[1330.0, 1250.0, 1110.0] {
            let cap = CapMode::FreqCap { mhz };
            t.row(vec![m.name.into(), "freq".into(), f(mhz, 0), pct(1.0 - tm.peak_frac(cap) / p0, 1), pct(1.0 - tm.relative_throughput(cap), 1)]);
            csv.row_strs(&[m.name.into(), "freq".into(), f(mhz, 0), f(1.0 - tm.peak_frac(cap) / p0, 4), f(1.0 - tm.relative_throughput(cap), 4)]);
        }
        for &fracw in &[0.95, 0.875, 0.8125] {
            let cap = CapMode::PowerCap { frac_of_tdp: fracw };
            t.row(vec![m.name.into(), "power".into(), f(fracw * 400.0, 0), pct(1.0 - tm.peak_frac(cap) / p0, 1), pct(1.0 - tm.relative_throughput(cap), 1)]);
            csv.row_strs(&[m.name.into(), "power".into(), f(fracw * 400.0, 0), f(1.0 - tm.peak_frac(cap) / p0, 4), f(1.0 - tm.relative_throughput(cap), 4)]);
        }
    }
    out.tables.push(t);
    out.csvs.push(("fig9_training_capping.csv".into(), csv));
    out.notes.push("frequency capping reclaims ~22% peak for ~10% throughput loss (Flan-T5/NeoX); power capping is less controllable".into());
    out
}

/// Fig 11: per-server and per-GPU peak power vs TDP across a fleet.
pub fn fig11(seed: u64) -> FigureOutput {
    let mut out = FigureOutput::new("fig11", "Server & GPU peak power normalized to TDP (production-like fleet)");
    let mut rng = Rng::new(seed ^ 0x11);
    let srv = ServerPowerModel::default();
    let mut csv = Csv::new(&["server", "gpu_peak_over_tdp", "server_peak_over_tdp"]);
    let mut gpu_stats = crate::util::stats::Running::new();
    let mut srv_stats = crate::util::stats::Running::new();
    let models = inference_models();
    for i in 0..60 {
        let m = &models[rng.below(models.len() as u64) as usize];
        // Peak is driven by the largest prompt the server sees.
        let input = rng.range_f64(2048.0, 8192.0);
        let batch = *rng.choose(&[1.0, 2.0, 4.0]);
        let gpu_peak = m.power.prompt_peak_frac(input * batch) + rng.normal_with(0.02, 0.015);
        let server_peak = srv.server_power_w(
            Phase::Prompt { total_input: input * batch },
            CapMode::None,
            false,
        ) / srv.provisioned_w()
            + rng.normal_with(0.0, 0.01);
        gpu_stats.push(gpu_peak);
        srv_stats.push(server_peak);
        csv.row_strs(&[i.to_string(), f(gpu_peak, 4), f(server_peak, 4)]);
    }
    let mut t = Table::new("Fig 11 summary", &["metric", "min", "mean", "max"]);
    t.row(vec!["GPU peak / GPU TDP".into(), f(gpu_stats.min(), 2), f(gpu_stats.mean(), 2), f(gpu_stats.max(), 2)]);
    t.row(vec!["server peak / server provisioned".into(), f(srv_stats.min(), 2), f(srv_stats.mean(), 2), f(srv_stats.max(), 2)]);
    out.tables.push(t);
    out.csvs.push(("fig11_fleet_peaks.csv".into(), csv));
    out.notes.push("GPU peaks exceed GPU TDP (paper: by up to 500W per server); server peak tracks GPU peak with a narrower range".into());
    out
}

/// Fig 19: frequency-scaling response of vision/multimodal models (§7).
pub fn fig19() -> FigureOutput {
    let mut out = FigureOutput::new("fig19", "Vision/multimodal: peak power vs performance at varying SM frequencies");
    let freqs = [1410.0, 1330.0, 1250.0, 1170.0, 1110.0];
    let mut t = Table::new("Fig 19", &["model", "freq_MHz", "peak_reduction", "perf_reduction"]);
    let mut csv = Csv::new(&["model", "freq_mhz", "peak_reduction", "perf_reduction"]);
    for m in vision_models() {
        let peak0 = m.power.prompt_peak_frac(1024.0);
        for &mhz in &freqs {
            let peak = m.power.apply_freq(peak0, mhz);
            let perf = m.relative_perf(1024.0, 256.0, 8.0, mhz / m.power.max_freq_mhz);
            t.row(vec![m.name.into(), f(mhz, 0), pct(1.0 - peak / peak0, 1), pct(1.0 - perf, 1)]);
            csv.row_strs(&[m.name.into(), f(mhz, 0), f(1.0 - peak / peak0, 4), f(1.0 - perf, 4)]);
        }
    }
    out.tables.push(t);
    out.csvs.push(("fig19_vision.csv".into(), csv));
    out.notes.push("vision/multimodal perf scales near-linearly with frequency (compute-bound): less headroom than generative LLM inference, but capping still works".into());
    out
}

/// Table 3: POLCA power modes.
pub fn table3() -> FigureOutput {
    let mut out = FigureOutput::new("table3", "Power modes for low and high priority workloads");
    let p = PolicyConfig::default();
    let mut t = Table::new("Table 3", &["mode", "low priority", "high priority"]);
    t.row(vec!["Uncapped".into(), "Uncapped".into(), "Uncapped".into()]);
    t.row(vec![format!("Threshold T1 ({:.0}%)", p.t1 * 100.0), format!("Freq capped ({:.0} MHz)", p.lp_freq_t1_mhz), "Uncapped".into()]);
    t.row(vec![format!("Threshold T2 ({:.0}%)", p.t2 * 100.0), format!("Freq capped ({:.0} MHz)", p.lp_freq_t2_mhz), format!("Freq capped ({:.0} MHz)", p.hp_freq_t2_mhz)]);
    t.row(vec!["Powerbrake".into(), format!("Freq capped ({:.0} MHz)", p.brake_freq_mhz), format!("Freq capped ({:.0} MHz)", p.brake_freq_mhz)]);
    out.tables.push(t);
    out
}

/// Table 4: workload distribution.
pub fn table4_fig() -> FigureOutput {
    let mut out = FigureOutput::new("table4", "Workload distribution (BLOOM-176B)");
    let mut t = Table::new("Table 4", &["workload", "prompt size", "output size", "ratio", "priority"]);
    for w in crate::workload::spec::table4() {
        let pri = if w.hp_fraction == 0.0 {
            "Low".to_string()
        } else if w.hp_fraction == 1.0 {
            "High".to_string()
        } else {
            "50:50".to_string()
        };
        t.row(vec![
            w.name.into(),
            format!("{}-{}", w.prompt_range.0, w.prompt_range.1),
            format!("{}-{}", w.output_range.0, w.output_range.1),
            pct(w.ratio, 0),
            pri,
        ]);
    }
    out.tables.push(t);
    out
}

/// Table 5: SLOs.
pub fn table5() -> FigureOutput {
    let mut out = FigureOutput::new("table5", "Service level objectives for POLCA");
    let s = SloConfig::default();
    let mut t = Table::new("Table 5", &["metric", "high priority", "low priority"]);
    t.row(vec!["P50 latency impact".into(), format!("< {:.0}%", s.hp_p50_impact * 100.0), format!("< {:.0}%", s.lp_p50_impact * 100.0)]);
    t.row(vec!["P99 latency impact".into(), format!("< {:.0}%", s.hp_p99_impact * 100.0), format!("< {:.0}%", s.lp_p99_impact * 100.0)]);
    t.row(vec!["Number of powerbrakes".into(), s.max_powerbrakes.to_string(), s.max_powerbrakes.to_string()]);
    out.tables.push(t);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shows_spike_structure() {
        let out = fig4(1);
        assert!(!out.csvs.is_empty());
        assert!(out.csvs[0].1.len() > 100);
    }

    #[test]
    fn fig5_has_all_panels() {
        let out = fig5();
        assert_eq!(out.tables.len(), 3);
        assert_eq!(out.csvs.len(), 3);
    }

    #[test]
    fn fig6_power_cap_peak_exceeds_sustained() {
        let out = fig6();
        // the csv rows for power caps must show peak > sustained
        let csv = &out.csvs[0].1;
        let text = csv.to_string();
        for line in text.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            if cells[0] == "power" {
                let peak: f64 = cells[2].parse().unwrap();
                let sustained: f64 = cells[3].parse().unwrap();
                assert!(peak >= sustained, "{line}");
            }
        }
    }

    #[test]
    fn fig7_superlinear_for_all_models() {
        let out = fig7();
        let text = out.csvs[0].1.to_string();
        for line in text.lines().skip(1) {
            let cells: Vec<&str> = line.split(',').collect();
            let peak_red: f64 = cells[2].parse().unwrap();
            let perf_red: f64 = cells[3].parse().unwrap();
            assert!(
                peak_red >= perf_red - 1e-9,
                "capping must reclaim more power than perf lost: {line}"
            );
        }
    }

    #[test]
    fn fig8_and_9_run() {
        assert_eq!(fig8(1).tables.len(), 1);
        assert!(fig9().csvs[0].1.len() >= 18);
    }

    #[test]
    fn fig11_gpu_peaks_exceed_tdp() {
        let out = fig11(3);
        let text = out.csvs[0].1.to_string();
        let any_over: bool = text.lines().skip(1).any(|l| {
            l.split(',').nth(1).unwrap().parse::<f64>().unwrap() > 1.0
        });
        assert!(any_over, "some GPU peaks must exceed TDP (paper Fig 11)");
    }

    #[test]
    fn fig19_runs() {
        assert!(fig19().csvs[0].1.len() == 10);
    }
}
