//! Configuration system: a TOML-subset parser **and renderer** plus the
//! typed experiment configuration used across the simulator, with
//! presets matching the paper's Tables 1, 3, 4 and 5.
//!
//! Supported TOML subset (enough for real deployment configs):
//! `[section]` headers, `key = value` with strings (with `\"`, `\\`,
//! `\n`, `\t` escapes), integers, floats, booleans, and (nestable)
//! arrays; `#` comments. [`Toml::render`] emits the same subset, so a
//! document round-trips: `Toml::parse(&doc.render()) == doc` (the
//! scenario layer relies on this for `polca scenario save`).
//!
//! Parse errors always cite 1-based line numbers (the first line of the
//! input is line 1), matching what editors display.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// A parsed flat-ish TOML document: section -> key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Toml {
    /// Section name ("" = top level) → key → value.
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

/// One TOML value (the supported subset).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// A boolean.
    Bool(bool),
    /// A flat array.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// Numeric view (floats and integers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    /// Integer view.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Toml {
    /// Parse the supported TOML subset (see module docs). Errors cite
    /// 1-based line numbers (the first input line is line 1).
    pub fn parse(input: &str) -> anyhow::Result<Toml> {
        let mut doc = Toml::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();
        for (idx, raw) in input.lines().enumerate() {
            let line_no = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {line_no}: bad section header"))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let value = parse_value(v.trim())
                    .with_context(|| format!("line {line_no}: bad value '{}'", v.trim()))?;
                doc.sections
                    .get_mut(&section)
                    .unwrap()
                    .insert(k.trim().to_string(), value);
            } else {
                bail!("line {line_no}: expected 'key = value' or '[section]'");
            }
        }
        Ok(doc)
    }

    /// Render the document in the same subset [`Toml::parse`] accepts:
    /// top-level keys first, then `[section]` blocks in name order, keys
    /// sorted within each. Strings are escaped (`\"`, `\\`, `\n`, `\t`),
    /// and whole-valued floats keep a trailing `.0` so they re-parse as
    /// floats — `Toml::parse(&doc.render()) == doc` for any document
    /// whose section/key names are themselves representable.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, keys) in &self.sections {
            if name.is_empty() {
                // Top-level keys need no header; parse starts there.
                if keys.is_empty() {
                    continue;
                }
            } else {
                if !out.is_empty() {
                    out.push('\n');
                }
                out.push_str(&format!("[{name}]\n"));
            }
            for (k, v) in keys {
                out.push_str(&format!("{k} = {}\n", render_value(v)));
            }
        }
        out
    }

    /// Insert (or overwrite) `[section] key = value`.
    pub fn set(&mut self, section: &str, key: &str, value: TomlValue) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }

    /// Raw value at `[section] key`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Float at `[section] key`, or `default`.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Unsigned integer at `[section] key`, or `default`.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_i64()).map(|x| x as usize).unwrap_or(default)
    }

    /// Boolean at `[section] key`, or `default`.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// String at `[section] key`, or `default`.
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings, including escaped quotes.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
        } else {
            match c {
                '"' => in_str = true,
                '#' => return &line[..i],
                _ => {}
            }
        }
    }
    line
}

/// Decode a quoted string starting at `s[0] == '"'`; returns the content
/// and the remaining input after the closing quote. Escapes: `\"`, `\\`,
/// `\n`, `\t`; any other `\x` is kept literally (backslash included),
/// matching the historical lenient behavior.
fn parse_str(s: &str) -> anyhow::Result<(String, &str)> {
    let mut out = String::new();
    let mut escaped = false;
    for (i, c) in s.char_indices().skip(1) {
        if escaped {
            match c {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'n' => out.push('\n'),
                't' => out.push('\t'),
                other => {
                    out.push('\\');
                    out.push(other);
                }
            }
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            return Ok((out, &s[i + 1..]));
        } else {
            out.push(c);
        }
    }
    bail!("unterminated string")
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    if s.starts_with('"') {
        let (content, rest) = parse_str(s)?;
        if !rest.trim().is_empty() {
            bail!("trailing characters after closing quote: '{}'", rest.trim());
        }
        return Ok(TomlValue::Str(content));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '[' => depth += 1,
            ']' => depth = depth.saturating_sub(1),
            ',' if depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn render_value(v: &TomlValue) -> String {
    match v {
        TomlValue::Str(s) => render_str(s),
        TomlValue::Int(i) => i.to_string(),
        TomlValue::Float(x) => render_float(*x),
        TomlValue::Bool(b) => b.to_string(),
        TomlValue::Arr(items) => {
            let inner: Vec<String> = items.iter().map(render_value).collect();
            format!("[{}]", inner.join(", "))
        }
    }
}

/// Float formatting that survives re-parsing as the same `f64`: Rust's
/// `Debug` for floats emits the shortest round-trippable decimal and
/// always marks floatness (a `.0` suffix or an exponent), so whole
/// values of any magnitude re-parse as floats, not ints.
fn render_float(x: f64) -> String {
    format!("{x:?}")
}

fn render_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            _ => out.push(c),
        }
    }
    out.push('"');
    out
}

// ---------------------------------------------------------------------------
// Typed cluster/simulation configuration (paper defaults).
// ---------------------------------------------------------------------------

/// Row-level parameters — paper Table 1 defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct RowConfig {
    /// Baseline number of servers the row's power budget was provisioned for.
    pub num_servers: usize,
    /// Telemetry sampling delay (PDU -> power manager), seconds.
    pub telemetry_delay_s: f64,
    /// Hardware powerbrake engage latency, seconds.
    pub power_brake_latency_s: f64,
    /// Out-of-band (SMBPBI via BMC) cap-apply latency, seconds.
    pub oob_latency_s: f64,
    /// Telemetry sampling period, seconds.
    pub telemetry_period_s: f64,
}

impl Default for RowConfig {
    fn default() -> Self {
        // Table 1: 40 DGX-A100 servers, 2s telemetry, 5s brake, 40s OOB.
        RowConfig {
            num_servers: 40,
            telemetry_delay_s: 2.0,
            power_brake_latency_s: 5.0,
            oob_latency_s: 40.0,
            telemetry_period_s: 2.0,
        }
    }
}

/// POLCA policy parameters — paper §5.1 / Table 3.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyConfig {
    /// Lower threshold (fraction of row budget); caps LP workloads.
    pub t1: f64,
    /// Upper threshold; caps LP harder, then HP.
    pub t2: f64,
    /// T1 hysteresis: uncap when power < T1 - buffer (paper: 5%).
    pub t1_buffer: f64,
    /// T2 hysteresis: uncap HP when power < T2 - buffer.
    pub t2_buffer: f64,
    /// LP cap at T1 (MHz): A100 base frequency.
    pub lp_freq_t1_mhz: f64,
    /// LP cap at T2 (MHz).
    pub lp_freq_t2_mhz: f64,
    /// HP cap at T2 (MHz).
    pub hp_freq_t2_mhz: f64,
    /// Powerbrake frequency (MHz) — near-halt.
    pub brake_freq_mhz: f64,
    /// Nominal max SM clock (MHz).
    pub max_freq_mhz: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            t1: 0.80,
            t2: 0.89,
            t1_buffer: 0.05,
            t2_buffer: 0.05,
            lp_freq_t1_mhz: 1275.0,
            lp_freq_t2_mhz: 1110.0,
            hp_freq_t2_mhz: 1305.0,
            brake_freq_mhz: 288.0,
            max_freq_mhz: 1410.0,
        }
    }
}

/// SLOs — paper Table 5.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// Max HP P50 latency impact (paper: 1%).
    pub hp_p50_impact: f64,
    /// Max HP P99 latency impact (paper: 5%).
    pub hp_p99_impact: f64,
    /// Max LP P50 latency impact (paper: 5%).
    pub lp_p50_impact: f64,
    /// Max LP P99 latency impact (paper: 50%).
    pub lp_p99_impact: f64,
    /// Powerbrake engagements allowed (paper: zero).
    pub max_powerbrakes: u64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            hp_p50_impact: 0.01,
            hp_p99_impact: 0.05,
            lp_p50_impact: 0.05,
            lp_p99_impact: 0.50,
            max_powerbrakes: 0,
        }
    }
}

/// Full experiment configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExperimentConfig {
    /// Row topology and control-path latencies (Table 1).
    pub row: RowConfig,
    /// Policy thresholds and cap setpoints (Table 3).
    pub policy: PolicyConfig,
    /// Latency/brake SLOs (Table 5).
    pub slo: SloConfig,
    /// Root seed for the run's random streams.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Overlay values from a TOML document onto the defaults.
    pub fn from_toml(doc: &Toml) -> ExperimentConfig {
        let d = ExperimentConfig::default();
        ExperimentConfig {
            row: RowConfig {
                num_servers: doc.usize_or("row", "num_servers", d.row.num_servers),
                telemetry_delay_s: doc.f64_or("row", "telemetry_delay_s", d.row.telemetry_delay_s),
                power_brake_latency_s: doc
                    .f64_or("row", "power_brake_latency_s", d.row.power_brake_latency_s),
                oob_latency_s: doc.f64_or("row", "oob_latency_s", d.row.oob_latency_s),
                telemetry_period_s: doc
                    .f64_or("row", "telemetry_period_s", d.row.telemetry_period_s),
            },
            policy: PolicyConfig {
                t1: doc.f64_or("policy", "t1", d.policy.t1),
                t2: doc.f64_or("policy", "t2", d.policy.t2),
                t1_buffer: doc.f64_or("policy", "t1_buffer", d.policy.t1_buffer),
                t2_buffer: doc.f64_or("policy", "t2_buffer", d.policy.t2_buffer),
                lp_freq_t1_mhz: doc.f64_or("policy", "lp_freq_t1_mhz", d.policy.lp_freq_t1_mhz),
                lp_freq_t2_mhz: doc.f64_or("policy", "lp_freq_t2_mhz", d.policy.lp_freq_t2_mhz),
                hp_freq_t2_mhz: doc.f64_or("policy", "hp_freq_t2_mhz", d.policy.hp_freq_t2_mhz),
                brake_freq_mhz: doc.f64_or("policy", "brake_freq_mhz", d.policy.brake_freq_mhz),
                max_freq_mhz: doc.f64_or("policy", "max_freq_mhz", d.policy.max_freq_mhz),
            },
            slo: SloConfig {
                hp_p50_impact: doc.f64_or("slo", "hp_p50_impact", d.slo.hp_p50_impact),
                hp_p99_impact: doc.f64_or("slo", "hp_p99_impact", d.slo.hp_p99_impact),
                lp_p50_impact: doc.f64_or("slo", "lp_p50_impact", d.slo.lp_p50_impact),
                lp_p99_impact: doc.f64_or("slo", "lp_p99_impact", d.slo.lp_p99_impact),
                max_powerbrakes: doc
                    .get("slo", "max_powerbrakes")
                    .and_then(|v| v.as_i64())
                    .map(|x| x as u64)
                    .unwrap_or(d.slo.max_powerbrakes),
            },
            seed: doc.get("", "seed").and_then(|v| v.as_i64()).map(|x| x as u64).unwrap_or(0),
        }
    }

    /// Load a TOML config file and overlay it onto the defaults.
    pub fn load(path: &std::path::Path) -> anyhow::Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Ok(Self::from_toml(&Toml::parse(&text)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Toml::parse(
            r#"
            seed = 7
            [row]
            num_servers = 52         # oversubscribed
            telemetry_delay_s = 2.5
            [policy]
            name = "polca"
            freqs = [1275, 1110.5, "x"]
            enabled = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.usize_or("row", "num_servers", 0), 52);
        assert_eq!(doc.f64_or("row", "telemetry_delay_s", 0.0), 2.5);
        assert_eq!(doc.str_or("policy", "name", ""), "polca");
        assert!(doc.bool_or("policy", "enabled", false));
        let arr = doc.get("policy", "freqs").unwrap();
        match arr {
            TomlValue::Arr(v) => {
                assert_eq!(v[0].as_i64(), Some(1275));
                assert_eq!(v[1].as_f64(), Some(1110.5));
                assert_eq!(v[2].as_str(), Some("x"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("justakey").is_err());
        assert!(Toml::parse("k = @@@").is_err());
        assert!(Toml::parse("k = \"unterminated").is_err());
        assert!(Toml::parse("k = \"done\" trailing").is_err());
    }

    #[test]
    fn errors_cite_one_based_line_numbers() {
        // First line of the input is line 1, in every error path.
        let e = format!("{:#}", Toml::parse("justakey").unwrap_err());
        assert!(e.contains("line 1"), "{e}");
        let e = format!("{:#}", Toml::parse("a = 1\nb = 2\nc = @@@").unwrap_err());
        assert!(e.contains("line 3"), "{e}");
        let e = format!("{:#}", Toml::parse("a = 1\n[unclosed").unwrap_err());
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = Toml::parse("k = \"a # b\"").unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a # b");
        // ... even when an escaped quote precedes the '#'.
        let doc = Toml::parse(r#"k = "a\"b # c" # real comment"#).unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a\"b # c");
    }

    #[test]
    fn string_escapes_round_trip() {
        for content in [
            "plain",
            "with \"quotes\"",
            "back\\slash",
            "tab\tand\nnewline",
            "trailing backslash \\",
            "\\\"mixed\\\" run",
            "a # b",
        ] {
            let mut doc = Toml::default();
            doc.set("", "k", TomlValue::Str(content.to_string()));
            let text = doc.render();
            let reparsed = Toml::parse(&text).unwrap();
            assert_eq!(reparsed.str_or("", "k", "<missing>"), content, "text: {text}");
        }
    }

    #[test]
    fn render_round_trips_documents() {
        let mut doc = Toml::default();
        doc.set("", "seed", TomlValue::Int(7));
        doc.set("", "label", TomlValue::Str("a \"quoted\" name".into()));
        doc.set("row", "num_servers", TomlValue::Int(40));
        doc.set("row", "added", TomlValue::Float(0.3));
        doc.set("row", "whole", TomlValue::Float(2.0));
        doc.set("policy", "enabled", TomlValue::Bool(true));
        doc.set(
            "faults",
            "events",
            TomlValue::Arr(vec![
                TomlValue::Arr(vec![
                    TomlValue::Str("feed-loss".into()),
                    TomlValue::Float(500.0),
                    TomlValue::Float(0.75),
                ]),
                TomlValue::Arr(vec![TomlValue::Str("telemetry-freeze".into())]),
            ]),
        );
        let text = doc.render();
        let reparsed = Toml::parse(&text).unwrap();
        assert_eq!(reparsed, doc, "render:\n{text}");
        // Whole-valued floats stay floats (not silently re-typed as ints).
        assert!(matches!(reparsed.get("row", "whole"), Some(TomlValue::Float(x)) if *x == 2.0));
    }

    #[test]
    fn render_float_precision_is_lossless() {
        for x in [0.1, 1.0 / 3.0, 0.30000000000000004, 123456.789, 1e-12, 6.5e3, 1e15, 1e20] {
            let s = render_float(x);
            assert_eq!(s.parse::<f64>().unwrap(), x, "{s}");
            // Whole values must stay float-typed through a round-trip.
            let mut doc = Toml::default();
            doc.set("", "x", TomlValue::Float(x));
            let back = Toml::parse(&doc.render()).unwrap();
            assert!(matches!(back.get("", "x"), Some(TomlValue::Float(_))), "{s}");
        }
    }

    #[test]
    fn defaults_match_paper_tables() {
        let row = RowConfig::default();
        assert_eq!(row.num_servers, 40); // Table 1
        assert_eq!(row.telemetry_delay_s, 2.0);
        assert_eq!(row.power_brake_latency_s, 5.0);
        assert_eq!(row.oob_latency_s, 40.0);
        let pol = PolicyConfig::default();
        assert_eq!((pol.t1, pol.t2), (0.80, 0.89)); // §6.2 chosen thresholds
        assert_eq!(pol.lp_freq_t1_mhz, 1275.0); // Table 3
        assert_eq!(pol.lp_freq_t2_mhz, 1110.0);
        assert_eq!(pol.hp_freq_t2_mhz, 1305.0);
        assert_eq!(pol.brake_freq_mhz, 288.0);
        let slo = SloConfig::default();
        assert_eq!(slo.max_powerbrakes, 0); // Table 5
        assert_eq!(slo.lp_p99_impact, 0.50);
    }

    #[test]
    fn from_toml_overlays() {
        let doc = Toml::parse("[policy]\nt1 = 0.75\nt2 = 0.85\n").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc);
        assert_eq!(cfg.policy.t1, 0.75);
        assert_eq!(cfg.policy.t2, 0.85);
        assert_eq!(cfg.policy.lp_freq_t1_mhz, 1275.0); // default retained
        assert_eq!(cfg.row.num_servers, 40);
    }
}
