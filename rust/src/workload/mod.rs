//! Workloads: the Table-4 service mix (Summarize / Search / Chat on
//! BLOOM-176B), diurnal interactive arrival processes, and the synthetic
//! production-trace replication of §6.1.

pub mod arrivals;
pub mod spec;
pub mod tracegen;

pub use arrivals::{diurnal_multiplier, ArrivalProcess, DriftConfig};
pub use spec::{assign_servers, sample_request, table4, WorkloadSpec};
pub use tracegen::{target_power_profile, TraceTarget};
