//! Arrival processes for interactive inference: non-homogeneous Poisson
//! with a diurnal rate profile (Table 2: "inference power consumption
//! shows a diurnal pattern since it is an interactive workload").

use crate::util::rng::Rng;

/// Diurnal rate multiplier at time `t_s` (seconds since trace start).
///
/// Shape: interactive traffic — overnight trough (~0.45×), morning ramp,
/// afternoon peak (~1.0×), evening shoulder; weekends ~12% lighter.
/// Mean over a week ≈ 0.75. Deterministic (noise is added by the Poisson
/// sampling itself and by the per-request randomness).
pub fn diurnal_multiplier(t_s: f64) -> f64 {
    let day_s = 86_400.0;
    let hour = (t_s / 3600.0).rem_euclid(24.0);
    let day = (t_s / day_s).floor() as i64 % 7;
    // Two-harmonic daily curve peaking ~15:00, trough ~04:00.
    let x = (hour - 15.0) / 24.0 * std::f64::consts::TAU;
    let base = 0.725 + 0.24 * x.cos() + 0.035 * (2.0 * x).cos();
    let weekend = if day >= 5 { 0.88 } else { 1.0 };
    (base * weekend).max(0.05)
}

/// Per-server non-homogeneous Poisson arrival stream, sampled by
/// thinning against the diurnal envelope.
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    /// Peak arrival rate (requests/s) — the rate at diurnal multiplier 1.
    pub peak_rate: f64,
    /// Diurnal phase offset (s): the envelope is evaluated at `t + phase`,
    /// so a +6 h phase makes this stream peak 6 h *earlier* in sim time —
    /// it serves a region whose afternoon arrives sooner. Used by the
    /// fleet layer to stagger cluster peaks within a site.
    pub phase_s: f64,
    rng: Rng,
}

impl ArrivalProcess {
    /// Stream at the given peak rate with its own random source.
    pub fn new(peak_rate: f64, rng: Rng) -> Self {
        ArrivalProcess { peak_rate, phase_s: 0.0, rng }
    }

    /// Set the diurnal phase offset (builder style).
    pub fn with_phase(mut self, phase_s: f64) -> Self {
        self.phase_s = phase_s;
        self
    }

    /// Next arrival time strictly after `t_s` (thinning algorithm).
    pub fn next_after(&mut self, t_s: f64) -> f64 {
        let lambda_max = self.peak_rate.max(1e-12);
        let mut t = t_s;
        loop {
            t += self.rng.exp(lambda_max);
            let accept = diurnal_multiplier(t + self.phase_s);
            if self.rng.f64() < accept {
                return t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_peak_and_trough() {
        // Peak mid-afternoon on a weekday, trough overnight.
        let peak = diurnal_multiplier(15.0 * 3600.0);
        let trough = diurnal_multiplier(4.0 * 3600.0);
        assert!(peak > 0.95, "peak={peak}");
        assert!(trough < 0.55, "trough={trough}");
        assert!(peak / trough > 1.8);
    }

    #[test]
    fn weekend_lighter() {
        let weekday = diurnal_multiplier(15.0 * 3600.0); // day 0
        let weekend = diurnal_multiplier((5.0 * 24.0 + 15.0) * 3600.0); // day 5
        assert!(weekend < weekday);
    }

    #[test]
    fn weekly_mean_near_three_quarters() {
        let n = 7 * 24 * 12;
        let mean: f64 =
            (0..n).map(|i| diurnal_multiplier(i as f64 * 300.0)).sum::<f64>() / n as f64;
        assert!((0.65..0.80).contains(&mean), "mean={mean}");
    }

    #[test]
    fn arrivals_track_rate() {
        // Count arrivals in a flat-ish window and compare to expectation.
        let mut ap = ArrivalProcess::new(0.1, Rng::new(5));
        let start = 14.0 * 3600.0; // near peak, multiplier ~0.95-1.0
        let mut t = start;
        let mut count = 0;
        while t < start + 20_000.0 {
            t = ap.next_after(t);
            count += 1;
        }
        let expected = 0.1 * diurnal_multiplier(start + 10_000.0) * 20_000.0;
        assert!(
            (count as f64 - expected).abs() < expected * 0.15,
            "count={count} expected={expected}"
        );
    }

    #[test]
    fn phase_shift_moves_the_peak() {
        // Over 04:00-06:00 sim time (envelope ≈ 0.47) a +11 h phase sees
        // 15:00-17:00 (≈ 0.97): the shifted stream must arrive roughly
        // twice as fast. The window stays inside the trough/peak plateaus
        // so the expected ratio (~2.05) clears the 1.5 bar by > 5 sigma.
        let window = 7_200.0;
        let count_at = |phase: f64, seed: u64| {
            let mut ap = ArrivalProcess::new(0.1, Rng::new(seed)).with_phase(phase);
            let start = 4.0 * 3600.0;
            let mut t = start;
            let mut count = 0u32;
            while t < start + window {
                t = ap.next_after(t);
                count += 1;
            }
            count
        };
        let trough = count_at(0.0, 8);
        let peak = count_at(11.0 * 3600.0, 8);
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "peak-phased {peak} vs trough {trough}"
        );
    }

    #[test]
    fn arrivals_strictly_increasing() {
        let mut ap = ArrivalProcess::new(0.5, Rng::new(6));
        let mut t = 0.0;
        for _ in 0..1000 {
            let nt = ap.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }
}
