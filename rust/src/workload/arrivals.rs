//! Arrival processes for interactive inference: non-homogeneous Poisson
//! with a diurnal rate profile (Table 2: "inference power consumption
//! shows a diurnal pattern since it is an interactive workload").

use crate::util::rng::Rng;

/// Seconds in one week (the drift ramp's unit of time).
const WEEK_S: f64 = 7.0 * 86_400.0;

/// Long-horizon demand drift layered on top of the diurnal envelope:
/// a linear demand-growth ramp (fraction per week) plus a slow seasonal
/// sinusoid. Both default to zero, and an [`ArrivalProcess`] without a
/// drift config consumes randomness bit-identically to one built before
/// drift existed — the multi-week adaptive scenarios opt in, everything
/// else is untouched.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftConfig {
    /// Linear demand growth per week (0.10 = +10%/week). Must be > -1.
    pub growth_per_week: f64,
    /// Seasonal modulation amplitude (0.2 = ±20% around the ramp).
    pub season_amp: f64,
    /// Seasonal period in weeks.
    pub season_period_weeks: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { growth_per_week: 0.0, season_amp: 0.0, season_period_weeks: 4.0 }
    }
}

impl DriftConfig {
    /// The drift multiplier at time `t_s` (1.0 at t = 0 when amp = 0).
    /// Floored at 0.01 so a steep negative ramp can't extinguish the
    /// stream (or produce a negative rate).
    pub fn multiplier(&self, t_s: f64) -> f64 {
        let ramp = 1.0 + self.growth_per_week * t_s / WEEK_S;
        let season = 1.0
            + self.season_amp
                * (std::f64::consts::TAU * t_s / (self.season_period_weeks * WEEK_S)).sin();
        (ramp * season).max(0.01)
    }

    /// An upper bound on [`DriftConfig::multiplier`] over `[0, horizon]`
    /// weeks — the thinning envelope the sampler rejects against.
    pub fn max_multiplier(&self, horizon_weeks: f64) -> f64 {
        let ramp_max = (1.0 + self.growth_per_week.max(0.0) * horizon_weeks).max(1.0);
        (ramp_max * (1.0 + self.season_amp.abs())).max(0.01)
    }
}

/// Diurnal rate multiplier at time `t_s` (seconds since trace start).
///
/// Shape: interactive traffic — overnight trough (~0.45×), morning ramp,
/// afternoon peak (~1.0×), evening shoulder; weekends ~12% lighter.
/// Mean over a week ≈ 0.75. Deterministic (noise is added by the Poisson
/// sampling itself and by the per-request randomness).
pub fn diurnal_multiplier(t_s: f64) -> f64 {
    let day_s = 86_400.0;
    let hour = (t_s / 3600.0).rem_euclid(24.0);
    let day = (t_s / day_s).floor() as i64 % 7;
    // Two-harmonic daily curve peaking ~15:00, trough ~04:00.
    let x = (hour - 15.0) / 24.0 * std::f64::consts::TAU;
    let base = 0.725 + 0.24 * x.cos() + 0.035 * (2.0 * x).cos();
    let weekend = if day >= 5 { 0.88 } else { 1.0 };
    (base * weekend).max(0.05)
}

/// Per-server non-homogeneous Poisson arrival stream, sampled by
/// thinning against the diurnal envelope.
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    /// Peak arrival rate (requests/s) — the rate at diurnal multiplier 1.
    pub peak_rate: f64,
    /// Diurnal phase offset (s): the envelope is evaluated at `t + phase`,
    /// so a +6 h phase makes this stream peak 6 h *earlier* in sim time —
    /// it serves a region whose afternoon arrives sooner. Used by the
    /// fleet layer to stagger cluster peaks within a site.
    pub phase_s: f64,
    /// Optional long-horizon drift (ramp + season) with its
    /// precomputed thinning bound. `None` keeps the sampler on the
    /// pre-drift code path, consuming randomness bit-identically.
    drift: Option<DriftState>,
    rng: Rng,
}

/// A [`DriftConfig`] plus the thinning envelope precomputed for the
/// scenario horizon (so the hot sampling loop never recomputes it).
#[derive(Debug, Clone)]
struct DriftState {
    cfg: DriftConfig,
    max_mult: f64,
}

impl ArrivalProcess {
    /// Stream at the given peak rate with its own random source.
    pub fn new(peak_rate: f64, rng: Rng) -> Self {
        ArrivalProcess { peak_rate, phase_s: 0.0, drift: None, rng }
    }

    /// Set the diurnal phase offset (builder style).
    pub fn with_phase(mut self, phase_s: f64) -> Self {
        self.phase_s = phase_s;
        self
    }

    /// Layer long-horizon drift over the diurnal envelope (builder
    /// style). `horizon_weeks` sizes the thinning bound; `None` leaves
    /// the stream exactly as constructed.
    pub fn with_drift(mut self, drift: Option<DriftConfig>, horizon_weeks: f64) -> Self {
        self.drift = drift.map(|cfg| {
            let max_mult = cfg.max_multiplier(horizon_weeks);
            DriftState { cfg, max_mult }
        });
        self
    }

    /// Next arrival time strictly after `t_s` (thinning algorithm).
    pub fn next_after(&mut self, t_s: f64) -> f64 {
        match &self.drift {
            None => {
                let lambda_max = self.peak_rate.max(1e-12);
                let mut t = t_s;
                loop {
                    t += self.rng.exp(lambda_max);
                    let accept = diurnal_multiplier(t + self.phase_s);
                    if self.rng.f64() < accept {
                        return t;
                    }
                }
            }
            Some(d) => {
                // Same thinning loop with the envelope widened to the
                // drift bound; past the horizon the drift ratio can
                // exceed 1, which just means "always accept" — the
                // loop still terminates.
                let lambda_max = (self.peak_rate * d.max_mult).max(1e-12);
                let mut t = t_s;
                loop {
                    t += self.rng.exp(lambda_max);
                    let accept = diurnal_multiplier(t + self.phase_s)
                        * d.cfg.multiplier(t)
                        / d.max_mult;
                    if self.rng.f64() < accept {
                        return t;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_peak_and_trough() {
        // Peak mid-afternoon on a weekday, trough overnight.
        let peak = diurnal_multiplier(15.0 * 3600.0);
        let trough = diurnal_multiplier(4.0 * 3600.0);
        assert!(peak > 0.95, "peak={peak}");
        assert!(trough < 0.55, "trough={trough}");
        assert!(peak / trough > 1.8);
    }

    #[test]
    fn weekend_lighter() {
        let weekday = diurnal_multiplier(15.0 * 3600.0); // day 0
        let weekend = diurnal_multiplier((5.0 * 24.0 + 15.0) * 3600.0); // day 5
        assert!(weekend < weekday);
    }

    #[test]
    fn weekly_mean_near_three_quarters() {
        let n = 7 * 24 * 12;
        let mean: f64 =
            (0..n).map(|i| diurnal_multiplier(i as f64 * 300.0)).sum::<f64>() / n as f64;
        assert!((0.65..0.80).contains(&mean), "mean={mean}");
    }

    #[test]
    fn arrivals_track_rate() {
        // Count arrivals in a flat-ish window and compare to expectation.
        let mut ap = ArrivalProcess::new(0.1, Rng::new(5));
        let start = 14.0 * 3600.0; // near peak, multiplier ~0.95-1.0
        let mut t = start;
        let mut count = 0;
        while t < start + 20_000.0 {
            t = ap.next_after(t);
            count += 1;
        }
        let expected = 0.1 * diurnal_multiplier(start + 10_000.0) * 20_000.0;
        assert!(
            (count as f64 - expected).abs() < expected * 0.15,
            "count={count} expected={expected}"
        );
    }

    #[test]
    fn phase_shift_moves_the_peak() {
        // Over 04:00-06:00 sim time (envelope ≈ 0.47) a +11 h phase sees
        // 15:00-17:00 (≈ 0.97): the shifted stream must arrive roughly
        // twice as fast. The window stays inside the trough/peak plateaus
        // so the expected ratio (~2.05) clears the 1.5 bar by > 5 sigma.
        let window = 7_200.0;
        let count_at = |phase: f64, seed: u64| {
            let mut ap = ArrivalProcess::new(0.1, Rng::new(seed)).with_phase(phase);
            let start = 4.0 * 3600.0;
            let mut t = start;
            let mut count = 0u32;
            while t < start + window {
                t = ap.next_after(t);
                count += 1;
            }
            count
        };
        let trough = count_at(0.0, 8);
        let peak = count_at(11.0 * 3600.0, 8);
        assert!(
            peak as f64 > trough as f64 * 1.5,
            "peak-phased {peak} vs trough {trough}"
        );
    }

    #[test]
    fn arrivals_strictly_increasing() {
        let mut ap = ArrivalProcess::new(0.5, Rng::new(6));
        let mut t = 0.0;
        for _ in 0..1000 {
            let nt = ap.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }

    #[test]
    fn no_drift_config_is_bit_identical_to_plain_stream() {
        // `with_drift(None, ..)` must not perturb the sampler: same
        // seed, same arrival times, to the bit.
        let mut plain = ArrivalProcess::new(0.2, Rng::new(11)).with_phase(3_600.0);
        let mut gated =
            ArrivalProcess::new(0.2, Rng::new(11)).with_phase(3_600.0).with_drift(None, 4.0);
        let mut t = 0.0;
        for _ in 0..500 {
            let a = plain.next_after(t);
            let b = gated.next_after(t);
            assert_eq!(a.to_bits(), b.to_bits());
            t = a;
        }
    }

    #[test]
    fn zero_drift_multiplier_is_one_and_bounded() {
        let d = DriftConfig::default();
        assert_eq!(d.multiplier(0.0), 1.0);
        assert_eq!(d.multiplier(10.0 * 7.0 * 86_400.0), 1.0);
        assert_eq!(d.max_multiplier(8.0), 1.0);
    }

    #[test]
    fn growth_ramp_raises_the_rate_week_over_week() {
        let drift =
            DriftConfig { growth_per_week: 0.25, season_amp: 0.0, season_period_weeks: 4.0 };
        let count_week = |week: f64| {
            let mut ap = ArrivalProcess::new(0.1, Rng::new(21)).with_drift(Some(drift.clone()), 4.0);
            // Same clock window each week (same diurnal shape), so the
            // only difference between weeks is the ramp.
            let start = week * 7.0 * 86_400.0 + 12.0 * 3_600.0;
            let mut t = start;
            let mut count = 0u32;
            while t < start + 40_000.0 {
                t = ap.next_after(t);
                count += 1;
            }
            count
        };
        let early = count_week(0.0);
        let late = count_week(3.0);
        // +25%/week compounds to 1.75x by week 3 — demand 1.4x is a
        // conservative bar well above Poisson noise at these counts.
        assert!(late as f64 > early as f64 * 1.4, "early={early} late={late}");
    }

    #[test]
    fn seasonal_modulation_peaks_at_quarter_period() {
        let d = DriftConfig { growth_per_week: 0.0, season_amp: 0.3, season_period_weeks: 4.0 };
        let quarter = 1.0 * 7.0 * 86_400.0; // sin peaks at period/4 = week 1
        let trough = 3.0 * 7.0 * 86_400.0;
        assert!((d.multiplier(quarter) - 1.3).abs() < 1e-9);
        assert!((d.multiplier(trough) - 0.7).abs() < 1e-9);
        assert!(d.max_multiplier(8.0) >= d.multiplier(quarter));
    }

    #[test]
    fn drifted_arrivals_strictly_increase_even_past_the_horizon() {
        // Past the thinning horizon accept ratios can exceed 1; the
        // sampler must still terminate and keep time monotone.
        let drift =
            DriftConfig { growth_per_week: 0.5, season_amp: 0.2, season_period_weeks: 2.0 };
        let mut ap = ArrivalProcess::new(0.5, Rng::new(31)).with_drift(Some(drift), 0.5);
        let mut t = 0.4 * 7.0 * 86_400.0;
        for _ in 0..500 {
            let nt = ap.next_after(t);
            assert!(nt > t);
            t = nt;
        }
    }
}
