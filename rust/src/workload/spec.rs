//! Table 4: the workload distribution POLCA is evaluated on. All services
//! run BLOOM-176B (the paper's worst case for capping sensitivity, §6.1)
//! on dedicated DGX-A100 servers.

use crate::cluster::hierarchy::{JobKind, Priority, Row};
use crate::util::rng::Rng;

/// One service class (a Table 4 row).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Service name (Table 4 row label).
    pub name: &'static str,
    /// Prompt size range in tokens (inclusive, log-uniform sampling).
    pub prompt_range: (u32, u32),
    /// Output size range in tokens.
    pub output_range: (u32, u32),
    /// Share of the row's servers running this service.
    pub ratio: f64,
    /// Fraction of this service's servers that are high priority.
    pub hp_fraction: f64,
}

/// The paper's Table 4.
pub fn table4() -> Vec<WorkloadSpec> {
    vec![
        WorkloadSpec {
            name: "Summarize",
            prompt_range: (2048, 8192),
            output_range: (256, 512),
            ratio: 0.25,
            hp_fraction: 0.0, // Low priority
        },
        WorkloadSpec {
            name: "Search",
            prompt_range: (512, 2048),
            output_range: (1024, 2048),
            ratio: 0.25,
            hp_fraction: 1.0, // High priority
        },
        WorkloadSpec {
            name: "Chat",
            prompt_range: (2048, 4096),
            output_range: (128, 2048),
            ratio: 0.50,
            hp_fraction: 0.5, // 50:50
        },
    ]
}

/// Sample (input_tokens, output_tokens) for a service. Log-uniform:
/// interactive token-length distributions are heavy on the short side.
pub fn sample_request(spec: &WorkloadSpec, rng: &mut Rng) -> (f64, f64) {
    let logu = |lo: u32, hi: u32, rng: &mut Rng| {
        let (l, h) = ((lo as f64).ln(), (hi as f64).ln());
        rng.range_f64(l, h).exp().round().clamp(lo as f64, hi as f64)
    };
    (
        logu(spec.prompt_range.0, spec.prompt_range.1, rng),
        logu(spec.output_range.0, spec.output_range.1, rng),
    )
}

/// The oversubscription-aware allocator (§5.B): assign every server in a
/// row a service and a priority so each rack carries a good HP/LP mix.
/// `lp_fraction_override` rescales the LP share for the Fig 15b sweep.
pub fn assign_servers(
    row: &mut Row,
    specs: &[WorkloadSpec],
    model_idx: usize,
    lp_fraction_override: Option<f64>,
    rng: &mut Rng,
) {
    let n = row.servers.len();
    // Deterministic counts per service from ratios (largest remainder).
    let mut counts: Vec<usize> = specs.iter().map(|s| (s.ratio * n as f64).floor() as usize).collect();
    let mut assigned: usize = counts.iter().sum();
    let mut i = 0;
    while assigned < n {
        counts[i % specs.len()] += 1;
        assigned += 1;
        i += 1;
    }
    // Build the assignment list, then shuffle across racks for mixing.
    let mut slots: Vec<(usize, Priority)> = Vec::with_capacity(n);
    for (w, &count) in counts.iter().enumerate() {
        let hp_frac = match lp_fraction_override {
            Some(lp) => {
                // Rescale the global LP share while keeping the service
                // structure: services become HP with prob (1 - lp).
                1.0 - lp
            }
            None => specs[w].hp_fraction,
        };
        let hp_count = (hp_frac * count as f64).round() as usize;
        for j in 0..count {
            let pri = if j < hp_count { Priority::High } else { Priority::Low };
            slots.push((w, pri));
        }
    }
    rng.shuffle(&mut slots);
    for (server, (w, pri)) in row.servers.iter_mut().zip(slots) {
        server.workload_idx = w;
        server.priority = pri;
        server.model_idx = model_idx;
    }
}

/// Convert the last `train_count` server slots of an already-assigned
/// row into training-job slices (§7 colocation). Deliberately
/// deterministic and RNG-free: the inference allocation ([`assign_servers`])
/// consumes exactly the same random stream at every training fraction,
/// so a 0%-training mixed row is bit-identical to an inference-only row
/// and sweeps interpolate on a fixed workload realization. Training
/// slots take the priority class [`JobKind::fixed_priority`] pins them
/// to (always [`Priority::Low`]).
pub fn mark_training(row: &mut Row, train_count: usize) {
    let n = row.servers.len();
    let start = n.saturating_sub(train_count);
    for server in &mut row.servers[start..] {
        server.job = JobKind::Training;
        server.priority = JobKind::Training.fixed_priority().expect("training is priority-pinned");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::server::ServerPowerModel;

    #[test]
    fn table4_matches_paper() {
        let t = table4();
        assert_eq!(t.len(), 3);
        assert!((t.iter().map(|w| w.ratio).sum::<f64>() - 1.0).abs() < 1e-12);
        let chat = &t[2];
        assert_eq!(chat.name, "Chat");
        assert_eq!(chat.prompt_range, (2048, 4096));
        assert_eq!(chat.output_range, (128, 2048));
        assert_eq!(chat.hp_fraction, 0.5);
        assert_eq!(t[0].hp_fraction, 0.0); // Summarize: Low
        assert_eq!(t[1].hp_fraction, 1.0); // Search: High
    }

    #[test]
    fn samples_stay_in_range() {
        let t = table4();
        let mut rng = Rng::new(1);
        for spec in &t {
            for _ in 0..500 {
                let (i, o) = sample_request(spec, &mut rng);
                assert!(i >= spec.prompt_range.0 as f64 && i <= spec.prompt_range.1 as f64);
                assert!(o >= spec.output_range.0 as f64 && o <= spec.output_range.1 as f64);
            }
        }
    }

    #[test]
    fn log_uniform_is_short_heavy() {
        let spec = &table4()[2]; // Chat outputs 128..2048
        let mut rng = Rng::new(2);
        let n = 20_000;
        let below_mid = (0..n)
            .filter(|_| sample_request(spec, &mut rng).1 < (128.0 + 2048.0) / 2.0)
            .count();
        assert!(below_mid as f64 / n as f64 > 0.65);
    }

    #[test]
    fn allocator_respects_ratios_and_priorities() {
        let mut row = Row::provision(40, 40, ServerPowerModel::default());
        let specs = table4();
        let mut rng = Rng::new(3);
        assign_servers(&mut row, &specs, 3, None, &mut rng);
        let count = |w: usize| row.servers.iter().filter(|s| s.workload_idx == w).count();
        assert_eq!(count(0), 10);
        assert_eq!(count(1), 10);
        assert_eq!(count(2), 20);
        // LP total = summarize 10 + half of chat 10 = 20
        assert_eq!(row.lp_servers().count(), 20);
        assert_eq!(row.hp_servers().count(), 20);
        // every Search server is HP
        assert!(row
            .servers
            .iter()
            .filter(|s| s.workload_idx == 1)
            .all(|s| s.priority == Priority::High));
    }

    #[test]
    fn mark_training_pins_low_priority_and_preserves_inference_rng() {
        let specs = table4();
        // Two rows assigned with identical seeds...
        let mut plain = Row::provision(20, 20, ServerPowerModel::default());
        let mut mixed = Row::provision(20, 20, ServerPowerModel::default());
        let mut rng_a = Rng::new(9);
        let mut rng_b = Rng::new(9);
        assign_servers(&mut plain, &specs, 0, None, &mut rng_a);
        assign_servers(&mut mixed, &specs, 0, None, &mut rng_b);
        mark_training(&mut mixed, 5);
        // ...training claims exactly the last 5 slots, all LP,
        assert_eq!(mixed.training_servers().count(), 5);
        assert!(mixed.training_servers().all(|s| s.priority == Priority::Low));
        assert!(mixed.training_servers().all(|s| s.id >= 15));
        // ...and the surviving inference slots are untouched.
        for (a, b) in plain.servers.iter().zip(&mixed.servers).take(15) {
            assert_eq!(a.workload_idx, b.workload_idx);
            assert_eq!(a.priority, b.priority);
            assert_eq!(b.job, JobKind::Inference);
        }
        // Zero training count is a no-op.
        let before: Vec<_> = plain.servers.iter().map(|s| s.priority).collect();
        mark_training(&mut plain, 0);
        assert_eq!(plain.training_servers().count(), 0);
        assert_eq!(before, plain.servers.iter().map(|s| s.priority).collect::<Vec<_>>());
    }

    #[test]
    fn lp_override_rescales() {
        let mut row = Row::provision(40, 40, ServerPowerModel::default());
        let specs = table4();
        let mut rng = Rng::new(4);
        assign_servers(&mut row, &specs, 0, Some(0.25), &mut rng);
        let lp = row.lp_servers().count();
        assert!((9..=11).contains(&lp), "lp={lp}");
    }
}
