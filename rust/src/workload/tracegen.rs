//! Production-trace replication (§6.1).
//!
//! The paper takes a six-week power trace from a production inference
//! cluster (June 21 – Aug 2, 2023) and generates a synthetic request
//! trace whose simulated power matches it within 3% MAPE. We have no
//! production trace, so we replicate the *replication*: the "production"
//! target is synthesized from the published statistics (Table 2: 79%
//! peak utilization, ≤9% 2 s spike, 11.8% 40 s spike, diurnal shape),
//! and the simulator's load is calibrated against that target
//! ([`crate::simulation::calibrate`]), closing the same loop with the
//! same fidelity metric.

use crate::util::rng::Rng;
use crate::util::stats::mape;
use crate::workload::arrivals::diurnal_multiplier;

/// The "production" target: a normalized row-power profile.
#[derive(Debug, Clone)]
pub struct TraceTarget {
    /// Sampling period, seconds.
    pub dt_s: f64,
    /// Normalized row power (fraction of provisioned budget).
    pub power: Vec<f64>,
    /// Statistics the synthesis is anchored to (Table 2 inference column).
    pub peak_util: f64,
}

/// Synthesize the six-week production-like power profile.
///
/// `floor_util` is the row power when every server idles; `peak_util`
/// the diurnal peak (Table 2: 0.79). Short-term variation (Table 2:
/// ≤9% over 2 s) comes from an AR(1) jitter plus prompt-burst shot noise.
pub fn target_power_profile(
    weeks: f64,
    dt_s: f64,
    floor_util: f64,
    peak_util: f64,
    seed: u64,
) -> TraceTarget {
    let total_s = weeks * 7.0 * 86_400.0;
    let n = (total_s / dt_s) as usize;
    let mut rng = Rng::new(seed);
    let mut power = Vec::with_capacity(n);
    // Diurnal multiplier spans [~0.40, 1.0] → map onto [floor..peak].
    let (dmin, dmax) = (0.40, 1.0);
    let mut ar = 0.0; // AR(1) short-term state
    let rho = 0.7_f64;
    let sigma = 0.013;
    for i in 0..n {
        let t = i as f64 * dt_s;
        let d = ((diurnal_multiplier(t) - dmin) / (dmax - dmin)).clamp(0.0, 1.0);
        let base = floor_util + d * (peak_util - floor_util) * 0.97;
        ar = rho * ar + rng.normal_with(0.0, sigma);
        // Occasional correlated prompt bursts (uncorrelated across
        // endpoints, so small at row level: ≤ ~2%).
        let burst = if rng.bool(0.01) { rng.range_f64(0.005, 0.02) } else { 0.0 };
        power.push((base + ar + burst).clamp(0.05, 1.0));
    }
    // Rescale so the realized peak lands exactly on the published figure
    // (Table 2: the statistic the synthesis is anchored to).
    let realized = power.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    for p in power.iter_mut() {
        *p *= peak_util / realized;
    }
    TraceTarget { dt_s, power, peak_util }
}

impl TraceTarget {
    /// Daily profile: mean power per time-of-day bucket (for MAPE
    /// comparison against a simulated run, mirroring §6.1).
    pub fn daily_profile(&self, buckets: usize) -> Vec<f64> {
        daily_profile_of(&self.power, self.dt_s, buckets)
    }

    /// Peak utilization of the synthesized profile.
    pub fn peak(&self) -> f64 {
        self.power.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// MAPE between this target's daily profile and another power series'.
    pub fn mape_daily(&self, other: &[f64], other_dt_s: f64, buckets: usize) -> f64 {
        let a = self.daily_profile(buckets);
        let b = daily_profile_of(other, other_dt_s, buckets);
        mape(&a, &b)
    }
}

/// Average a power series into `buckets` time-of-day bins.
pub fn daily_profile_of(power: &[f64], dt_s: f64, buckets: usize) -> Vec<f64> {
    let mut sums = vec![0.0; buckets];
    let mut counts = vec![0u64; buckets];
    for (i, &p) in power.iter().enumerate() {
        let tod = (i as f64 * dt_s).rem_euclid(86_400.0);
        let b = ((tod / 86_400.0) * buckets as f64) as usize % buckets;
        sums[b] += p;
        counts[b] += 1;
    }
    sums.iter().zip(&counts).map(|(&s, &c)| if c > 0 { s / c as f64 } else { 0.0 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::max_rise_within;

    fn week_target() -> TraceTarget {
        target_power_profile(1.0, 2.0, 0.42, 0.79, 11)
    }

    #[test]
    fn peak_matches_table2() {
        let t = week_target();
        let peak = t.peak();
        assert!((peak - 0.79).abs() < 1e-9, "peak={peak}");
    }

    #[test]
    fn short_term_spikes_match_table2() {
        // Table 2 inference: max 2 s spike ≈ 9%, 40 s spike ≈ 11.8%.
        let t = week_target();
        let spike_2s = max_rise_within(&t.power, 1); // dt = 2 s
        let spike_40s = max_rise_within(&t.power, 20);
        assert!((0.04..=0.12).contains(&spike_2s), "2s spike {spike_2s}");
        assert!((0.06..=0.16).contains(&spike_40s), "40s spike {spike_40s}");
        assert!(spike_40s >= spike_2s);
    }

    #[test]
    fn diurnal_shape_present() {
        let t = week_target();
        let daily = t.daily_profile(24);
        let peak_hour = daily.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let trough_hour = daily.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(peak_hour / trough_hour > 1.4, "{peak_hour} / {trough_hour}");
    }

    #[test]
    fn self_mape_is_zero_and_shifted_is_not() {
        let t = week_target();
        assert!(t.mape_daily(&t.power, t.dt_s, 48) < 1e-9);
        let shifted: Vec<f64> = t.power.iter().map(|p| p * 1.10).collect();
        let m = t.mape_daily(&shifted, t.dt_s, 48);
        assert!((9.0..11.0).contains(&m), "mape={m}");
    }

    #[test]
    fn deterministic() {
        let a = target_power_profile(0.1, 2.0, 0.4, 0.79, 3);
        let b = target_power_profile(0.1, 2.0, 0.4, 0.79, 3);
        assert_eq!(a.power, b.power);
    }
}
