//! Named scenario presets — the registry behind `polca scenario list`
//! and `polca run <preset>`. Each preset is one [`Scenario`] value
//! built through the fluent [`crate::scenario::ScenarioBuilder`]; all
//! of them round-trip bit-identically through TOML and reproduce the
//! legacy subcommand they replaced (property- and golden-tested in
//! `tests/integration_scenario.rs`).
//!
//! Adding a study = adding one entry here (or shipping a `.toml` under
//! `examples/scenarios/`) — no new subcommand, no new wiring.

use crate::policy::engine::PolicyKind;

use super::Scenario;

/// One registry row.
struct Preset {
    name: &'static str,
    description: &'static str,
    build: fn() -> Scenario,
}

/// The registry, in presentation order: rows first, then drills, then
/// sites. Descriptions double as `polca scenario list` output.
fn registry() -> Vec<Preset> {
    vec![
        Preset {
            name: "inference-row",
            description: "The paper's §6 row: 40 servers, POLCA, no oversubscription, 1 week \
                          (what `polca simulate` ran by default)",
            build: inference_row,
        },
        Preset {
            name: "oversubscribed-row",
            description: "The headline claim: the same row deployed at +30% under POLCA \
                          (Fig 13's chosen point)",
            build: oversubscribed_row,
        },
        Preset {
            name: "mixed-row",
            description: "§2.4/§7 colocation: half the deployed servers run one synchronized \
                          training job (what `polca mixed run` ran by default)",
            build: mixed_row,
        },
        Preset {
            name: "training-row",
            description: "Pure-training row under No-cap: the §2.4 coordinated-swing regime \
                          (headroom bounded by the 37.5% swing)",
            build: training_row,
        },
        Preset {
            name: "h100-row",
            description: "An HGX-H100 row at +30%: Table-3 setpoints rescaled into the H100 \
                          clock domain (fleet SKU registry)",
            build: h100_row,
        },
        Preset {
            name: "adaptive-row",
            description: "The provisioning→runtime loop closed: a +40%-racked row under the \
                          adaptive controller, demand growing 2.5%/week with a seasonal swing",
            build: adaptive_row,
        },
        Preset {
            name: "cascade-faults",
            description: "Telemetry freeze → OOB storm → feed loss cascading over one \
                          +30% row, containment escalation armed (docs/RELIABILITY.md)",
            build: cascade_faults,
        },
        Preset {
            name: "cap-ignore-drill",
            description: "Every server acks caps without applying them; only the brake path \
                          (via escalation) can contain the row",
            build: cap_ignore_drill,
        },
        Preset {
            name: "feed-loss-drill",
            description: "A redundancy event cuts the row budget to 75% mid-run; the brake \
                          must answer before the UPS tolerance window",
            build: feed_loss_drill,
        },
        Preset {
            name: "site-headroom",
            description: "Plan a 4-cluster heterogeneous site: max deployable servers under \
                          the shared substation budget (fleet planner)",
            build: site_headroom,
        },
        Preset {
            name: "site-derated",
            description: "The same site plan derated for a feed-loss fault: how many servers \
                          must be given back to keep containment",
            build: site_derated,
        },
        Preset {
            name: "region-headroom",
            description: "Plan an 8-site region under one shared grid budget via the \
                          compositional trace algebra (no per-site simulation per candidate)",
            build: region_headroom,
        },
    ]
}

fn inference_row() -> Scenario {
    Scenario::builder("inference-row")
        .description("Paper §6 row: 40 DGX-A100 servers, POLCA, 1 week")
        .policy(PolicyKind::Polca)
        .build()
}

fn oversubscribed_row() -> Scenario {
    Scenario::builder("oversubscribed-row")
        .description("Paper headline: +30% servers on the same budget under POLCA")
        .policy(PolicyKind::Polca)
        .added(0.30)
        .build()
}

fn mixed_row() -> Scenario {
    Scenario::builder("mixed-row")
        .description("50% training colocation under POLCA (§2.4/§7)")
        .policy(PolicyKind::Polca)
        .weeks(0.25)
        .seed(1)
        .training(0.5)
        .build()
}

fn training_row() -> Scenario {
    Scenario::builder("training-row")
        .description("Pure-training row, uncapped: the §2.4 swing regime")
        .policy(PolicyKind::NoCap)
        .weeks(0.25)
        .seed(1)
        .training(1.0)
        .build()
}

fn h100_row() -> Scenario {
    Scenario::builder("h100-row")
        .description("HGX-H100 row at +30%: SKU-rescaled policy setpoints")
        .policy(PolicyKind::Polca)
        .added(0.30)
        .weeks(0.25)
        .seed(1)
        .sku("hgx-h100")
        .build()
}

fn adaptive_row() -> Scenario {
    Scenario::builder("adaptive-row")
        .description("Adaptive oversubscription under demand growth (§5.1/§6.2 online)")
        .policy(PolicyKind::Polca)
        .servers(16)
        .added(0.40)
        .weeks(2.0)
        .seed(1)
        .adaptive(21_600.0)
        .adapt_levels(0.0, 0.10, 0.40)
        .adapt_pacing(2, 3)
        .drift(0.025, 0.15, 4.0)
        .build()
}

/// The fault drills share the fault-matrix row shape (16 servers at
/// +30%, 0.1 weeks, escalation armed) so their numbers line up with
/// the `fault-matrix` experiment grid.
fn fault_drill(name: &str, description: &str, scenario: &str) -> Scenario {
    Scenario::builder(name)
        .description(description)
        .policy(PolicyKind::Polca)
        .servers(16)
        .added(0.30)
        .weeks(0.1)
        .seed(1)
        .faults_scenario(scenario)
        .escalate(120.0)
        .build()
}

fn cascade_faults() -> Scenario {
    fault_drill(
        "cascade-faults",
        "Cascading telemetry freeze, OOB storm, feed loss on a +30% row",
        "cascade",
    )
}

fn cap_ignore_drill() -> Scenario {
    fault_drill(
        "cap-ignore-drill",
        "Cap-ignoring servers: only the brake (via escalation) contains",
        "cap-ignore",
    )
}

fn feed_loss_drill() -> Scenario {
    fault_drill("feed-loss-drill", "Feed loss cuts the budget to 75% mid-run", "feed-loss")
}

fn site_headroom() -> Scenario {
    Scenario::builder("site-headroom")
        .description("Max deployable servers for a 4-cluster site under POLCA")
        .policy(PolicyKind::Polca)
        .weeks(0.08)
        .seed(1)
        .site(4)
        .site_search(50, 5)
        .build()
}

fn site_derated() -> Scenario {
    Scenario::builder("site-derated")
        .description("The site plan derated for a feed-loss fault timeline")
        .policy(PolicyKind::Polca)
        .weeks(0.08)
        .seed(1)
        .site(4)
        .site_search(50, 10)
        .faults_scenario("feed-loss")
        .escalate(120.0)
        .build()
}

fn region_headroom() -> Scenario {
    Scenario::builder("region-headroom")
        .description("Max deployable servers across an 8-site region under one grid budget")
        .policy(PolicyKind::Polca)
        .weeks(1.0 / 7.0)
        .seed(1)
        .region(8)
        .region_clusters(3)
        .region_grid(0.85)
        .region_search(50, 5)
        .build()
}

/// Preset names, in presentation order.
pub fn preset_names() -> Vec<&'static str> {
    registry().iter().map(|p| p.name).collect()
}

/// One-line description of a preset (for `polca scenario list`).
pub fn preset_description(name: &str) -> Option<&'static str> {
    registry().iter().find(|p| p.name == name).map(|p| p.description)
}

/// Build a preset by name.
pub fn preset(name: &str) -> anyhow::Result<Scenario> {
    registry()
        .iter()
        .find(|p| p.name == name)
        .map(|p| (p.build)())
        .ok_or_else(|| {
            anyhow::anyhow!("unknown preset '{name}' (known: {})", preset_names().join(", "))
        })
}

/// Every preset, built, in presentation order.
pub fn presets() -> Vec<Scenario> {
    registry().iter().map(|p| (p.build)()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_unique_named_and_valid() {
        let names = preset_names();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate preset names");
        for sc in presets() {
            assert!(names.contains(&sc.name.as_str()), "preset name '{}' not its key", sc.name);
            assert!(!sc.description.is_empty(), "{}", sc.name);
            sc.validate().unwrap_or_else(|e| panic!("preset '{}': {e:#}", sc.name));
            assert!(preset_description(&sc.name).is_some());
        }
        assert!(preset("nope").is_err());
    }

    #[test]
    fn issue_named_presets_exist() {
        for name in ["inference-row", "mixed-row", "cascade-faults", "site-headroom"] {
            assert!(preset(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn preset_families_dispatch_as_expected() {
        use crate::scenario::FaultSpec;
        assert!(preset("inference-row").unwrap().site.is_none());
        assert!(preset("site-headroom").unwrap().site.is_some());
        let region = preset("region-headroom").unwrap();
        assert!(region.site.is_none() && region.region.is_some());
        assert!(matches!(preset("cascade-faults").unwrap().faults, FaultSpec::Named(_)));
        assert_eq!(preset("training-row").unwrap().training.fraction, 1.0);
        assert_eq!(preset("h100-row").unwrap().sku.as_deref(), Some("hgx-h100"));
        let adaptive = preset("adaptive-row").unwrap();
        assert!(adaptive.adapt.is_some() && adaptive.drift.is_some());
        // The controller's ceiling must fit inside what is racked.
        assert!(adaptive.adapt.unwrap().max_added <= adaptive.added_frac);
    }
}
