//! Fluent construction of [`Scenario`] values — the programmatic front
//! door the CLI aliases and every experiment generator use, so run
//! wiring reads as *what* is being studied instead of field-by-field
//! `SimConfig` assembly.
//!
//! ```
//! use polca::policy::engine::PolicyKind;
//! use polca::scenario::Scenario;
//!
//! let sc = Scenario::builder("demo")
//!     .description("one oversubscribed mixed row under a fault drill")
//!     .policy(PolicyKind::Polca)
//!     .servers(16)
//!     .added(0.30)
//!     .weeks(0.1)
//!     .seed(3)
//!     .training(0.25)
//!     .faults_scenario("cap-ignore")
//!     .escalate(120.0)
//!     .build();
//! assert!(sc.validate().is_ok());
//! assert_eq!(sc.deployed_servers(), 21);
//! ```

use crate::config::{ExperimentConfig, PolicyConfig};
use crate::faults::FaultPlan;
use crate::policy::engine::PolicyKind;

use super::{FaultSpec, Scenario};

/// Fluent [`Scenario`] builder (see [`Scenario::builder`]).
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    sc: Scenario,
}

impl ScenarioBuilder {
    /// A builder over the default scenario (the paper's 40-server row,
    /// POLCA, one week, no oversubscription).
    pub fn new(name: &str) -> Self {
        ScenarioBuilder { sc: Scenario { name: name.to_string(), ..Default::default() } }
    }

    /// Set the one-line description.
    pub fn description(mut self, d: &str) -> Self {
        self.sc.description = d.to_string();
        self
    }

    /// Replace the whole experiment config (row latencies, policy
    /// knobs, SLOs, seed) — e.g. one loaded from a `--config` file.
    pub fn experiment(mut self, exp: ExperimentConfig) -> Self {
        self.sc.exp = exp;
        self
    }

    /// Set the driving policy.
    pub fn policy(mut self, kind: PolicyKind) -> Self {
        self.sc.policy_kind = kind;
        self
    }

    /// Set the baseline (budget) server count.
    pub fn servers(mut self, n: usize) -> Self {
        self.sc.exp.row.num_servers = n;
        self
    }

    /// Set the added-server fraction (oversubscription).
    pub fn added(mut self, frac: f64) -> Self {
        self.sc.added_frac = frac;
        self
    }

    /// Set the simulated horizon in weeks.
    pub fn weeks(mut self, w: f64) -> Self {
        self.sc.weeks = w;
        self
    }

    /// Set the root seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.sc.exp.seed = s;
        self
    }

    /// Set the catalog model.
    pub fn model(mut self, name: &str) -> Self {
        self.sc.model_name = name.to_string();
        self
    }

    /// Pin a server SKU by registry name (row scenarios only).
    pub fn sku(mut self, name: &str) -> Self {
        self.sc.sku = Some(name.to_string());
        self
    }

    /// Override the row-power calibration (rarely needed; the default
    /// follows the row size).
    pub fn power_scale(mut self, scale: f64) -> Self {
        self.sc.power_scale = Some(scale);
        self
    }

    /// Set the diurnal-peak target utilization.
    pub fn peak_utilization(mut self, u: f64) -> Self {
        self.sc.peak_utilization = u;
        self
    }

    /// Set the Fig-17 workload power multiplier.
    pub fn power_mult(mut self, m: f64) -> Self {
        self.sc.workload_power_mult = m;
        self
    }

    /// Override the low-priority workload share (Fig 15b).
    pub fn lp_fraction(mut self, frac: f64) -> Self {
        self.sc.lp_fraction_override = Some(frac);
        self
    }

    /// Set the POLCA thresholds (fractions of the row budget).
    pub fn thresholds(mut self, t1: f64, t2: f64) -> Self {
        self.sc.exp.policy.t1 = t1;
        self.sc.exp.policy.t2 = t2;
        self
    }

    /// Tune any other Table-3 policy knob in place.
    pub fn policy_config(mut self, f: impl FnOnce(&mut PolicyConfig)) -> Self {
        f(&mut self.sc.exp.policy);
        self
    }

    /// Colocate this fraction of deployed servers as training.
    pub fn training(mut self, fraction: f64) -> Self {
        self.sc.training.fraction = fraction;
        self
    }

    /// Set the training job granularity and start stagger.
    pub fn training_jobs(mut self, servers_per_job: usize, stagger_s: f64) -> Self {
        self.sc.training.servers_per_job = servers_per_job;
        self.sc.training.stagger_s = stagger_s;
        self
    }

    /// Inject a named fault scenario (resolved against the horizon).
    pub fn faults_scenario(mut self, name: &str) -> Self {
        self.sc.faults = FaultSpec::Named(name.to_string());
        self
    }

    /// Inject an explicit fault timeline.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.sc.faults = FaultSpec::Plan(plan);
        self
    }

    /// Enable the policy engine's containment escalation.
    pub fn escalate(mut self, after_s: f64) -> Self {
        self.sc.brake_escalation_s = Some(after_s);
        self
    }

    /// Enable the adaptive oversubscription controller
    /// ([`crate::policy::adapt`]) with this retune window. Composes
    /// with [`Self::adapt_levels`] / [`Self::adapt_pacing`] in any
    /// order.
    pub fn adaptive(mut self, window_s: f64) -> Self {
        let mut a = self.sc.adapt.take().unwrap_or_default();
        a.window_s = window_s;
        self.sc.adapt = Some(a);
        self
    }

    /// Set the controller's added-level range (floor / starting point /
    /// ceiling, as fractions of the baseline row).
    pub fn adapt_levels(mut self, min: f64, initial: f64, max: f64) -> Self {
        let mut a = self.sc.adapt.take().unwrap_or_default();
        a.min_added = min;
        a.initial_added = initial;
        a.max_added = max;
        self.sc.adapt = Some(a);
        self
    }

    /// Set the controller's hysteresis (calm windows required before a
    /// raise) and safety clamp (windows after a violation during which
    /// raises are vetoed).
    pub fn adapt_pacing(mut self, hold_windows: u32, cooldown_windows: u32) -> Self {
        let mut a = self.sc.adapt.take().unwrap_or_default();
        a.hold_windows = hold_windows;
        a.cooldown_windows = cooldown_windows;
        self.sc.adapt = Some(a);
        self
    }

    /// Apply long-horizon demand drift to every arrival stream: a
    /// linear growth ramp per week plus a sinusoidal seasonal
    /// modulation with the given period.
    pub fn drift(mut self, growth_per_week: f64, season_amp: f64, period_weeks: f64) -> Self {
        self.sc.drift = Some(crate::workload::arrivals::DriftConfig {
            growth_per_week,
            season_amp,
            season_period_weeks: period_weeks,
        });
        self
    }

    /// Make this a site scenario over the demo topology of `clusters`
    /// clusters (dispatches to the fleet planner).
    pub fn site(mut self, clusters: usize) -> Self {
        let mut s = self.sc.site.take().unwrap_or_default();
        s.clusters = clusters;
        self.sc.site = Some(s);
        self
    }

    /// Set the site planner's search ceiling and resolution (percent).
    pub fn site_search(mut self, max_added_pct: u32, step_pct: u32) -> Self {
        let mut s = self.sc.site.take().unwrap_or_default();
        s.max_added_pct = max_added_pct;
        s.step_pct = step_pct;
        self.sc.site = Some(s);
        self
    }

    /// Make this a region scenario over the demo topology of `sites`
    /// sites (dispatches to the fleet region planner).
    pub fn region(mut self, sites: usize) -> Self {
        let mut r = self.sc.region.take().unwrap_or_default();
        r.sites = sites;
        self.sc.region = Some(r);
        self
    }

    /// Set the clusters-per-site shape of the demo region.
    pub fn region_clusters(mut self, clusters_per_site: usize) -> Self {
        let mut r = self.sc.region.take().unwrap_or_default();
        r.clusters_per_site = clusters_per_site;
        self.sc.region = Some(r);
        self
    }

    /// Set the shared grid budget as a fraction of the substation sum.
    pub fn region_grid(mut self, budget_frac: f64) -> Self {
        let mut r = self.sc.region.take().unwrap_or_default();
        r.grid_budget_frac = budget_frac;
        self.sc.region = Some(r);
        self
    }

    /// Set the region planner's search ceiling and resolution (percent).
    pub fn region_search(mut self, max_added_pct: u32, step_pct: u32) -> Self {
        let mut r = self.sc.region.take().unwrap_or_default();
        r.max_added_pct = max_added_pct;
        r.step_pct = step_pct;
        self.sc.region = Some(r);
        self
    }

    /// Run serially (reference path; default is parallel). Targets the
    /// region section when one exists, the site section otherwise — so
    /// call it after [`Self::region`] in region scenarios.
    pub fn serial(mut self) -> Self {
        if let Some(r) = self.sc.region.as_mut() {
            r.parallel = false;
        } else {
            let mut s = self.sc.site.take().unwrap_or_default();
            s.parallel = false;
            self.sc.site = Some(s);
        }
        self
    }

    /// Finish: the assembled [`Scenario`] (call
    /// [`Scenario::validate`] to check it for contradictions).
    pub fn build(self) -> Scenario {
        self.sc
    }
}

#[cfg(test)]
mod tests {
    use super::super::SiteSection;
    use super::*;

    #[test]
    fn builder_touches_every_section() {
        let plan = FaultPlan::new();
        let sc = Scenario::builder("full")
            .description("d")
            .policy(PolicyKind::NoCap)
            .servers(12)
            .added(0.5)
            .weeks(0.05)
            .seed(9)
            .model("BLOOM-176B")
            .power_scale(1.35)
            .peak_utilization(0.8)
            .power_mult(1.05)
            .lp_fraction(0.4)
            .thresholds(0.7, 0.9)
            .policy_config(|p| p.lp_freq_t1_mhz = 1200.0)
            .training(0.5)
            .training_jobs(3, 2.0)
            .faults(plan.clone())
            .escalate(60.0)
            .build();
        assert_eq!(sc.name, "full");
        assert_eq!(sc.policy_kind, PolicyKind::NoCap);
        assert_eq!(sc.servers(), 12);
        assert_eq!(sc.deployed_servers(), 18);
        assert_eq!(sc.exp.seed, 9);
        assert_eq!(sc.power_scale, Some(1.35));
        assert_eq!(sc.lp_fraction_override, Some(0.4));
        assert_eq!((sc.exp.policy.t1, sc.exp.policy.t2), (0.7, 0.9));
        assert_eq!(sc.exp.policy.lp_freq_t1_mhz, 1200.0);
        assert_eq!(sc.training.servers_per_job, 3);
        assert_eq!(sc.faults, FaultSpec::Plan(plan));
        assert_eq!(sc.brake_escalation_s, Some(60.0));
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn adapt_setters_compose_without_clobbering() {
        let sc = Scenario::builder("a")
            .added(0.40)
            .adapt_levels(0.0, 0.10, 0.40)
            .adaptive(1800.0)
            .adapt_pacing(3, 4)
            .drift(0.05, 0.2, 4.0)
            .build();
        let a = sc.adapt.expect("adaptive() must create the section");
        assert_eq!(a.window_s, 1800.0);
        assert_eq!((a.min_added, a.initial_added, a.max_added), (0.0, 0.10, 0.40));
        assert_eq!((a.hold_windows, a.cooldown_windows), (3, 4));
        let dr = sc.drift.unwrap();
        assert_eq!(
            (dr.growth_per_week, dr.season_amp, dr.season_period_weeks),
            (0.05, 0.2, 4.0)
        );
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn site_setters_compose_without_clobbering() {
        let sc = Scenario::builder("s").site(6).site_search(40, 5).serial().build();
        let site = sc.site.unwrap();
        assert_eq!(site.clusters, 6);
        assert_eq!(site.max_added_pct, 40);
        assert_eq!(site.step_pct, 5);
        assert!(!site.parallel);
        // Order must not matter either.
        let sc2 = Scenario::builder("s").serial().site_search(40, 5).site(6).build();
        assert_eq!(sc2.site.unwrap(), SiteSection {
            clusters: 6,
            max_added_pct: 40,
            step_pct: 5,
            parallel: false,
            ..Default::default()
        });
    }

    #[test]
    fn region_setters_compose_and_serial_targets_the_region() {
        let sc = Scenario::builder("r")
            .region(10)
            .region_clusters(2)
            .region_grid(0.8)
            .region_search(40, 10)
            .serial()
            .build();
        assert!(sc.site.is_none(), "region setters must not create a site section");
        let r = sc.region.unwrap();
        assert_eq!((r.sites, r.clusters_per_site), (10, 2));
        assert_eq!(r.grid_budget_frac, 0.8);
        assert_eq!((r.max_added_pct, r.step_pct), (40, 10));
        assert!(!r.parallel);
    }
}
