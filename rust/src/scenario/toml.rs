//! Lossless TOML (de)serialization for [`Scenario`] — the format behind
//! `polca run <file.toml>`, `polca scenario show|save`, and the
//! `examples/scenarios/` directory.
//!
//! The codec targets the in-tree TOML subset ([`crate::config::Toml`])
//! and is *bit-lossless*: for every scenario `s`,
//! `Scenario::from_toml(&s.to_toml()) == s` exactly (floats included —
//! the renderer emits shortest round-trippable decimals). This is
//! property-tested over every built-in preset and over randomly
//! generated scenarios in `tests/integration_scenario.rs`.
//!
//! Schema (all keys optional on input; defaults fill the gaps):
//!
//! ```toml
//! name = "cascade-faults"
//! description = "..."
//! seed = 1
//!
//! [workload]   # weeks, model, peak_utilization, power_mult, lp_fraction
//! [row]        # num_servers, added, telemetry/brake/OOB latencies, sku, power_scale
//! [policy]     # kind ("polca"|"1t-lp"|"1t-all"|"nocap"), Table-3 knobs, escalate_s
//! [slo]        # Table-5 bounds
//! [training]   # fraction, servers_per_job, stagger_s
//! [faults]     # scenario = "name"  OR  events = [["feed-loss", start, dur, frac], ...]
//! [adapt]      # window_s, hold/cooldown windows, raise_margin, level_step, min/initial/max added
//! [drift]      # growth_per_week, season_amp, season_period_weeks
//! [site]       # clusters, max_added_pct, step_pct, parallel, sample_s, containment bounds
//! [region]     # sites, clusters_per_site, grid_budget_frac, search knobs, validate_sites
//! ```

use anyhow::Context;

use crate::config::{ExperimentConfig, Toml, TomlValue};
use crate::faults::{ContainmentSlo, FaultEvent, FaultKind, FaultPlan};
use crate::policy::engine::PolicyKind;

use super::{FaultSpec, RegionSection, Scenario, SiteSection, TrainingMix};

impl Scenario {
    /// Serialize to a TOML document (every field written, so the
    /// document is self-contained).
    pub fn to_toml(&self) -> Toml {
        let mut doc = Toml::default();
        doc.set("", "name", TomlValue::Str(self.name.clone()));
        doc.set("", "description", TomlValue::Str(self.description.clone()));
        doc.set("", "seed", TomlValue::Int(self.exp.seed as i64));

        doc.set("workload", "weeks", TomlValue::Float(self.weeks));
        doc.set("workload", "model", TomlValue::Str(self.model_name.clone()));
        doc.set("workload", "peak_utilization", TomlValue::Float(self.peak_utilization));
        doc.set("workload", "power_mult", TomlValue::Float(self.workload_power_mult));
        if let Some(lp) = self.lp_fraction_override {
            doc.set("workload", "lp_fraction", TomlValue::Float(lp));
        }

        let r = &self.exp.row;
        doc.set("row", "num_servers", TomlValue::Int(r.num_servers as i64));
        doc.set("row", "added", TomlValue::Float(self.added_frac));
        doc.set("row", "telemetry_delay_s", TomlValue::Float(r.telemetry_delay_s));
        doc.set("row", "power_brake_latency_s", TomlValue::Float(r.power_brake_latency_s));
        doc.set("row", "oob_latency_s", TomlValue::Float(r.oob_latency_s));
        doc.set("row", "telemetry_period_s", TomlValue::Float(r.telemetry_period_s));
        if let Some(sku) = &self.sku {
            doc.set("row", "sku", TomlValue::Str(sku.clone()));
        }
        if let Some(scale) = self.power_scale {
            doc.set("row", "power_scale", TomlValue::Float(scale));
        }

        let p = &self.exp.policy;
        doc.set("policy", "kind", TomlValue::Str(self.policy_kind.slug().to_string()));
        doc.set("policy", "t1", TomlValue::Float(p.t1));
        doc.set("policy", "t2", TomlValue::Float(p.t2));
        doc.set("policy", "t1_buffer", TomlValue::Float(p.t1_buffer));
        doc.set("policy", "t2_buffer", TomlValue::Float(p.t2_buffer));
        doc.set("policy", "lp_freq_t1_mhz", TomlValue::Float(p.lp_freq_t1_mhz));
        doc.set("policy", "lp_freq_t2_mhz", TomlValue::Float(p.lp_freq_t2_mhz));
        doc.set("policy", "hp_freq_t2_mhz", TomlValue::Float(p.hp_freq_t2_mhz));
        doc.set("policy", "brake_freq_mhz", TomlValue::Float(p.brake_freq_mhz));
        doc.set("policy", "max_freq_mhz", TomlValue::Float(p.max_freq_mhz));
        if let Some(esc) = self.brake_escalation_s {
            doc.set("policy", "escalate_s", TomlValue::Float(esc));
        }

        let s = &self.exp.slo;
        doc.set("slo", "hp_p50_impact", TomlValue::Float(s.hp_p50_impact));
        doc.set("slo", "hp_p99_impact", TomlValue::Float(s.hp_p99_impact));
        doc.set("slo", "lp_p50_impact", TomlValue::Float(s.lp_p50_impact));
        doc.set("slo", "lp_p99_impact", TomlValue::Float(s.lp_p99_impact));
        doc.set("slo", "max_powerbrakes", TomlValue::Int(s.max_powerbrakes as i64));

        doc.set("training", "fraction", TomlValue::Float(self.training.fraction));
        doc.set(
            "training",
            "servers_per_job",
            TomlValue::Int(self.training.servers_per_job as i64),
        );
        doc.set("training", "stagger_s", TomlValue::Float(self.training.stagger_s));

        match &self.faults {
            FaultSpec::None => {}
            FaultSpec::Named(name) => {
                doc.set("faults", "scenario", TomlValue::Str(name.clone()));
            }
            FaultSpec::Plan(plan) => {
                let items: Vec<TomlValue> = plan.events.iter().map(event_to_toml).collect();
                doc.set("faults", "events", TomlValue::Arr(items));
            }
        }

        if let Some(a) = &self.adapt {
            doc.set("adapt", "window_s", TomlValue::Float(a.window_s));
            doc.set("adapt", "hold_windows", TomlValue::Int(a.hold_windows as i64));
            doc.set("adapt", "cooldown_windows", TomlValue::Int(a.cooldown_windows as i64));
            doc.set("adapt", "raise_margin", TomlValue::Float(a.raise_margin));
            doc.set("adapt", "level_step", TomlValue::Float(a.level_step));
            doc.set("adapt", "min_added", TomlValue::Float(a.min_added));
            doc.set("adapt", "max_added", TomlValue::Float(a.max_added));
            doc.set("adapt", "initial_added", TomlValue::Float(a.initial_added));
        }

        if let Some(dr) = &self.drift {
            doc.set("drift", "growth_per_week", TomlValue::Float(dr.growth_per_week));
            doc.set("drift", "season_amp", TomlValue::Float(dr.season_amp));
            doc.set("drift", "season_period_weeks", TomlValue::Float(dr.season_period_weeks));
        }

        if let Some(site) = &self.site {
            doc.set("site", "clusters", TomlValue::Int(site.clusters as i64));
            doc.set("site", "max_added_pct", TomlValue::Int(site.max_added_pct as i64));
            doc.set("site", "step_pct", TomlValue::Int(site.step_pct as i64));
            doc.set("site", "parallel", TomlValue::Bool(site.parallel));
            doc.set("site", "sample_s", TomlValue::Float(site.sample_s));
            let c = &site.containment;
            doc.set("site", "max_violation_s", TomlValue::Float(c.max_violation_s));
            doc.set("site", "max_time_to_contain_s", TomlValue::Float(c.max_time_to_contain_s));
            doc.set("site", "max_overshoot_frac", TomlValue::Float(c.max_overshoot_frac));
        }

        if let Some(region) = &self.region {
            doc.set("region", "sites", TomlValue::Int(region.sites as i64));
            doc.set(
                "region",
                "clusters_per_site",
                TomlValue::Int(region.clusters_per_site as i64),
            );
            doc.set("region", "grid_budget_frac", TomlValue::Float(region.grid_budget_frac));
            doc.set("region", "max_added_pct", TomlValue::Int(region.max_added_pct as i64));
            doc.set("region", "step_pct", TomlValue::Int(region.step_pct as i64));
            doc.set("region", "parallel", TomlValue::Bool(region.parallel));
            doc.set("region", "sample_s", TomlValue::Float(region.sample_s));
            doc.set("region", "validate_sites", TomlValue::Int(region.validate_sites as i64));
        }
        doc
    }

    /// Deserialize from a TOML document. Missing keys take the default
    /// `Scenario` values, so sparse hand-written files work; documents
    /// produced by [`Scenario::to_toml`] reconstruct exactly.
    pub fn from_toml(doc: &Toml) -> anyhow::Result<Scenario> {
        let d = Scenario::default();
        let exp = ExperimentConfig::from_toml(doc);
        let kind_slug = doc.str_or("policy", "kind", d.policy_kind.slug());
        let policy_kind = PolicyKind::from_slug(kind_slug)
            .with_context(|| format!("unknown policy kind '{kind_slug}'"))?;
        let faults = if let Some(v) = doc.get("faults", "scenario") {
            let name = v.as_str().context("[faults] scenario must be a string")?;
            FaultSpec::Named(name.to_string())
        } else if let Some(v) = doc.get("faults", "events") {
            let TomlValue::Arr(items) = v else {
                anyhow::bail!("[faults] events must be an array of event arrays");
            };
            let events = items
                .iter()
                .enumerate()
                .map(|(i, item)| {
                    event_from_toml(item).with_context(|| format!("fault event #{}", i + 1))
                })
                .collect::<anyhow::Result<Vec<FaultEvent>>>()?;
            FaultSpec::Plan(FaultPlan { events })
        } else {
            FaultSpec::None
        };
        let adapt = if doc.sections.contains_key("adapt") {
            let da = crate::policy::adapt::AdaptConfig::default();
            Some(crate::policy::adapt::AdaptConfig {
                window_s: doc.f64_or("adapt", "window_s", da.window_s),
                hold_windows: doc.usize_or("adapt", "hold_windows", da.hold_windows as usize)
                    as u32,
                cooldown_windows: doc
                    .usize_or("adapt", "cooldown_windows", da.cooldown_windows as usize)
                    as u32,
                raise_margin: doc.f64_or("adapt", "raise_margin", da.raise_margin),
                level_step: doc.f64_or("adapt", "level_step", da.level_step),
                min_added: doc.f64_or("adapt", "min_added", da.min_added),
                max_added: doc.f64_or("adapt", "max_added", da.max_added),
                initial_added: doc.f64_or("adapt", "initial_added", da.initial_added),
            })
        } else {
            None
        };
        let drift = if doc.sections.contains_key("drift") {
            let dd = crate::workload::arrivals::DriftConfig::default();
            Some(crate::workload::arrivals::DriftConfig {
                growth_per_week: doc.f64_or("drift", "growth_per_week", dd.growth_per_week),
                season_amp: doc.f64_or("drift", "season_amp", dd.season_amp),
                season_period_weeks: doc.f64_or(
                    "drift",
                    "season_period_weeks",
                    dd.season_period_weeks,
                ),
            })
        } else {
            None
        };
        let site = if doc.sections.contains_key("site") {
            let ds = SiteSection::default();
            let dc = ContainmentSlo::default();
            Some(SiteSection {
                clusters: doc.usize_or("site", "clusters", ds.clusters),
                max_added_pct: doc.usize_or("site", "max_added_pct", ds.max_added_pct as usize)
                    as u32,
                step_pct: doc.usize_or("site", "step_pct", ds.step_pct as usize) as u32,
                parallel: doc.bool_or("site", "parallel", ds.parallel),
                sample_s: doc.f64_or("site", "sample_s", ds.sample_s),
                containment: ContainmentSlo {
                    max_violation_s: doc.f64_or("site", "max_violation_s", dc.max_violation_s),
                    max_time_to_contain_s: doc.f64_or(
                        "site",
                        "max_time_to_contain_s",
                        dc.max_time_to_contain_s,
                    ),
                    max_overshoot_frac: doc.f64_or(
                        "site",
                        "max_overshoot_frac",
                        dc.max_overshoot_frac,
                    ),
                },
            })
        } else {
            None
        };
        let region = if doc.sections.contains_key("region") {
            let dr = RegionSection::default();
            Some(RegionSection {
                sites: doc.usize_or("region", "sites", dr.sites),
                clusters_per_site: doc.usize_or(
                    "region",
                    "clusters_per_site",
                    dr.clusters_per_site,
                ),
                grid_budget_frac: doc.f64_or("region", "grid_budget_frac", dr.grid_budget_frac),
                max_added_pct: doc.usize_or("region", "max_added_pct", dr.max_added_pct as usize)
                    as u32,
                step_pct: doc.usize_or("region", "step_pct", dr.step_pct as usize) as u32,
                parallel: doc.bool_or("region", "parallel", dr.parallel),
                sample_s: doc.f64_or("region", "sample_s", dr.sample_s),
                validate_sites: doc.usize_or("region", "validate_sites", dr.validate_sites),
            })
        } else {
            None
        };
        Ok(Scenario {
            name: doc.str_or("", "name", &d.name).to_string(),
            description: doc.str_or("", "description", &d.description).to_string(),
            exp,
            policy_kind,
            added_frac: doc.f64_or("row", "added", d.added_frac),
            weeks: doc.f64_or("workload", "weeks", d.weeks),
            model_name: doc.str_or("workload", "model", &d.model_name).to_string(),
            peak_utilization: doc.f64_or("workload", "peak_utilization", d.peak_utilization),
            workload_power_mult: doc.f64_or("workload", "power_mult", d.workload_power_mult),
            lp_fraction_override: doc.get("workload", "lp_fraction").and_then(|v| v.as_f64()),
            power_scale: doc.get("row", "power_scale").and_then(|v| v.as_f64()),
            sku: doc.get("row", "sku").and_then(|v| v.as_str()).map(str::to_string),
            training: TrainingMix {
                fraction: doc.f64_or("training", "fraction", d.training.fraction),
                servers_per_job: doc.usize_or(
                    "training",
                    "servers_per_job",
                    d.training.servers_per_job,
                ),
                stagger_s: doc.f64_or("training", "stagger_s", d.training.stagger_s),
            },
            faults,
            brake_escalation_s: doc.get("policy", "escalate_s").and_then(|v| v.as_f64()),
            adapt,
            drift,
            site,
            region,
        })
    }

    /// The scenario rendered as a TOML string (what `polca scenario
    /// show|save` emit).
    pub fn to_toml_string(&self) -> String {
        format!(
            "# polca scenario '{}'\n# run with: polca run <this-file>\n{}",
            self.name,
            self.to_toml().render()
        )
    }

    /// Parse a scenario from TOML text.
    pub fn parse(text: &str) -> anyhow::Result<Scenario> {
        Scenario::from_toml(&Toml::parse(text)?)
    }

    /// Load a scenario file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        Scenario::parse(&text).with_context(|| format!("parsing scenario {}", path.display()))
    }

    /// Write the scenario to a file.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_toml_string())
            .with_context(|| format!("writing scenario {}", path.display()))
    }
}

fn event_to_toml(e: &FaultEvent) -> TomlValue {
    let mut items = vec![
        TomlValue::Str(e.kind.label().to_string()),
        TomlValue::Float(e.start_s),
        TomlValue::Float(e.duration_s),
    ];
    match e.kind {
        FaultKind::TelemetryFreeze => {}
        FaultKind::OobStorm { loss_prob, latency_mult, jitter_frac } => {
            items.push(TomlValue::Float(loss_prob));
            items.push(TomlValue::Float(latency_mult));
            items.push(TomlValue::Float(jitter_frac));
        }
        FaultKind::CapIgnore { server_frac } => items.push(TomlValue::Float(server_frac)),
        FaultKind::MeterBias { mult } => items.push(TomlValue::Float(mult)),
        FaultKind::FeedLoss { budget_frac } => items.push(TomlValue::Float(budget_frac)),
    }
    TomlValue::Arr(items)
}

fn event_from_toml(v: &TomlValue) -> anyhow::Result<FaultEvent> {
    let TomlValue::Arr(items) = v else {
        anyhow::bail!("expected [\"kind\", start_s, duration_s, params...]");
    };
    let label = items.first().and_then(|v| v.as_str()).context("missing kind label")?;
    let num = |i: usize, what: &str| -> anyhow::Result<f64> {
        items
            .get(i)
            .and_then(|v| v.as_f64())
            .with_context(|| format!("'{label}' needs numeric {what} at position {}", i + 1))
    };
    let start_s = num(1, "start_s")?;
    let duration_s = num(2, "duration_s")?;
    let kind = match label {
        "telemetry-freeze" => FaultKind::TelemetryFreeze,
        "oob-storm" => FaultKind::OobStorm {
            loss_prob: num(3, "loss_prob")?,
            latency_mult: num(4, "latency_mult")?,
            jitter_frac: num(5, "jitter_frac")?,
        },
        "cap-ignore" => FaultKind::CapIgnore { server_frac: num(3, "server_frac")? },
        "meter-bias" => FaultKind::MeterBias { mult: num(3, "mult")? },
        "feed-loss" => FaultKind::FeedLoss { budget_frac: num(3, "budget_frac")? },
        other => anyhow::bail!(
            "unknown fault kind '{other}' (known: telemetry-freeze, oob-storm, cap-ignore, \
             meter-bias, feed-loss)"
        ),
    };
    Ok(FaultEvent { kind, start_s, duration_s })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_scenario() -> Scenario {
        let plan = FaultPlan::new()
            .with(FaultKind::TelemetryFreeze, 100.5, 50.25)
            .with(
                FaultKind::OobStorm { loss_prob: 0.85, latency_mult: 4.0, jitter_frac: 0.25 },
                300.0,
                120.0,
            )
            .with(FaultKind::CapIgnore { server_frac: 0.5 }, 600.0, 60.0)
            .with(FaultKind::MeterBias { mult: 0.8 }, 900.0, 60.0)
            .with(FaultKind::FeedLoss { budget_frac: 0.75 }, 1200.0, 60.0);
        let mut sc = Scenario::builder("full")
            .description("every field exercised, incl. \"quotes\"")
            .policy(PolicyKind::OneThreshAll)
            .servers(16)
            .added(0.3)
            .weeks(0.1)
            .seed(42)
            .power_scale(1.45)
            .peak_utilization(0.8)
            .power_mult(1.05)
            .lp_fraction(0.4)
            .thresholds(0.75, 0.9)
            .training(0.25)
            .training_jobs(4, 3.5)
            .faults(plan)
            .escalate(120.0)
            .build();
        sc.sku = Some("hgx-h100".to_string());
        sc
    }

    #[test]
    fn every_field_round_trips_bit_identically() {
        let sc = full_scenario();
        let doc = sc.to_toml();
        let text = doc.render();
        let reparsed = Toml::parse(&text).unwrap();
        assert_eq!(reparsed, doc, "document level:\n{text}");
        let back = Scenario::from_toml(&reparsed).unwrap();
        assert_eq!(back, sc, "value level:\n{text}");
    }

    #[test]
    fn adapt_and_drift_round_trip() {
        let sc = Scenario::builder("adaptive")
            .added(0.40)
            .weeks(4.0)
            .adaptive(1800.5)
            .adapt_levels(0.05, 0.10, 0.35)
            .adapt_pacing(3, 5)
            .drift(0.025, 0.15, 4.5)
            .build();
        let back = Scenario::parse(&sc.to_toml_string()).unwrap();
        assert_eq!(back, sc);
        // Sparse [adapt] sections fill controller defaults.
        let sparse = Scenario::parse("[adapt]\nwindow_s = 900.0").unwrap();
        let a = sparse.adapt.unwrap();
        assert_eq!(a.window_s, 900.0);
        assert_eq!(a.hold_windows, crate::policy::adapt::AdaptConfig::default().hold_windows);
        // ... and no [adapt]/[drift] section means no controller at all.
        assert!(Scenario::parse("name = \"x\"").unwrap().adapt.is_none());
        assert!(Scenario::parse("name = \"x\"").unwrap().drift.is_none());
    }

    #[test]
    fn site_and_named_faults_round_trip() {
        let mut sc = Scenario::builder("site")
            .policy(PolicyKind::Polca)
            .weeks(0.05)
            .seed(7)
            .site(3)
            .site_search(30, 5)
            .serial()
            .faults_scenario("cascade")
            .escalate(90.0)
            .build();
        sc.site.as_mut().unwrap().containment.max_violation_s = 45.0;
        let back = Scenario::parse(&sc.to_toml_string()).unwrap();
        assert_eq!(back, sc);
    }

    #[test]
    fn region_round_trips() {
        let sc = Scenario::builder("region")
            .policy(PolicyKind::Polca)
            .weeks(1.0 / 7.0)
            .seed(11)
            .region(6)
            .region_grid(0.8)
            .region_search(40, 10)
            .serial()
            .build();
        assert!(!sc.region.as_ref().unwrap().parallel, "serial() must reach [region]");
        let back = Scenario::parse(&sc.to_toml_string()).unwrap();
        assert_eq!(back, sc);
        let r = back.region.unwrap();
        assert_eq!((r.sites, r.max_added_pct, r.step_pct), (6, 40, 10));
        assert_eq!(r.grid_budget_frac, 0.8);
    }

    #[test]
    fn sparse_files_fill_defaults() {
        let sc = Scenario::parse(
            r#"
            name = "sparse"
            [row]
            added = 0.3
            [policy]
            kind = "nocap"
            "#,
        )
        .unwrap();
        assert_eq!(sc.name, "sparse");
        assert_eq!(sc.policy_kind, PolicyKind::NoCap);
        assert_eq!(sc.added_frac, 0.3);
        assert_eq!(sc.servers(), 40); // default row
        assert_eq!(sc.weeks, 1.0);
        assert_eq!(sc.faults, FaultSpec::None);
        assert!(sc.site.is_none());
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn bad_specs_error_helpfully() {
        let e = format!("{:#}", Scenario::parse("[policy]\nkind = \"bogus\"").unwrap_err());
        assert!(e.contains("bogus"), "{e}");
        let e = format!(
            "{:#}",
            Scenario::parse("[faults]\nevents = [[\"not-a-kind\", 1.0, 2.0]]").unwrap_err()
        );
        assert!(e.contains("not-a-kind"), "{e}");
        let e = format!(
            "{:#}",
            Scenario::parse("[faults]\nevents = [[\"oob-storm\", 1.0, 2.0]]").unwrap_err()
        );
        assert!(e.contains("loss_prob"), "{e}");
    }

    #[test]
    fn save_and_load_through_disk() {
        let sc = full_scenario();
        let dir = std::env::temp_dir().join("polca_scenario_toml_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("full.toml");
        sc.save(&path).unwrap();
        let back = Scenario::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, sc);
    }
}
