//! The unified scenario layer: **one declarative spec for every run**.
//!
//! PRs 1–3 grew four disjoint entry points — `SimConfig`/`run` for
//! inference rows, `MixedRowConfig` for training colocation,
//! `FaultPlan` + `SiteRunConfig` for resilience, and the fleet planner
//! for sites — each re-wired by hand in `main.rs` and in every
//! experiment generator. A [`Scenario`] composes all of them into a
//! single value:
//!
//! * **workload** — horizon, seed, catalog model, peak utilization,
//!   power multiplier, LP-share override;
//! * **cluster shape** — baseline servers, oversubscription,
//!   optional SKU ([`crate::fleet::sku`]) and power-scale override;
//! * **policy** — [`PolicyKind`] plus every Table-3 tuning knob
//!   (carried in [`crate::config::ExperimentConfig`]), and the
//!   containment-escalation setting;
//! * **training mix** — fraction / job granularity / stagger
//!   ([`crate::simulation::MixedRowConfig`], §2.4/§7);
//! * **fault plan** — a named scenario resolved against the horizon or
//!   an explicit [`FaultPlan`] timeline ([`crate::faults`]);
//! * **site topology** — optional [`SiteSection`]: when present the
//!   scenario runs through the fleet planner instead of a single row;
//! * **region topology** — optional [`RegionSection`]: when present the
//!   scenario runs the analytic region planner
//!   ([`crate::fleet::region`]) over a demo multi-site region under a
//!   shared grid budget.
//!
//! The spec is fully declarative and [`PartialEq`]: it builds fluently
//! ([`ScenarioBuilder`]), round-trips losslessly through the in-tree
//! TOML subset (`Scenario::from_toml(&s.to_toml()) == s`, see
//! [`crate::config::Toml::render`]), ships as a named preset registry
//! ([`presets::preset`], `polca scenario list`), and executes through
//! exactly one path:
//! [`Scenario::run`], which dispatches to the existing simulation and
//! fleet engines. Every CLI surface (`polca run`, and the deprecated
//! `simulate|mixed|faults|fleet` aliases) and every experiment
//! generator constructs runs through this layer, so adding a new
//! study is one preset (or one `.toml` under `examples/scenarios/`),
//! not a new subcommand.

pub mod builder;
pub mod presets;
pub mod toml;

pub use builder::ScenarioBuilder;
pub use presets::{preset, preset_names, presets};

use crate::config::ExperimentConfig;
use crate::faults::{ContainmentSlo, FaultPlan};
use crate::fleet::planner::{
    plan_site, plan_site_under_faults, FaultedSitePlan, PlannerConfig, PolicyPlan,
};
use crate::fleet::region::{plan_region, RegionPlan, RegionPlanConfig, RegionSpec};
use crate::fleet::site::SiteSpec;
use crate::metrics::{ImpactSummary, ResilienceMetrics, RunReport};
use crate::obs::export::{render_timeline, IncidentTimeline};
use crate::obs::Observer;
use crate::policy::adapt::AdaptConfig;
use crate::policy::engine::PolicyKind;
use crate::simulation::{
    power_scale_for_row, run_with_impact, run_with_impact_observed, MixedRowConfig, SimConfig,
};
use crate::workload::arrivals::DriftConfig;

/// The training-colocation part of a scenario (flows into
/// [`MixedRowConfig`]; the iteration waveform is the canonical
/// [`crate::power::training::TrainingProfile::large_llm`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrainingMix {
    /// Fraction of deployed servers running synchronized training
    /// (0.0 = the paper's inference-only row).
    pub fraction: f64,
    /// Servers per synchronized job (0 = one row-spanning job).
    pub servers_per_job: usize,
    /// Offset between consecutive jobs' start times, seconds.
    pub stagger_s: f64,
}

/// The fault-injection part of a scenario.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum FaultSpec {
    /// No injection at all (the paper's well-behaved control plane).
    #[default]
    None,
    /// A named built-in scenario ([`FaultPlan::scenario_names`]),
    /// resolved against the run horizon at execution time.
    Named(String),
    /// An explicit episode timeline, absolute seconds.
    Plan(FaultPlan),
}

/// The optional site-topology part of a scenario: when present,
/// [`Scenario::run`] dispatches to the fleet planner over a
/// [`SiteSpec::demo`] topology of this size instead of one row.
#[derive(Debug, Clone, PartialEq)]
pub struct SiteSection {
    /// Demo-topology cluster count (SKUs cycle through the registry,
    /// diurnal peaks staggered 3 h apart).
    pub clusters: usize,
    /// Planner search ceiling for the added fraction, percent.
    pub max_added_pct: u32,
    /// Planner search resolution, percentage points.
    pub step_pct: u32,
    /// Fan clusters out on scoped threads.
    pub parallel: bool,
    /// Power-series sampling period for trace composition, seconds.
    pub sample_s: f64,
    /// Containment SLO for fault-mode planning (used when the scenario
    /// also carries a fault spec).
    pub containment: ContainmentSlo,
}

impl Default for SiteSection {
    fn default() -> Self {
        SiteSection {
            clusters: 4,
            max_added_pct: 50,
            step_pct: 2,
            parallel: true,
            sample_s: 60.0,
            containment: ContainmentSlo::default(),
        }
    }
}

/// The optional region part of a scenario: when present,
/// [`Scenario::run`] dispatches to the analytic region planner
/// ([`crate::fleet::region::plan_region`]) over a
/// [`RegionSpec::demo`] topology instead of one row or site.
#[derive(Debug, Clone, PartialEq)]
pub struct RegionSection {
    /// Demo-region site count (time zones staggered 3 h apart).
    pub sites: usize,
    /// Clusters per demo site (SKUs cycle through the registry).
    pub clusters_per_site: usize,
    /// Shared grid budget as a fraction of the summed substation
    /// budgets.
    pub grid_budget_frac: f64,
    /// Planner search ceiling for the added level, percent.
    pub max_added_pct: u32,
    /// Planner granularity, percentage points.
    pub step_pct: u32,
    /// Fan archetype/validation batches out on scoped threads.
    pub parallel: bool,
    /// Trace sampling period, seconds.
    pub sample_s: f64,
    /// Sites to spot-validate against full simulation (the
    /// `polca fleet region validate` surface; planning ignores it).
    pub validate_sites: usize,
}

impl Default for RegionSection {
    fn default() -> Self {
        RegionSection {
            sites: 8,
            clusters_per_site: 3,
            grid_budget_frac: 0.85,
            max_added_pct: 50,
            step_pct: 5,
            parallel: true,
            sample_s: 300.0,
            validate_sites: 3,
        }
    }
}

/// One declarative run specification (see the module docs). Build with
/// [`Scenario::builder`], load with [`Scenario::load`], execute with
/// [`Scenario::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Scenario name (preset key / report label).
    pub name: String,
    /// One-line human description (shown by `polca scenario list`).
    pub description: String,
    /// Row topology, policy tuning knobs, SLOs, and the seed
    /// (paper Tables 1/3/5). `exp.row.num_servers` is the baseline
    /// (budget) server count.
    pub exp: ExperimentConfig,
    /// Which power-management policy drives the run.
    pub policy_kind: PolicyKind,
    /// Added-server fraction: deployed = baseline × (1 + added).
    pub added_frac: f64,
    /// Simulated horizon, weeks (fractions allowed).
    pub weeks: f64,
    /// Catalog model every server is dedicated to (§6.1).
    pub model_name: String,
    /// Target server busy fraction at the diurnal peak.
    pub peak_utilization: f64,
    /// Multiplier on per-workload power (Fig 17 robustness knob).
    pub workload_power_mult: f64,
    /// Override the global LP share (Fig 15b sweep).
    pub lp_fraction_override: Option<f64>,
    /// Explicit row-power calibration; `None` = the row-size-appropriate
    /// [`power_scale_for_row`] (the shared calibration every surface
    /// uses since PR 3).
    pub power_scale: Option<f64>,
    /// Server SKU by registry name ([`crate::fleet::sku`]); `None` = the
    /// paper's DGX-A100 catalog default.
    pub sku: Option<String>,
    /// Training colocation (§2.4/§7).
    pub training: TrainingMix,
    /// Fault injection ([`crate::faults`]).
    pub faults: FaultSpec,
    /// Policy-engine containment escalation (`None` = paper behavior).
    pub brake_escalation_s: Option<f64>,
    /// Adaptive oversubscription controller ([`crate::policy::adapt`]);
    /// `None` = the static provisioning every other scenario uses.
    pub adapt: Option<AdaptConfig>,
    /// Long-horizon demand drift (growth ramp + seasonal modulation)
    /// on every arrival stream; `None` = the paper's stationary diurnal.
    pub drift: Option<DriftConfig>,
    /// Site topology; `None` = a single row.
    pub site: Option<SiteSection>,
    /// Region topology; `None` = a single row or site. Mutually
    /// exclusive with `site`.
    pub region: Option<RegionSection>,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "scenario".to_string(),
            description: String::new(),
            exp: ExperimentConfig::default(),
            policy_kind: PolicyKind::Polca,
            added_frac: 0.0,
            weeks: 1.0,
            model_name: "BLOOM-176B".to_string(),
            peak_utilization: 0.85,
            workload_power_mult: 1.0,
            lp_fraction_override: None,
            power_scale: None,
            sku: None,
            training: TrainingMix::default(),
            faults: FaultSpec::None,
            brake_escalation_s: None,
            adapt: None,
            drift: None,
            site: None,
            region: None,
        }
    }
}

impl Scenario {
    /// Start a fluent builder.
    pub fn builder(name: &str) -> ScenarioBuilder {
        ScenarioBuilder::new(name)
    }

    /// Baseline (budget) server count of the row.
    pub fn servers(&self) -> usize {
        self.exp.row.num_servers
    }

    /// Servers actually deployed at the scenario's oversubscription.
    pub fn deployed_servers(&self) -> usize {
        (self.servers() as f64 * (1.0 + self.added_frac)).round() as usize
    }

    /// The simulated horizon in seconds (fault scenarios scale to it).
    pub fn horizon_s(&self) -> f64 {
        self.weeks * 7.0 * 86_400.0
    }

    /// The row-power calibration in effect: the explicit override, or
    /// the shared row-size fit.
    pub fn effective_power_scale(&self) -> f64 {
        self.power_scale.unwrap_or_else(|| power_scale_for_row(self.servers()))
    }

    /// Resolve the fault spec into a concrete plan (`None` = no
    /// injection). Named scenarios place their episodes relative to
    /// `horizon_s`.
    pub fn fault_plan(&self, horizon_s: f64) -> anyhow::Result<Option<FaultPlan>> {
        match &self.faults {
            FaultSpec::None => Ok(None),
            FaultSpec::Named(name) => Ok(Some(FaultPlan::scenario(name, horizon_s)?)),
            FaultSpec::Plan(plan) => {
                plan.normalized()?; // surface invalid timelines here, not mid-run
                Ok(Some(plan.clone()))
            }
        }
    }

    /// The row-level [`SimConfig`] this scenario denotes — the single
    /// place scenario fields map onto the simulator (the golden tests
    /// pin it against the legacy per-subcommand wiring it replaced).
    ///
    /// Call [`Scenario::validate`] first: an unresolvable fault spec or
    /// SKU panics here (the CLI always validates before running).
    pub fn sim_config(&self) -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.exp = self.exp.clone();
        cfg.policy_kind = self.policy_kind;
        cfg.deployed_servers = self.deployed_servers();
        cfg.weeks = self.weeks;
        cfg.model_name = self.model_name.clone();
        cfg.lp_fraction_override = self.lp_fraction_override;
        cfg.power_scale = self.effective_power_scale();
        cfg.workload_power_mult = self.workload_power_mult;
        cfg.peak_utilization = self.peak_utilization;
        cfg.brake_escalation_s = self.brake_escalation_s;
        cfg.adapt = self.adapt.clone();
        cfg.drift = self.drift.clone();
        if self.training.fraction > 0.0 {
            cfg.mixed = Some(MixedRowConfig {
                training_fraction: self.training.fraction,
                servers_per_job: self.training.servers_per_job,
                job_stagger_s: self.training.stagger_s,
                ..Default::default()
            });
        }
        cfg.faults = self.fault_plan(self.horizon_s()).expect("validate() the scenario first");
        if let Some(name) = &self.sku {
            let sku = crate::fleet::sku::find(name).expect("validate() the scenario first");
            let base = crate::characterize::catalog::find(&self.model_name)
                .expect("validate() the scenario first")
                .power;
            cfg.server_model = Some(sku.server_model(base));
            cfg.perf_mult = sku.perf_mult;
            sku.scale_policy(&mut cfg.exp.policy);
        }
        cfg
    }

    /// The site topology this scenario denotes (`None` for row
    /// scenarios): the demo heterogeneous site at the scenario's
    /// training fraction.
    pub fn site_spec(&self) -> Option<SiteSpec> {
        self.site.as_ref().map(|s| {
            let spec = SiteSpec::demo(s.clusters);
            if self.training.fraction > 0.0 {
                spec.with_training(self.training.fraction)
            } else {
                spec
            }
        })
    }

    /// The planner configuration for a site scenario (`None` for row
    /// scenarios).
    pub fn planner_config(&self) -> Option<PlannerConfig> {
        self.site.as_ref().map(|s| PlannerConfig {
            weeks: self.weeks,
            seed: self.exp.seed,
            sample_s: s.sample_s,
            parallel: s.parallel,
            max_added_pct: s.max_added_pct,
            step_pct: s.step_pct,
            slo: self.exp.slo.clone(),
            brake_escalation_s: self.brake_escalation_s,
        })
    }

    /// The region topology this scenario denotes (`None` for row and
    /// site scenarios): the demo multi-site region at the scenario's
    /// training fraction.
    pub fn region_spec(&self) -> Option<RegionSpec> {
        self.region.as_ref().map(|r| {
            let mut spec = RegionSpec::demo(r.sites, r.clusters_per_site, r.grid_budget_frac);
            if self.training.fraction > 0.0 {
                for rs in &mut spec.sites {
                    rs.site = rs.site.with_training(self.training.fraction);
                }
            }
            spec
        })
    }

    /// The region-planner configuration (`None` for row and site
    /// scenarios).
    pub fn region_plan_config(&self) -> Option<RegionPlanConfig> {
        self.region.as_ref().map(|r| RegionPlanConfig {
            policy: self.policy_kind,
            weeks: self.weeks,
            seed: self.exp.seed,
            sample_s: r.sample_s,
            parallel: r.parallel,
            max_added_pct: r.max_added_pct,
            step_pct: r.step_pct,
        })
    }

    /// A shortened copy for smoke runs, mirroring
    /// [`crate::experiments::Depth::Quick`]'s horizon scaling — but
    /// never *longer* than the spec's own horizon (a scenario already
    /// shorter than the quick floor stays as it is).
    pub fn quick(mut self) -> Self {
        self.weeks = self.weeks.min((self.weeks * 0.15).max(0.1));
        self
    }

    /// Check the spec for contradictions: threshold ordering, fraction
    /// ranges, resolvable SKU / model / fault names, valid fault
    /// timelines, and site-section sanity. Collects every problem into
    /// one error so a config file is fixed in one pass.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut problems: Vec<String> = Vec::new();
        if self.name.is_empty() {
            problems.push("name must not be empty".into());
        }
        if self.weeks.is_nan() || self.weeks <= 0.0 {
            problems.push(format!("weeks must be > 0 (got {})", self.weeks));
        }
        if self.servers() == 0 {
            problems.push("row.num_servers must be > 0".into());
        }
        if self.added_frac.is_nan() || self.added_frac < 0.0 {
            problems.push(format!("added must be >= 0 (got {})", self.added_frac));
        }
        let p = &self.exp.policy;
        if p.t1.is_nan() || p.t2.is_nan() || p.t1 >= p.t2 {
            problems.push(format!("policy thresholds need t1 < t2 (got {} >= {})", p.t1, p.t2));
        }
        if !(0.0..=1.0).contains(&self.training.fraction) {
            problems.push(format!(
                "training fraction must be in [0, 1] (got {})",
                self.training.fraction
            ));
        }
        if !(self.peak_utilization > 0.0 && self.peak_utilization <= 1.0) {
            problems.push(format!(
                "peak_utilization must be in (0, 1] (got {})",
                self.peak_utilization
            ));
        }
        if crate::characterize::catalog::find(&self.model_name).is_none() {
            problems.push(format!("unknown model '{}'", self.model_name));
        }
        if let Some(sku) = &self.sku {
            if crate::fleet::sku::find(sku).is_none() {
                problems.push(format!(
                    "unknown sku '{sku}' (known: {})",
                    crate::fleet::sku::registry()
                        .iter()
                        .map(|s| s.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
        if let Err(e) = self.fault_plan(self.horizon_s()) {
            problems.push(format!("fault spec: {e:#}"));
        }
        if let Some(a) = &self.adapt {
            if !(a.window_s > 0.0) {
                problems.push(format!("adapt.window_s must be > 0 (got {})", a.window_s));
            }
            if !(a.level_step > 0.0) {
                problems.push(format!("adapt.level_step must be > 0 (got {})", a.level_step));
            }
            if a.min_added < 0.0
                || a.min_added > a.initial_added
                || a.initial_added > a.max_added
            {
                problems.push(format!(
                    "adapt levels need 0 <= min <= initial <= max (got {} / {} / {})",
                    a.min_added, a.initial_added, a.max_added
                ));
            }
            if a.max_added > self.added_frac + 1e-9 {
                problems.push(format!(
                    "adapt.max_added ({}) exceeds the racked oversubscription \
                     (row.added = {}) — the controller cannot activate servers \
                     that are not deployed",
                    a.max_added, self.added_frac
                ));
            }
            if self.training.fraction > 0.0 {
                problems.push(
                    "adapt cannot be combined with training colocation (the active-server \
                     actuation only sheds inference arrivals)"
                        .into(),
                );
            }
            if self.site.is_some() || self.region.is_some() {
                problems.push(
                    "adapt is a row-level controller; site/region planning already \
                     searches the added level offline"
                        .into(),
                );
            }
        }
        if let Some(dr) = &self.drift {
            if !(dr.season_period_weeks > 0.0) {
                problems.push(format!(
                    "drift.season_period_weeks must be > 0 (got {})",
                    dr.season_period_weeks
                ));
            }
            if !(dr.growth_per_week > -1.0) {
                problems.push(format!(
                    "drift.growth_per_week must be > -1 (got {})",
                    dr.growth_per_week
                ));
            }
            if !(dr.season_amp.abs() < 1.0) {
                problems.push(format!(
                    "drift.season_amp must be in (-1, 1) (got {})",
                    dr.season_amp
                ));
            }
            if self.site.is_some() || self.region.is_some() {
                problems.push(
                    "drift is a row-level workload knob; the site/region planners \
                     do not thread it through"
                        .into(),
                );
            }
        }
        if let Some(site) = &self.site {
            if site.clusters == 0 {
                problems.push("site.clusters must be > 0".into());
            }
            if site.step_pct == 0 {
                problems.push("site.step_pct must be > 0".into());
            }
            if self.sku.is_some() {
                problems.push(
                    "sku cannot be combined with a site (the demo topology \
                     cycles through the SKU registry itself)"
                        .into(),
                );
            }
        }
        if let Some(region) = &self.region {
            if region.sites == 0 {
                problems.push("region.sites must be > 0".into());
            }
            if region.clusters_per_site == 0 {
                problems.push("region.clusters_per_site must be > 0".into());
            }
            if region.step_pct == 0 {
                problems.push("region.step_pct must be > 0".into());
            }
            if region.grid_budget_frac.is_nan() || region.grid_budget_frac <= 0.0 {
                problems.push(format!(
                    "region.grid_budget_frac must be > 0 (got {})",
                    region.grid_budget_frac
                ));
            }
            if self.site.is_some() {
                problems.push("a scenario plans either a site or a region, not both".into());
            }
            if self.sku.is_some() {
                problems.push(
                    "sku cannot be combined with a region (the demo topology \
                     cycles through the SKU registry itself)"
                        .into(),
                );
            }
            if !matches!(self.faults, FaultSpec::None) {
                problems.push(
                    "fault injection is not supported for region planning \
                     (derate individual sites via a [site] scenario instead)"
                        .into(),
                );
            }
        }
        if problems.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("invalid scenario '{}': {}", self.name, problems.join("; "))
        }
    }

    /// One-line description of what will run (printed before a run).
    pub fn describe(&self) -> String {
        let faults = match &self.faults {
            FaultSpec::None => String::new(),
            FaultSpec::Named(n) => format!(", faults '{n}'"),
            FaultSpec::Plan(p) => format!(", {} fault episodes", p.len()),
        };
        let training = if self.training.fraction > 0.0 {
            format!(", {:.0}% training", self.training.fraction * 100.0)
        } else {
            String::new()
        };
        let adapt = match &self.adapt {
            Some(a) => format!(
                ", adaptive (window {:.1}h, +{:.0}%..+{:.0}%)",
                a.window_s / 3600.0,
                a.min_added * 100.0,
                a.max_added * 100.0
            ),
            None => String::new(),
        };
        let drift = match &self.drift {
            Some(dr) => format!(
                ", drift {:+.0}%/wk ±{:.0}%",
                dr.growth_per_week * 100.0,
                dr.season_amp * 100.0
            ),
            None => String::new(),
        };
        if let Some(r) = &self.region {
            return format!(
                "scenario '{}': plan a {}-site region ({} clusters/site, grid budget \
                 {:.0}% of substation sum) under {} for {:.2} weeks{} (seed {})",
                self.name,
                r.sites,
                r.clusters_per_site,
                r.grid_budget_frac * 100.0,
                self.policy_kind.name(),
                self.weeks,
                training,
                self.exp.seed
            );
        }
        match &self.site {
            Some(s) => format!(
                "scenario '{}': plan a {}-cluster site under {} for {:.2} weeks{}{} (seed {})",
                self.name,
                s.clusters,
                self.policy_kind.name(),
                self.weeks,
                training,
                faults,
                self.exp.seed
            ),
            None => format!(
                "scenario '{}': {} deployed on a {}-server budget (+{:.0}%) under {} \
                 for {:.2} weeks{}{}{}{} (seed {})",
                self.name,
                self.deployed_servers(),
                self.servers(),
                self.added_frac * 100.0,
                self.policy_kind.name(),
                self.weeks,
                training,
                faults,
                adapt,
                drift,
                self.exp.seed
            ),
        }
    }

    /// Execute the scenario through the single dispatch path: row
    /// scenarios run the discrete-event simulator paired with its
    /// unthrottled baseline; site scenarios run the fleet planner
    /// (fault-derated when a fault spec is present).
    pub fn run(&self) -> anyhow::Result<ScenarioReport> {
        self.validate()?;
        if self.region.is_some() {
            let spec = self.region_spec().unwrap();
            let pc = self.region_plan_config().unwrap();
            let plan = plan_region(&spec, &pc);
            return Ok(ScenarioReport {
                name: self.name.clone(),
                outcome: Outcome::Region(Box::new(plan)),
                timeline: None,
            });
        }
        if self.site.is_some() {
            let spec = self.site_spec().unwrap();
            let pc = self.planner_config().unwrap();
            let cslo = self.site.as_ref().unwrap().containment.clone();
            let outcome = match self.fault_plan(self.horizon_s())? {
                Some(plan) if !plan.is_empty() => {
                    let derated =
                        plan_site_under_faults(&spec, self.policy_kind, &pc, &plan, &cslo);
                    SiteReport { plan: derated.clean.clone(), derated: Some(derated) }
                }
                _ => SiteReport { plan: plan_site(&spec, self.policy_kind, &pc), derated: None },
            };
            Ok(ScenarioReport {
                name: self.name.clone(),
                outcome: Outcome::Site(Box::new(outcome)),
                timeline: None,
            })
        } else {
            let cfg = self.sim_config();
            let (report, impact) = run_with_impact(&cfg);
            let slo_violations = impact.slo_violations(&self.exp.slo);
            Ok(ScenarioReport {
                name: self.name.clone(),
                outcome: Outcome::Row(Box::new(RowReport { report, impact, slo_violations })),
                timeline: None,
            })
        }
    }

    /// [`Scenario::run`] with an [`Observer`] on the policy run — the
    /// engine behind `polca run --trace`. Row scenarios only: a site
    /// scenario's planner sweep runs hundreds of candidate simulations,
    /// so there is no single run to trace (the CLI surfaces this as an
    /// error rather than silently tracing nothing). Observation is
    /// passive — the report is bit-identical to [`Scenario::run`]; the
    /// returned report's `timeline` stays `None` (the caller derives it
    /// from the observer's records, which the scenario layer does not
    /// assume are retrievable from an arbitrary `O`).
    pub fn run_observed<O: Observer>(&self, obs: &mut O) -> anyhow::Result<ScenarioReport> {
        self.validate()?;
        if self.site.is_some() || self.region.is_some() {
            anyhow::bail!(
                "scenario '{}' plans a {}: tracing needs a single row run \
                 (drop the [{}] section to trace)",
                self.name,
                if self.region.is_some() { "region" } else { "site" },
                if self.region.is_some() { "region" } else { "site" },
            );
        }
        let cfg = self.sim_config();
        let (report, impact) = run_with_impact_observed(&cfg, obs);
        let slo_violations = impact.slo_violations(&self.exp.slo);
        Ok(ScenarioReport {
            name: self.name.clone(),
            outcome: Outcome::Row(Box::new(RowReport { report, impact, slo_violations })),
            timeline: None,
        })
    }
}

/// The error-path counterpart of [`ScenarioReport::to_json`]: the one
/// machine-readable error document shared by `polca run --json` and
/// the gateway's failed-run reports, so the two surfaces cannot drift.
/// The shape mirrors the success document's envelope (`"name"` at the
/// top level) with `"error"` in place of `"outcome"`.
pub fn error_report_json(name: &str, err: &anyhow::Error) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("name", Json::Str(name.to_string())),
        ("error", Json::Str(format!("{err:#}"))),
    ])
}

/// A row scenario's result: the simulation report, its impact vs the
/// unthrottled baseline, and the Table-5 verdict.
#[derive(Debug, Clone)]
pub struct RowReport {
    /// The full simulation report (includes resilience accounting).
    pub report: RunReport,
    /// Latency/throughput impact vs the unthrottled counterfactual.
    pub impact: ImpactSummary,
    /// Table-5 SLO violations (empty = SLOs held).
    pub slo_violations: Vec<String>,
}

/// A site scenario's result: the clean capacity plan, plus the
/// fault-derated plan when a fault spec was present.
#[derive(Debug, Clone)]
pub struct SiteReport {
    /// The clean (no-fault) plan.
    pub plan: PolicyPlan,
    /// The fault-derated plan, when the scenario injected faults.
    pub derated: Option<FaultedSitePlan>,
}

/// Which engine the scenario dispatched to.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// A single-row simulation.
    Row(Box<RowReport>),
    /// A site-level capacity plan.
    Site(Box<SiteReport>),
    /// A region-level allocation plan (analytic trace composition).
    Region(Box<RegionPlan>),
}

/// What [`Scenario::run`] returns: one report shape for every scenario.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    /// The scenario's name.
    pub name: String,
    /// Row or site result.
    pub outcome: Outcome,
    /// Per-incident control-loop timelines, when the run was traced
    /// (`polca run --trace` attaches them from the recorded event
    /// stream; untraced runs carry `None` and their JSON shape is
    /// unchanged).
    pub timeline: Option<Vec<IncidentTimeline>>,
}

impl ScenarioReport {
    /// Machine-readable view (the `polca run --json` output): one JSON
    /// document per run so scripts consume results without scraping the
    /// rendered tables. Row scenarios carry the full simulation report,
    /// the impact-vs-baseline block, and the Table-5 verdict; site
    /// scenarios carry the capacity plan (and the fault-derated plan
    /// when the scenario injected faults). `&mut` because latency
    /// percentiles sort lazily.
    pub fn to_json(&mut self) -> crate::util::json::Json {
        use crate::util::json::Json;
        fn plan_json(p: &PolicyPlan) -> Json {
            Json::obj(vec![
                ("policy", Json::Str(p.policy.name().to_string())),
                ("feasible", Json::Bool(p.feasible)),
                ("added_pct", Json::Num(p.added_pct as f64)),
                ("baseline_servers", Json::Num(p.baseline_servers as f64)),
                ("deployable_servers", Json::Num(p.deployable_servers as f64)),
                ("site_peak_w", Json::Num(p.site_peak_w)),
                ("substation_budget_w", Json::Num(p.substation_budget_w)),
                ("headroom_frac", Json::Num(p.headroom_frac)),
                ("brake_events", Json::Num(p.brake_events as f64)),
                ("cap_events_per_day", Json::Num(p.cap_events_per_day)),
                ("worst_hp_p99", Json::Num(p.worst_hp_p99)),
                ("worst_lp_p99", Json::Num(p.worst_lp_p99)),
            ])
        }
        let outcome = match &mut self.outcome {
            Outcome::Row(row) => Json::obj(vec![
                ("kind", Json::Str("row".to_string())),
                ("report", row.report.to_json()),
                ("impact", row.impact.to_json()),
                ("slo_ok", Json::Bool(row.slo_violations.is_empty())),
                (
                    "slo_violations",
                    Json::arr(row.slo_violations.iter().map(|v| Json::Str(v.clone()))),
                ),
            ]),
            Outcome::Site(site) => {
                let mut pairs = vec![
                    ("kind", Json::Str("site".to_string())),
                    ("plan", plan_json(&site.plan)),
                ];
                if let Some(d) = &site.derated {
                    pairs.push((
                        "derated",
                        Json::obj(vec![
                            ("feasible", Json::Bool(d.feasible)),
                            ("derated_added_pct", Json::Num(d.derated_added_pct as f64)),
                            ("derated_servers", Json::Num(d.derated_servers as f64)),
                            ("worst_violation_s", Json::Num(d.worst_violation_s)),
                            (
                                // Json::num: infinite when uncontained.
                                "worst_time_to_contain_s",
                                Json::num(d.worst_time_to_contain_s),
                            ),
                            ("worst_overshoot_frac", Json::Num(d.worst_overshoot_frac)),
                        ]),
                    ));
                }
                Json::obj(pairs)
            }
            Outcome::Region(plan) => Json::obj(vec![
                ("kind", Json::Str("region".to_string())),
                ("feasible", Json::Bool(plan.feasible)),
                ("sites", Json::Num(plan.site_names.len() as f64)),
                ("baseline_servers", Json::Num(plan.baseline_servers as f64)),
                ("deployed_servers", Json::Num(plan.deployed_servers as f64)),
                ("uniform_added_pct", Json::Num(plan.uniform_added_pct as f64)),
                (
                    "added_pct",
                    Json::arr(plan.added_pct.iter().map(|&a| Json::Num(a as f64))),
                ),
                ("headroom_pct", Json::Num(plan.headroom_pct())),
                ("grid_budget_w", Json::Num(plan.grid_budget_w)),
                ("grid_peak_w", Json::Num(plan.grid_peak_w)),
                ("archetype_sims", Json::Num(plan.archetype_sims as f64)),
                ("candidate_evals", Json::Num(plan.candidate_evals as f64)),
            ]),
        };
        let mut pairs = vec![("name", Json::Str(self.name.clone())), ("outcome", outcome)];
        if let Some(tls) = &self.timeline {
            pairs.push(("timeline", Json::arr(tls.iter().map(|t| t.to_json()))));
        }
        Json::obj(pairs)
    }

    /// Render the human-readable report (the `polca run` output).
    /// `&mut` because latency percentiles sort lazily.
    pub fn render(&mut self) -> String {
        let mut out = String::new();
        match &mut self.outcome {
            Outcome::Row(row) => {
                out.push_str(&row.report.summary());
                out.push('\n');
                let i = &row.impact;
                out.push_str(&format!(
                    "impact vs uncapped: HP p50/p99 = {:.2}%/{:.2}%  LP p50/p99 = {:.2}%/{:.2}%  \
                     thrpt HP/LP = {:.3}/{:.3}\n",
                    i.hp_p50 * 100.0,
                    i.hp_p99 * 100.0,
                    i.lp_p50 * 100.0,
                    i.lp_p99 * 100.0,
                    i.hp_throughput,
                    i.lp_throughput
                ));
                if row.report.train.iters > 0 {
                    out.push_str(&format!(
                        "training: {} iterations, mean {:.3}s vs nominal {:.3}s \
                         (inflation {:.1}%)\n",
                        row.report.train.iters,
                        row.report.train.mean_iter_s(),
                        row.report.train.nominal_iter_s,
                        row.report.train.inflation() * 100.0
                    ));
                }
                if row.slo_violations.is_empty() {
                    out.push_str("SLO: OK (Table 5)\n");
                } else {
                    out.push_str(&format!("SLO: VIOLATED — {}\n", row.slo_violations.join("; ")));
                }
                let r = &row.report.resilience;
                if !r.incidents.is_empty() {
                    for inc in &r.incidents {
                        out.push_str(&format!(
                            "incident {:<16} [{:>7.0}s..{:>7.0}s]  time-to-contain {}\n",
                            inc.label,
                            inc.start_s,
                            inc.end_s,
                            ResilienceMetrics::fmt_ttc(inc.time_to_contain_s)
                        ));
                    }
                    out.push_str(&format!(
                        "containment: {} (violation {:.1}s, peak overshoot {:.0} W, \
                         true peak {:.3}, reissued {})\n",
                        if r.all_contained() { "OK" } else { "FAILED" },
                        r.violation_s,
                        r.peak_overshoot_w,
                        r.true_peak_norm,
                        r.reissued_commands
                    ));
                }
            }
            Outcome::Site(site) => {
                let p = &site.plan;
                out.push_str(&format!(
                    "{}: {} deployable servers (+{}%) of {} baseline — site peak {:.0} kW / \
                     budget {:.0} kW (headroom {:.1}%), {} brakes, {:.1} caps/day, \
                     HP p99 {:.2}% LP p99 {:.2}%{}\n",
                    p.policy.name(),
                    p.deployable_servers,
                    p.added_pct,
                    p.baseline_servers,
                    p.site_peak_w / 1e3,
                    p.substation_budget_w / 1e3,
                    p.headroom_frac * 100.0,
                    p.brake_events,
                    p.cap_events_per_day,
                    p.worst_hp_p99 * 100.0,
                    p.worst_lp_p99 * 100.0,
                    if p.feasible { "" } else { " (NOT deployable even at baseline)" }
                ));
                if let Some(d) = &site.derated {
                    out.push_str(&format!(
                        "under faults: {} servers (+{}%) — derated by {} servers{}\n",
                        d.derated_servers,
                        d.derated_added_pct,
                        d.clean.deployable_servers.saturating_sub(d.derated_servers),
                        if d.feasible { "" } else { " (NOT deployable even at baseline)" }
                    ));
                    out.push_str(&format!(
                        "worst case at the derated point: violation {:.1}s, ttc {}, \
                         overshoot {:.1}%\n",
                        d.worst_violation_s,
                        ResilienceMetrics::fmt_ttc(d.worst_time_to_contain_s),
                        d.worst_overshoot_frac * 100.0
                    ));
                }
            }
            Outcome::Region(plan) => {
                out.push_str(&format!(
                    "region plan: {} deployable servers of {} baseline (+{:.1}%) across {} \
                     sites — grid peak {:.2} MW / budget {:.2} MW; uniform +{}%, \
                     {} archetype sims, {} closed-form evals{}\n",
                    plan.deployed_servers,
                    plan.baseline_servers,
                    plan.headroom_pct(),
                    plan.site_names.len(),
                    plan.grid_peak_w / 1e6,
                    plan.grid_budget_w / 1e6,
                    plan.uniform_added_pct,
                    plan.archetype_sims,
                    plan.candidate_evals,
                    if plan.feasible { "" } else { " (grid budget broken even at baseline)" }
                ));
            }
        }
        if let Some(tls) = &self.timeline {
            if !tls.is_empty() {
                out.push_str(&render_timeline(tls));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scenario_is_the_paper_row() {
        let sc = Scenario::default();
        assert!(sc.validate().is_ok());
        let cfg = sc.sim_config();
        let d = SimConfig::default();
        // The default scenario IS the paper's default simulation.
        assert_eq!(format!("{cfg:?}"), format!("{d:?}"));
    }

    #[test]
    fn added_fraction_rounds_like_the_legacy_wiring() {
        let mut sc = Scenario::default();
        sc.added_frac = 0.30;
        assert_eq!(sc.deployed_servers(), 52); // round(40 * 1.3)
        sc.exp.row.num_servers = 16;
        assert_eq!(sc.deployed_servers(), 21); // round(16 * 1.3)
    }

    #[test]
    fn power_scale_follows_row_size_unless_overridden() {
        let mut sc = Scenario::default();
        assert_eq!(sc.effective_power_scale(), crate::simulation::DEFAULT_POWER_SCALE);
        sc.exp.row.num_servers = 12;
        assert_eq!(sc.effective_power_scale(), power_scale_for_row(12));
        sc.power_scale = Some(2.0);
        assert_eq!(sc.effective_power_scale(), 2.0);
    }

    #[test]
    fn training_fraction_zero_keeps_the_inference_fast_path() {
        let sc = Scenario::default();
        assert!(sc.sim_config().mixed.is_none());
        let mut mixed = sc.clone();
        mixed.training.fraction = 0.5;
        mixed.training.servers_per_job = 3;
        let cfg = mixed.sim_config();
        let m = cfg.mixed.expect("training fraction must produce a mixed config");
        assert_eq!(m.training_fraction, 0.5);
        assert_eq!(m.servers_per_job, 3);
    }

    #[test]
    fn sku_override_scales_the_policy_domain() {
        let mut sc = Scenario::default();
        sc.sku = Some("hgx-h100".to_string());
        assert!(sc.validate().is_ok());
        let cfg = sc.sim_config();
        assert!(cfg.server_model.is_some());
        assert!(cfg.perf_mult > 2.0);
        // Table-3 setpoints moved into the H100 clock domain.
        assert_eq!(cfg.exp.policy.max_freq_mhz, 1980.0);
        // ... but the scenario itself still stores the A100-domain spec.
        assert_eq!(sc.exp.policy.max_freq_mhz, 1410.0);
    }

    #[test]
    fn validate_collects_every_problem() {
        let mut sc = Scenario::default();
        sc.weeks = 0.0;
        sc.exp.policy.t1 = 0.95; // >= t2
        sc.sku = Some("dgx-h200".to_string());
        sc.faults = FaultSpec::Named("bogus".to_string());
        sc.training.fraction = 1.5;
        let msg = format!("{:#}", sc.validate().unwrap_err());
        for needle in ["weeks", "t1 < t2", "dgx-h200", "bogus", "training fraction"] {
            assert!(msg.contains(needle), "missing '{needle}' in: {msg}");
        }
    }

    #[test]
    fn named_fault_spec_resolves_against_the_horizon() {
        let mut sc = Scenario::default();
        sc.weeks = 0.1;
        sc.faults = FaultSpec::Named("cascade".to_string());
        let plan = sc.fault_plan(sc.horizon_s()).unwrap().unwrap();
        assert_eq!(plan.len(), 3);
        let evs = plan.normalized().unwrap();
        assert!(evs.iter().all(|e| e.end_s() < sc.horizon_s()));
        // Explicit plans pass through unchanged.
        sc.faults = FaultSpec::Plan(plan.clone());
        assert_eq!(sc.fault_plan(sc.horizon_s()).unwrap().unwrap(), plan);
    }

    #[test]
    fn site_scenario_maps_onto_the_planner() {
        let mut sc = Scenario::default();
        sc.site = Some(SiteSection { clusters: 2, ..Default::default() });
        sc.training.fraction = 0.25;
        assert!(sc.validate().is_ok());
        let spec = sc.site_spec().unwrap();
        assert_eq!(spec.clusters.len(), 2);
        assert!(spec.clusters.iter().all(|c| c.training_fraction == 0.25));
        let pc = sc.planner_config().unwrap();
        assert_eq!(pc.weeks, sc.weeks);
        assert_eq!(pc.seed, sc.exp.seed);
        assert_eq!(pc.max_added_pct, 50);
    }

    #[test]
    fn region_scenario_maps_onto_the_region_planner() {
        let mut sc = Scenario::default();
        sc.region = Some(RegionSection { sites: 6, ..Default::default() });
        sc.weeks = 1.0 / 7.0;
        assert!(sc.validate().is_ok());
        let spec = sc.region_spec().unwrap();
        assert_eq!(spec.sites.len(), 6);
        let pc = sc.region_plan_config().unwrap();
        assert_eq!(pc.weeks, sc.weeks);
        assert_eq!(pc.seed, sc.exp.seed);
        assert_eq!(pc.max_added_pct, 50);
        assert_eq!(pc.step_pct, 5);
        assert!(sc.describe().contains("6-site region"));
        // training flows into every cluster of every site
        sc.training.fraction = 0.25;
        let spec = sc.region_spec().unwrap();
        assert!(spec
            .sites
            .iter()
            .all(|rs| rs.site.clusters.iter().all(|c| c.training_fraction == 0.25)));
        // region + site, region + sku, and region + faults all conflict
        sc.training.fraction = 0.0;
        sc.site = Some(SiteSection::default());
        sc.sku = Some("hgx-h100".to_string());
        sc.faults = FaultSpec::Named("cascade".to_string());
        let msg = format!("{:#}", sc.validate().unwrap_err());
        for needle in ["not both", "sku cannot be combined with a region", "fault injection"] {
            assert!(msg.contains(needle), "missing '{needle}' in: {msg}");
        }
    }

    #[test]
    fn quick_shrinks_the_horizon_like_depth_quick() {
        let sc = Scenario::default().quick();
        assert_eq!(sc.weeks, crate::experiments::Depth::Quick.weeks(1.0));
        // ... and never stretches an already-short scenario.
        let mut short = Scenario::default();
        short.weeks = 0.05;
        assert_eq!(short.quick().weeks, 0.05);
    }
}
