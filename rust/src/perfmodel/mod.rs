//! Request execution / latency model under time-varying frequency caps.
//!
//! [`crate::characterize::ModelSpec`] gives closed-form latencies at a
//! *fixed* frequency (Fig 5/7). The cluster simulator needs more: a
//! request's frequency can change mid-flight when the power manager caps
//! or uncaps its server (with 40 s OOB latency). [`RequestExec`] tracks
//! remaining *nominal-seconds* of work per phase and converts wall time
//! to work at the current frequency ratio, so latency composes correctly
//! across any sequence of cap changes.

use crate::characterize::catalog::ModelSpec;

/// Phase of an executing request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPhase {
    /// Prompt processing (compute-bound burst).
    Prompt,
    /// Autoregressive token generation (mostly memory-bound).
    Token,
    /// All work finished.
    Done,
}

/// Work state of one in-flight request.
#[derive(Debug, Clone)]
pub struct RequestExec {
    /// Input (prompt) tokens.
    pub input: f64,
    /// Output tokens to generate.
    pub output: f64,
    /// Batch size the request runs at.
    pub batch: f64,
    /// Remaining prompt work in nominal seconds (at f_max).
    pub prompt_remaining: f64,
    /// Remaining token work in nominal seconds.
    pub token_remaining: f64,
    /// Total nominal latency (for SLO impact accounting).
    pub nominal_latency: f64,
}

impl RequestExec {
    /// Fresh request with full nominal work remaining in both phases.
    pub fn new(model: &ModelSpec, input: f64, output: f64, batch: f64) -> Self {
        let p = model.prompt_time_s(input, batch);
        let t = model.token_time_s(output, batch);
        RequestExec {
            input,
            output,
            batch,
            prompt_remaining: p,
            token_remaining: t,
            nominal_latency: p + t,
        }
    }

    /// The phase the request is currently in.
    pub fn phase(&self) -> ExecPhase {
        if self.prompt_remaining > 0.0 {
            ExecPhase::Prompt
        } else if self.token_remaining > 0.0 {
            ExecPhase::Token
        } else {
            ExecPhase::Done
        }
    }

    /// Work progress rate (nominal-seconds per wall-second) for the
    /// current phase at frequency ratio `r = f/f_max`. Compute-bound
    /// fractions stretch 1/r; memory-bound fractions are unaffected.
    pub fn rate(&self, model: &ModelSpec, freq_ratio: f64) -> f64 {
        let r = freq_ratio.clamp(0.01, 1.0);
        let cf = match self.phase() {
            ExecPhase::Prompt => model.prompt_compute_frac,
            ExecPhase::Token => model.token_compute_frac,
            ExecPhase::Done => return 0.0,
        };
        1.0 / (cf / r + (1.0 - cf))
    }

    /// Wall time needed to finish the *current phase* at a fixed ratio.
    pub fn wall_to_phase_end(&self, model: &ModelSpec, freq_ratio: f64) -> f64 {
        let remaining = match self.phase() {
            ExecPhase::Prompt => self.prompt_remaining,
            ExecPhase::Token => self.token_remaining,
            ExecPhase::Done => return 0.0,
        };
        remaining / self.rate(model, freq_ratio)
    }

    /// Advance by `wall_dt` seconds at a fixed ratio; returns wall time
    /// actually consumed (may be less if the request finished).
    pub fn advance(&mut self, model: &ModelSpec, freq_ratio: f64, wall_dt: f64) -> f64 {
        let mut left = wall_dt;
        let mut consumed = 0.0;
        while left > 1e-12 && self.phase() != ExecPhase::Done {
            let phase_wall = self.wall_to_phase_end(model, freq_ratio);
            let step = phase_wall.min(left);
            let work = step * self.rate(model, freq_ratio);
            match self.phase() {
                ExecPhase::Prompt => {
                    self.prompt_remaining = (self.prompt_remaining - work).max(0.0);
                    if phase_wall <= left {
                        self.prompt_remaining = 0.0;
                    }
                }
                ExecPhase::Token => {
                    self.token_remaining = (self.token_remaining - work).max(0.0);
                    if phase_wall <= left {
                        self.token_remaining = 0.0;
                    }
                }
                ExecPhase::Done => {}
            }
            left -= step;
            consumed += step;
        }
        consumed
    }
}

/// Latency *impact* relative to nominal: `actual/nominal - 1`
/// (the paper's SLO metric, Table 5).
pub fn latency_impact(actual: f64, nominal: f64) -> f64 {
    (actual / nominal - 1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::characterize::catalog::find;

    #[test]
    fn uncapped_execution_matches_closed_form() {
        let bloom = find("BLOOM-176B").unwrap();
        let mut exec = RequestExec::new(&bloom, 2048.0, 256.0, 1.0);
        let closed = bloom.request_latency_s(2048.0, 256.0, 1.0, 1.0);
        let mut wall = 0.0;
        while exec.phase() != ExecPhase::Done {
            let step = exec.wall_to_phase_end(&bloom, 1.0);
            exec.advance(&bloom, 1.0, step);
            wall += step;
        }
        assert!((wall - closed).abs() < 1e-9, "wall={wall} closed={closed}");
    }

    #[test]
    fn capped_execution_matches_closed_form() {
        let bloom = find("BLOOM-176B").unwrap();
        let r = 1110.0 / 1410.0;
        let mut exec = RequestExec::new(&bloom, 4096.0, 128.0, 1.0);
        let closed = bloom.request_latency_s(4096.0, 128.0, 1.0, r);
        let mut wall = 0.0;
        while exec.phase() != ExecPhase::Done {
            let step = exec.wall_to_phase_end(&bloom, r);
            exec.advance(&bloom, r, step);
            wall += step;
        }
        assert!((wall - closed).abs() < 1e-9, "wall={wall} closed={closed}");
    }

    #[test]
    fn mid_flight_cap_change_composes() {
        // Run half the token phase uncapped, half capped; total work
        // must be conserved (no work lost or duplicated at the switch).
        let neox = find("GPT-NeoX-20B").unwrap();
        let mut a = RequestExec::new(&neox, 1024.0, 512.0, 1.0);
        // finish prompt
        let p = a.wall_to_phase_end(&neox, 1.0);
        a.advance(&neox, 1.0, p);
        assert_eq!(a.phase(), ExecPhase::Token);
        let token_nominal = a.token_remaining;
        // half at r=1, then rest at r=0.5
        let half_wall = a.wall_to_phase_end(&neox, 1.0) / 2.0;
        a.advance(&neox, 1.0, half_wall);
        let remaining_after_half = a.token_remaining;
        assert!((remaining_after_half - token_nominal / 2.0).abs() < 1e-9);
        let rest = a.wall_to_phase_end(&neox, 0.5);
        a.advance(&neox, 0.5, rest);
        assert_eq!(a.phase(), ExecPhase::Done);
    }

    #[test]
    fn advance_stops_at_done() {
        let m = find("Flan-T5-XXL").unwrap();
        let mut exec = RequestExec::new(&m, 256.0, 16.0, 1.0);
        let consumed = exec.advance(&m, 1.0, 1e9);
        assert_eq!(exec.phase(), ExecPhase::Done);
        assert!(consumed < 1e9);
        assert!((consumed - exec.nominal_latency).abs() < 1e-6);
        // further advances are no-ops
        assert_eq!(exec.advance(&m, 1.0, 1.0), 0.0);
    }

    #[test]
    fn token_phase_insensitive_prompt_sensitive() {
        let neox = find("GPT-NeoX-20B").unwrap();
        let exec = RequestExec::new(&neox, 4096.0, 512.0, 1.0);
        // prompt rate at half frequency drops hard
        let prompt_rate = exec.rate(&neox, 0.5);
        assert!(prompt_rate < 0.6);
        // token rate barely moves (memory-bound)
        let mut token_exec = exec.clone();
        token_exec.prompt_remaining = 0.0;
        let token_rate = token_exec.rate(&neox, 0.5);
        assert!(token_rate > 0.94, "token_rate={token_rate}");
    }

    #[test]
    fn impact_metric() {
        assert_eq!(latency_impact(1.1, 1.0), 0.10000000000000009);
        assert_eq!(latency_impact(0.9, 1.0), 0.0); // never negative
    }
}
