//! KV-cache slot management: each in-flight request owns exactly one
//! batch slot of the static-shaped KV cache (the protocol the L2 model
//! defines — see python/compile/model.py docstring).

/// Free-list slot allocator with occupancy tracking.
#[derive(Debug, Clone)]
pub struct SlotManager {
    free: Vec<usize>,
    total: usize,
    in_use: Vec<bool>,
}

impl SlotManager {
    /// Allocator over `total` slots, all free.
    pub fn new(total: usize) -> Self {
        SlotManager { free: (0..total).rev().collect(), total, in_use: vec![false; total] }
    }

    /// Total slot count.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Free slots remaining.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// Slots currently claimed.
    pub fn occupied(&self) -> usize {
        self.total - self.free.len()
    }

    /// Claim a slot, if any.
    pub fn acquire(&mut self) -> Option<usize> {
        let slot = self.free.pop()?;
        debug_assert!(!self.in_use[slot]);
        self.in_use[slot] = true;
        Some(slot)
    }

    /// Return a slot. Panics on double-free (a protocol violation the
    /// coordinator must never commit).
    pub fn release(&mut self, slot: usize) {
        assert!(slot < self.total, "slot {slot} out of range");
        assert!(self.in_use[slot], "double free of slot {slot}");
        self.in_use[slot] = false;
        self.free.push(slot);
    }

    /// Whether `slot` is currently claimed.
    pub fn is_in_use(&self, slot: usize) -> bool {
        self.in_use[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    #[test]
    fn acquire_release_cycle() {
        let mut s = SlotManager::new(3);
        assert_eq!(s.available(), 3);
        let a = s.acquire().unwrap();
        let b = s.acquire().unwrap();
        assert_ne!(a, b);
        assert_eq!(s.occupied(), 2);
        s.release(a);
        assert_eq!(s.available(), 2);
        let c = s.acquire().unwrap();
        let d = s.acquire().unwrap();
        assert!(s.acquire().is_none());
        assert_eq!(s.occupied(), 3);
        s.release(b);
        s.release(c);
        s.release(d);
        assert_eq!(s.available(), 3);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut s = SlotManager::new(2);
        let a = s.acquire().unwrap();
        s.release(a);
        s.release(a);
    }

    /// Property: under any random acquire/release schedule, no slot is
    /// ever handed out twice concurrently and occupancy accounting holds.
    #[test]
    fn property_no_aliasing() {
        testing::check_default(
            "slot-no-aliasing",
            |r: &mut Rng| {
                let n = r.range_usize(1, 6);
                let ops: Vec<bool> = (0..40).map(|_| r.bool(0.6)).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut s = SlotManager::new(*n);
                let mut held: Vec<usize> = Vec::new();
                for &acquire in ops {
                    if acquire {
                        if let Some(slot) = s.acquire() {
                            if held.contains(&slot) {
                                return Err(format!("slot {slot} aliased"));
                            }
                            held.push(slot);
                        } else if held.len() != *n {
                            return Err("acquire failed below capacity".into());
                        }
                    } else if let Some(slot) = held.pop() {
                        s.release(slot);
                    }
                    if s.occupied() != held.len() {
                        return Err(format!(
                            "occupancy {} != held {}",
                            s.occupied(),
                            held.len()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
