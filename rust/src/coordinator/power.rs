//! Power adapter for the live serving path: convert an executed
//! [`PhaseTimeline`] into the modeled power draw of the serving node, and
//! run the POLCA policy engine over a replicated row of such nodes — the
//! "POLCA in the loop" half of the end-to-end driver.
//!
//! The compute is real (PJRT); the *power* is modeled, because this
//! testbed has no DCGM/A100 (DESIGN.md §2 substitution table). Phases map
//! exactly: the prefill kernel's MXU burst → prompt-spike power, the
//! decode matvec → token-phase power, idle gaps → idle power.

use crate::config::PolicyConfig;
use crate::policy::engine::{PolicyEngine, PolicyKind};
use crate::power::gpu::{CapMode, GpuPowerCalib, Phase};
use crate::power::server::ServerPowerModel;

use super::batcher::{PhaseRecord, PhaseTimeline};

/// Sampled modeled power for a node.
#[derive(Debug, Clone)]
pub struct NodePowerTrace {
    /// Sampling period, seconds.
    pub dt_s: f64,
    /// Fraction of the node's provisioned power per sample.
    pub samples: Vec<f64>,
}

/// Convert a timeline into a sampled power trace.
///
/// `time_scale` stretches the (fast, tiny-model) wall clock onto the
/// characteristic durations of production phases so the policy sees
/// realistic dynamics; 1.0 uses raw wall time.
pub fn timeline_power(
    timeline: &PhaseTimeline,
    model: &ServerPowerModel,
    dt_s: f64,
    time_scale: f64,
) -> NodePowerTrace {
    let end = timeline
        .records
        .iter()
        .map(|r| match *r {
            PhaseRecord::Prefill(t, d, _) | PhaseRecord::Decode(t, d, _) => (t + d) * time_scale,
        })
        .fold(0.0_f64, f64::max);
    let n = (end / dt_s).ceil() as usize + 1;
    let mut samples = vec![model.server_power_w(Phase::Idle, CapMode::None, false); n];
    for rec in &timeline.records {
        let (t0, d, phase) = match *rec {
            PhaseRecord::Prefill(t, d, toks) => {
                (t * time_scale, d * time_scale, Phase::Prompt { total_input: toks as f64 })
            }
            PhaseRecord::Decode(t, d, batch) => {
                (t * time_scale, d * time_scale, Phase::Token { batch: batch as f64 })
            }
        };
        let w = model.server_power_w(phase, CapMode::None, false);
        let i0 = (t0 / dt_s) as usize;
        let i1 = ((t0 + d) / dt_s).ceil() as usize;
        for i in i0..i1.min(n) {
            samples[i] = samples[i].max(w);
        }
    }
    let prov = model.provisioned_w();
    NodePowerTrace { dt_s, samples: samples.into_iter().map(|w| w / prov).collect() }
}

/// Outcome of running POLCA over a replicated row of serving nodes.
#[derive(Debug, Clone)]
pub struct ServingPolicyReport {
    /// Normalized row power before policy action.
    pub row_power: Vec<f64>,
    /// Cap state over time: (t_s, lp_cap_mhz, hp_cap_mhz, braked).
    pub cap_timeline: Vec<(f64, Option<f64>, Option<f64>, bool)>,
    /// Powerbrake engagements over the replayed trace.
    pub brake_events: u64,
    /// Modeled LP latency stretch if the caps had applied to the
    /// executed phases (aggregate factor over the run).
    pub lp_modeled_stretch: f64,
    /// Modeled HP latency stretch (aggregate factor over the run).
    pub hp_modeled_stretch: f64,
}

/// Replicate one node's trace into a row of `n_replicas` (each shifted by
/// one sample per replica — the arrival-time decorrelation of §2.3) and
/// drive the policy engine over the aggregate.
pub fn run_policy_over_row(
    trace: &NodePowerTrace,
    n_replicas: usize,
    oversubscription: f64,
    policy_cfg: &PolicyConfig,
    calib: &GpuPowerCalib,
    token_compute_frac: f64,
    prompt_compute_frac: f64,
) -> ServingPolicyReport {
    let n = trace.samples.len();
    let mut row = vec![0.0; n];
    for r in 0..n_replicas {
        let shift = (r * 7 + 3) % n.max(1);
        for i in 0..n {
            row[i] += trace.samples[(i + shift) % n];
        }
    }
    // Budget provisioned for n_replicas / oversubscription nodes.
    let budget = n_replicas as f64 / oversubscription;
    for p in row.iter_mut() {
        *p /= budget;
    }

    let mut engine = PolicyEngine::new(PolicyKind::Polca, policy_cfg.clone());
    let mut cap_timeline = Vec::new();
    let mut lp_stretch_acc = 0.0;
    let mut hp_stretch_acc = 0.0;
    for (i, &p) in row.iter().enumerate() {
        let t = i as f64 * trace.dt_s;
        let _ = engine.tick(t, p);
        let intent = engine.intent();
        cap_timeline.push((t, intent.lp_cap_mhz, intent.hp_cap_mhz, engine.is_braked()));
        let stretch = |cap: Option<f64>, cf: f64| -> f64 {
            let r = cap.map(|m| m / calib.max_freq_mhz).unwrap_or(1.0);
            cf / r + (1.0 - cf)
        };
        // Weight prompt/token by their rough duty cycle in the trace.
        let mix = 0.1 * prompt_compute_frac + 0.9 * token_compute_frac;
        lp_stretch_acc += stretch(intent.lp_cap_mhz, mix);
        hp_stretch_acc += stretch(intent.hp_cap_mhz, mix);
    }
    ServingPolicyReport {
        row_power: row,
        cap_timeline,
        brake_events: engine.brake_events,
        lp_modeled_stretch: lp_stretch_acc / n as f64,
        hp_modeled_stretch: hp_stretch_acc / n as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_timeline() -> PhaseTimeline {
        PhaseTimeline {
            records: vec![
                PhaseRecord::Prefill(0.0, 0.2, 2048),
                PhaseRecord::Decode(0.2, 0.1, 2),
                PhaseRecord::Decode(0.3, 0.1, 2),
                PhaseRecord::Prefill(0.45, 0.15, 4096),
                PhaseRecord::Decode(0.6, 0.4, 3),
            ],
        }
    }

    #[test]
    fn power_trace_shows_phase_structure() {
        let model = ServerPowerModel::default();
        let trace = timeline_power(&mini_timeline(), &model, 0.05, 1.0);
        let peak = trace.samples.iter().cloned().fold(0.0_f64, f64::max);
        let min = trace.samples.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(peak > min * 1.5, "peak={peak} min={min}");
        // prefill moments are the peak
        let idx_peak = trace.samples.iter().position(|&p| p == peak).unwrap();
        assert!(idx_peak <= (0.2 / 0.05) as usize + 1 || idx_peak >= (0.45 / 0.05) as usize);
    }

    #[test]
    fn time_scale_stretches() {
        let model = ServerPowerModel::default();
        let a = timeline_power(&mini_timeline(), &model, 0.05, 1.0);
        let b = timeline_power(&mini_timeline(), &model, 0.05, 10.0);
        assert!(b.samples.len() > a.samples.len() * 5);
    }

    #[test]
    fn oversubscribed_row_triggers_caps() {
        let model = ServerPowerModel::default();
        let trace = timeline_power(&mini_timeline(), &model, 0.05, 1.0);
        let report = run_policy_over_row(
            &trace,
            40,
            2.2, // extreme oversubscription to force T1/T2
            &PolicyConfig::default(),
            &model.calib,
            0.22,
            0.92,
        );
        let any_cap = report.cap_timeline.iter().any(|(_, lp, _, _)| lp.is_some());
        assert!(any_cap, "expected LP caps under heavy oversubscription");
        assert!(report.lp_modeled_stretch >= report.hp_modeled_stretch);
    }

    #[test]
    fn unsubscribed_row_never_caps() {
        let model = ServerPowerModel::default();
        let trace = timeline_power(&mini_timeline(), &model, 0.05, 1.0);
        let report = run_policy_over_row(
            &trace,
            40,
            0.8, // under-subscribed
            &PolicyConfig::default(),
            &model.calib,
            0.22,
            0.92,
        );
        assert!(report.cap_timeline.iter().all(|(_, lp, hp, b)| lp.is_none() && hp.is_none() && !b));
        assert_eq!(report.brake_events, 0);
        assert!((report.lp_modeled_stretch - 1.0).abs() < 1e-9);
    }
}
