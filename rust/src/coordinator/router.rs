//! Request router: spreads incoming requests across serving replicas.
//!
//! Production LLM fleets put a load balancer in front of the row (§6.3's
//! "typical load balanced setup, reducing the chance of simultaneous
//! capping"). The router is generic over the replica handle so the same
//! policy drives the real [`super::batcher::Coordinator`] nodes and the
//! simulator/tests' mock nodes.

use crate::cluster::hierarchy::Priority;

/// Load view a router needs from a replica.
pub trait Replica {
    /// In-flight + queued work units.
    fn load(&self) -> usize;
    /// Whether the replica can accept another request at all.
    fn accepting(&self) -> bool;
}

/// Routing decision policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Pick the least-loaded accepting replica (ties → lowest index).
    LeastLoaded,
    /// Round-robin over accepting replicas.
    RoundRobin,
}

/// The router: stateless except for the round-robin cursor.
#[derive(Debug, Clone)]
pub struct Router {
    /// The active routing policy.
    pub policy: RoutePolicy,
    cursor: usize,
    /// High-priority requests routed (observability).
    pub routed_hp: u64,
    /// Low-priority requests routed.
    pub routed_lp: u64,
    /// Requests no replica would accept.
    pub unroutable: u64,
}

impl Router {
    /// Router with zeroed counters.
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, cursor: 0, routed_hp: 0, routed_lp: 0, unroutable: 0 }
    }

    /// Pick a replica index for a request, or None if nobody accepts.
    pub fn route<R: Replica>(&mut self, replicas: &[R], priority: Priority) -> Option<usize> {
        let pick = match self.policy {
            RoutePolicy::LeastLoaded => replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.accepting())
                .min_by_key(|(i, r)| (r.load(), *i))
                .map(|(i, _)| i),
            RoutePolicy::RoundRobin => {
                let n = replicas.len();
                (0..n)
                    .map(|k| (self.cursor + k) % n)
                    .find(|&i| replicas[i].accepting())
                    .inspect(|&i| self.cursor = (i + 1) % n)
            }
        };
        match pick {
            Some(i) => {
                match priority {
                    Priority::High => self.routed_hp += 1,
                    Priority::Low => self.routed_lp += 1,
                }
                Some(i)
            }
            None => {
                self.unroutable += 1;
                None
            }
        }
    }
}

impl Replica for super::batcher::Coordinator {
    fn load(&self) -> usize {
        self.pending() + self.active_count()
    }
    fn accepting(&self) -> bool {
        self.pending() < self.max_queue
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    #[derive(Debug)]
    struct Mock {
        load: usize,
        accepting: bool,
    }
    impl Replica for Mock {
        fn load(&self) -> usize {
            self.load
        }
        fn accepting(&self) -> bool {
            self.accepting
        }
    }

    #[test]
    fn least_loaded_picks_min() {
        let replicas = vec![
            Mock { load: 5, accepting: true },
            Mock { load: 2, accepting: true },
            Mock { load: 2, accepting: false },
            Mock { load: 9, accepting: true },
        ];
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        assert_eq!(r.route(&replicas, Priority::High), Some(1));
        assert_eq!(r.routed_hp, 1);
    }

    #[test]
    fn round_robin_skips_full() {
        let replicas = vec![
            Mock { load: 0, accepting: true },
            Mock { load: 0, accepting: false },
            Mock { load: 0, accepting: true },
        ];
        let mut r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.route(&replicas, Priority::Low), Some(0));
        assert_eq!(r.route(&replicas, Priority::Low), Some(2));
        assert_eq!(r.route(&replicas, Priority::Low), Some(0));
        assert_eq!(r.routed_lp, 3);
    }

    #[test]
    fn nobody_accepting_counts_unroutable() {
        let replicas = vec![Mock { load: 0, accepting: false }];
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        assert_eq!(r.route(&replicas, Priority::High), None);
        assert_eq!(r.unroutable, 1);
    }

    /// Property: the router never returns a non-accepting replica, and
    /// least-loaded never returns one with load above the min accepting.
    #[test]
    fn property_routing_validity() {
        testing::check_default(
            "router-validity",
            |r: &mut Rng| {
                let n = r.range_usize(1, 8);
                (0..n)
                    .map(|_| (r.range_usize(0, 20), r.bool(0.7)))
                    .collect::<Vec<_>>()
            },
            |spec| {
                let replicas: Vec<Mock> = spec
                    .iter()
                    .map(|&(load, accepting)| Mock { load, accepting })
                    .collect();
                let mut router = Router::new(RoutePolicy::LeastLoaded);
                match router.route(&replicas, Priority::Low) {
                    Some(i) => {
                        if !replicas[i].accepting {
                            return Err(format!("routed to full replica {i}"));
                        }
                        let min = replicas
                            .iter()
                            .filter(|m| m.accepting)
                            .map(|m| m.load)
                            .min()
                            .unwrap();
                        if replicas[i].load != min {
                            return Err("not least loaded".into());
                        }
                    }
                    None => {
                        if replicas.iter().any(|m| m.accepting) {
                            return Err("failed to route despite capacity".into());
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
