//! The serving loop: continuous batching over the static-shaped decode
//! executable, prefill admission, and a power/phase timeline.
//!
//! Scheduling policy (vLLM-style, adapted to static batch slots):
//!   1. While a KV slot is free and the queue is non-empty, admit the
//!      next request with a prefill call (slot-local, one at a time).
//!   2. Run one batched decode step for all active slots.
//!   3. Retire slots whose request has generated `max_new_tokens` (or
//!      hit the model's max sequence length).
//!
//! Each engine call is recorded on a [`PhaseTimeline`] so the POLCA power
//! machinery can (a) derive the modeled power draw of the serving node
//! and (b) attribute modeled throttling impact. Priorities matter: when
//! a frequency cap targets Low priority, only low-priority requests'
//! modeled time stretches.

use std::collections::VecDeque;

use anyhow::Context;

use crate::cluster::hierarchy::Priority;
use crate::runtime::engine::{Engine, KvState};

use super::kv::SlotManager;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen request id (echoed on the completion).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Decode budget.
    pub max_new_tokens: usize,
    /// Priority class (drives modeled capping impact).
    pub priority: Priority,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// Generated token ids.
    pub tokens: Vec<i32>,
    /// The request's priority class.
    pub priority: Priority,
    /// Wall seconds spent queued before prefill started.
    pub queue_s: f64,
    /// Wall seconds of the prefill call.
    pub prefill_s: f64,
    /// Wall seconds from first decode step to completion.
    pub decode_s: f64,
}

/// One executed phase on the node, for power modeling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PhaseRecord {
    /// (t_start_s, dur_s, prompt_tokens)
    Prefill(f64, f64, usize),
    /// (t_start_s, dur_s, active_batch)
    Decode(f64, f64, usize),
}

/// Timeline of executed phases (monotone in start time).
#[derive(Debug, Clone, Default)]
pub struct PhaseTimeline {
    /// Executed phases in start-time order.
    pub records: Vec<PhaseRecord>,
}

struct Active {
    id: u64,
    priority: Priority,
    tokens: Vec<i32>,
    pos: usize,
    remaining: usize,
    submitted_s: f64,
    prefill_started_s: f64,
    prefill_s: f64,
    decode_started_s: f64,
}

/// The per-node coordinator: queue → slots → engine.
pub struct Coordinator {
    /// The loaded model (compiled executables + weights).
    pub engine: Engine,
    slots: SlotManager,
    queue: VecDeque<(Request, f64)>,
    active: Vec<Option<Active>>,
    kv: Option<KvState>,
    clock: std::time::Instant,
    /// Executed-phase record for power modeling.
    pub timeline: PhaseTimeline,
    /// Finished requests, in completion order.
    pub completions: Vec<Completion>,
    /// Requests rejected at submit (full queue / oversized prompt).
    pub rejected: u64,
    /// Maximum queue length before rejecting (load-shedding).
    pub max_queue: usize,
}

impl Coordinator {
    /// Coordinator over a loaded engine, with an empty KV cache.
    pub fn new(engine: Engine) -> anyhow::Result<Self> {
        let b = engine.manifest.model.batch_slots;
        let kv = engine.empty_kv()?;
        Ok(Coordinator {
            engine,
            slots: SlotManager::new(b),
            queue: VecDeque::new(),
            active: (0..b).map(|_| None).collect(),
            kv: Some(kv),
            clock: std::time::Instant::now(),
            timeline: PhaseTimeline::default(),
            completions: Vec::new(),
            rejected: 0,
            max_queue: 64,
        })
    }

    fn now_s(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// Enqueue a request (rejects when the queue is full or the prompt
    /// exceeds every compiled bucket).
    pub fn submit(&mut self, req: Request) -> bool {
        let fits = self.engine.bucket_for(req.prompt.len()).is_some()
            && req.prompt.len() + req.max_new_tokens <= self.engine.manifest.model.max_seq;
        if !fits || self.queue.len() >= self.max_queue {
            self.rejected += 1;
            return false;
        }
        let now = self.now_s();
        self.queue.push_back((req, now));
        true
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently holding a batch slot.
    pub fn active_count(&self) -> usize {
        self.slots.occupied()
    }

    /// Whether any request is queued or in flight.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.occupied() > 0
    }

    /// One scheduling step. Returns false when fully idle.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        if !self.has_work() {
            return Ok(false);
        }
        // 1. Admit prefills while slots are free.
        while self.slots.available() > 0 && !self.queue.is_empty() {
            let (req, submitted_s) = self.queue.pop_front().unwrap();
            let slot = self.slots.acquire().unwrap();
            let t0 = self.now_s();
            let kv = self.kv.take().context("kv in flight")?;
            let (logits, kv) =
                self.engine.prefill(kv, &req.prompt, req.prompt.len(), slot)?;
            self.kv = Some(kv);
            let dur = self.now_s() - t0;
            self.timeline.records.push(PhaseRecord::Prefill(t0, dur, req.prompt.len()));
            let first = argmax(&logits) as i32;
            let mut tokens = req.prompt.clone();
            tokens.push(first);
            self.active[slot] = Some(Active {
                id: req.id,
                priority: req.priority,
                tokens,
                pos: req.prompt.len(),
                remaining: req.max_new_tokens.saturating_sub(1),
                submitted_s,
                prefill_started_s: t0,
                prefill_s: dur,
                decode_started_s: self.now_s(),
            });
            if self.active[slot].as_ref().unwrap().remaining == 0 {
                self.retire(slot);
            }
        }
        // 2. One batched decode step over all active slots.
        if self.slots.occupied() > 0 {
            let b = self.engine.manifest.model.batch_slots;
            let mut tokens = vec![0i32; b];
            let mut pos = vec![0i32; b];
            let mut active_slots = Vec::new();
            for (slot, a) in self.active.iter().enumerate() {
                if let Some(a) = a {
                    tokens[slot] = *a.tokens.last().unwrap();
                    pos[slot] = a.pos as i32;
                    active_slots.push(slot);
                }
            }
            let t0 = self.now_s();
            let kv = self.kv.take().context("kv in flight")?;
            let (logits, kv) = self.engine.decode(kv, &tokens, &pos)?;
            self.kv = Some(kv);
            let dur = self.now_s() - t0;
            self.timeline.records.push(PhaseRecord::Decode(t0, dur, active_slots.len()));
            for slot in active_slots {
                let next = self.engine.argmax_slot(&logits, slot);
                let a = self.active[slot].as_mut().unwrap();
                a.tokens.push(next);
                a.pos += 1;
                a.remaining -= 1;
                let at_cap = a.tokens.len() >= self.engine.manifest.model.max_seq;
                if a.remaining == 0 || at_cap {
                    self.retire(slot);
                }
            }
        }
        Ok(self.has_work())
    }

    fn retire(&mut self, slot: usize) {
        let a = self.active[slot].take().unwrap();
        let now = self.now_s();
        self.completions.push(Completion {
            id: a.id,
            tokens: a.tokens,
            priority: a.priority,
            queue_s: a.prefill_started_s - a.submitted_s,
            prefill_s: a.prefill_s,
            decode_s: now - a.decode_started_s,
        });
        self.slots.release(slot);
    }

    /// Drive until everything completes; returns completions drained.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<Completion>> {
        while self.step()? {}
        Ok(std::mem::take(&mut self.completions))
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn engine() -> Option<Engine> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::load(&dir).unwrap())
    }

    fn req(id: u64, prompt_len: usize, new: usize, pri: Priority) -> Request {
        Request {
            id,
            prompt: (0..prompt_len as i32).map(|i| (i * 7 + 3) % 512).collect(),
            max_new_tokens: new,
            priority: pri,
        }
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn serves_more_requests_than_slots() {
        let Some(engine) = engine() else { return };
        let slots = engine.manifest.model.batch_slots;
        let mut c = Coordinator::new(engine).unwrap();
        let n = slots + 3;
        for i in 0..n {
            assert!(c.submit(req(i as u64, 8 + i, 5, Priority::High)));
        }
        let done = c.run_to_completion().unwrap();
        assert_eq!(done.len(), n);
        // each produced exactly prompt + 5 tokens
        for d in &done {
            assert_eq!(d.tokens.len() - (8 + d.id as usize), 5);
        }
        // all slots returned
        assert_eq!(c.active_count(), 0);
        assert_eq!(c.rejected, 0);
        // timeline recorded prefills and decodes
        let prefills = c
            .timeline
            .records
            .iter()
            .filter(|r| matches!(r, PhaseRecord::Prefill(..)))
            .count();
        assert_eq!(prefills, n);
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn incremental_decode_matches_prefill_recompute() {
        // Serving correctness: generating k tokens via the KV cache must
        // equal re-running prefill on the extended prompt (greedy path).
        let Some(engine) = engine() else { return };
        let mut c = Coordinator::new(engine).unwrap();
        let prompt: Vec<i32> = vec![5, 99, 203, 41, 17, 350, 12, 8];
        c.submit(Request {
            id: 0,
            prompt: prompt.clone(),
            max_new_tokens: 4,
            priority: Priority::High,
        });
        let done = c.run_to_completion().unwrap();
        let served = done[0].tokens.clone();
        assert_eq!(served.len(), prompt.len() + 4);

        // Recompute the last generated token from scratch via prefill.
        let engine = c.engine;
        let kv = engine.empty_kv().unwrap();
        let prefix = &served[..served.len() - 1];
        let (logits, _) = engine.prefill(kv, prefix, prefix.len(), 0).unwrap();
        let recomputed = argmax(&logits) as i32;
        assert_eq!(recomputed, *served.last().unwrap(), "KV-incremental divergence");
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn rejects_oversized_and_overflow() {
        let Some(engine) = engine() else { return };
        let max_seq = engine.manifest.model.max_seq;
        let mut c = Coordinator::new(engine).unwrap();
        // prompt larger than any bucket
        assert!(!c.submit(req(1, 65, 4, Priority::Low)));
        // prompt + output beyond max_seq
        assert!(!c.submit(req(2, 60, max_seq, Priority::Low)));
        assert_eq!(c.rejected, 2);
        // queue overflow
        c.max_queue = 2;
        assert!(c.submit(req(3, 8, 2, Priority::Low)));
        assert!(c.submit(req(4, 8, 2, Priority::Low)));
        assert!(!c.submit(req(5, 8, 2, Priority::Low)));
        assert_eq!(c.rejected, 3);
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn mixed_priorities_tracked() {
        let Some(engine) = engine() else { return };
        let mut c = Coordinator::new(engine).unwrap();
        c.submit(req(1, 8, 3, Priority::High));
        c.submit(req(2, 8, 3, Priority::Low));
        let done = c.run_to_completion().unwrap();
        assert_eq!(done.iter().filter(|d| d.priority == Priority::High).count(), 1);
        assert_eq!(done.iter().filter(|d| d.priority == Priority::Low).count(), 1);
    }
}
