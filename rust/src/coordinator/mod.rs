//! The serving coordinator: request router, continuous batcher over the
//! static-slot KV cache, and the power adapter that puts POLCA in the
//! loop of the live PJRT serving path (the end-to-end driver of
//! `examples/serve_polca.rs`).

pub mod batcher;
pub mod kv;
pub mod power;
pub mod router;

pub use batcher::{Completion, Coordinator, PhaseRecord, PhaseTimeline, Request};
pub use kv::SlotManager;
pub use power::{run_policy_over_row, timeline_power, NodePowerTrace, ServingPolicyReport};
pub use router::{Replica, RoutePolicy, Router};
