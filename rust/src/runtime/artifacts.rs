//! Artifact bundle parsing: `manifest.json`, `weights.bin`, `*.hlo.txt`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::{self, Json};

/// Model dimensions (mirrors `ModelConfig` in python/compile/model.py).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Maximum sequence length the KV cache supports.
    pub max_seq: usize,
    /// Static batch slots compiled into the executables.
    pub batch_slots: usize,
    /// Per-head width (d_model / n_heads).
    pub d_head: usize,
    /// Total parameter count.
    pub num_params: usize,
}

/// One parameter tensor in `weights.bin`.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    /// Canonical parameter name.
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// Byte offset into `weights.bin`.
    pub byte_offset: usize,
    /// Byte length in `weights.bin`.
    pub byte_len: usize,
}

/// One compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Artifact name (e.g. `prefill_128`).
    pub name: String,
    /// HLO text file within the bundle.
    pub file: String,
    /// Computation kind: `"prefill"` or `"decode"`.
    pub kind: String,
    /// Prompt bucket length (prefill) or 1 (decode).
    pub seq: usize,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Bundle directory.
    pub dir: PathBuf,
    /// Model dimensions.
    pub model: ModelDims,
    /// KV cache tensor shape, exactly as compiled (5-D).
    pub kv_shape: [usize; 5],
    /// Parameter tensors, in canonical feed order.
    pub params: Vec<ParamEntry>,
    /// Compiled computations in the bundle.
    pub artifacts: Vec<ArtifactEntry>,
    /// Analytic FLOPs per artifact (drives the serving power model).
    pub flops: Vec<(String, f64)>,
}

impl Manifest {
    /// Parse `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let need = |j: &Json, path: &[&str]| -> anyhow::Result<f64> {
            j.at(path)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("manifest missing {}", path.join(".")))
        };
        let m = |k: &str| need(&doc, &["model", k]).map(|x| x as usize);
        let model = ModelDims {
            vocab: m("vocab")?,
            d_model: m("d_model")?,
            n_heads: m("n_heads")?,
            n_layers: m("n_layers")?,
            d_ff: m("d_ff")?,
            max_seq: m("max_seq")?,
            batch_slots: m("batch_slots")?,
            d_head: m("d_head")?,
            num_params: m("num_params")?,
        };

        let kv_arr = doc
            .get("kv_shape")
            .and_then(|v| v.as_arr())
            .context("manifest missing kv_shape")?;
        if kv_arr.len() != 5 {
            bail!("kv_shape must have 5 dims, got {}", kv_arr.len());
        }
        let mut kv_shape = [0usize; 5];
        for (i, v) in kv_arr.iter().enumerate() {
            kv_shape[i] = v.as_usize().context("bad kv dim")?;
        }

        let params = doc
            .get("params")
            .and_then(|v| v.as_arr())
            .context("manifest missing params")?
            .iter()
            .map(|p| -> anyhow::Result<ParamEntry> {
                Ok(ParamEntry {
                    name: p.get("name").and_then(|v| v.as_str()).context("param name")?.to_string(),
                    shape: p
                        .get("shape")
                        .and_then(|v| v.as_arr())
                        .context("param shape")?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    byte_offset: p.get("byte_offset").and_then(|v| v.as_usize()).context("offset")?,
                    byte_len: p.get("byte_len").and_then(|v| v.as_usize()).context("len")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let artifacts = doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .context("manifest missing artifacts")?
            .iter()
            .map(|a| -> anyhow::Result<ArtifactEntry> {
                Ok(ArtifactEntry {
                    name: a.get("name").and_then(|v| v.as_str()).context("name")?.to_string(),
                    file: a.get("file").and_then(|v| v.as_str()).context("file")?.to_string(),
                    kind: a.get("kind").and_then(|v| v.as_str()).context("kind")?.to_string(),
                    seq: a.get("seq").and_then(|v| v.as_usize()).unwrap_or(0),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let flops = match doc.get("flops") {
            Some(Json::Obj(map)) => {
                map.iter().filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x))).collect()
            }
            _ => Vec::new(),
        };

        Ok(Manifest { dir: dir.to_path_buf(), model, kv_shape, params, artifacts, flops })
    }

    /// Read `weights.bin` and split into per-parameter f32 vectors
    /// (little-endian on disk).
    pub fn load_weights(&self) -> anyhow::Result<Vec<Vec<f32>>> {
        let raw = std::fs::read(self.dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", self.dir.display()))?;
        let mut out = Vec::with_capacity(self.params.len());
        for p in &self.params {
            let end = p.byte_offset + p.byte_len;
            if end > raw.len() {
                bail!("weights.bin too short for {}", p.name);
            }
            let bytes = &raw[p.byte_offset..end];
            let mut v = Vec::with_capacity(bytes.len() / 4);
            for chunk in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            let expected: usize = p.shape.iter().product::<usize>().max(1);
            if v.len() != expected && !(p.shape.is_empty() && v.len() == 1) {
                bail!("{}: {} elems, expected {}", p.name, v.len(), expected);
            }
            out.push(v);
        }
        Ok(out)
    }

    /// Analytic FLOPs of a named artifact, if recorded.
    pub fn flops_of(&self, name: &str) -> Option<f64> {
        self.flops.iter().find(|(k, _)| k == name).map(|&(_, v)| v)
    }

    /// Total element count of the KV cache tensor.
    pub fn kv_elems(&self) -> usize {
        self.kv_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn parses_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.kv_shape[0], m.model.n_layers);
        assert_eq!(m.kv_shape[1], m.model.batch_slots);
        assert!(m.artifacts.iter().any(|a| a.kind == "decode"));
        assert!(m.artifacts.iter().filter(|a| a.kind == "prefill").count() >= 2);
        assert!(m.flops_of("decode_per_step").unwrap() > 0.0);
    }

    #[test]
    fn loads_weights_consistently() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.params.len());
        let total: usize = w.iter().map(|v| v.len()).sum();
        assert_eq!(total, m.model.num_params);
        // tok_emb comes first and is [vocab, d_model]
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(w[0].len(), m.model.vocab * m.model.d_model);
        // weights are not degenerate
        let nonzero = w[0].iter().filter(|x| **x != 0.0).count();
        assert!(nonzero > w[0].len() / 2);
    }
}
