//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + weights + manifest) and execute them from Rust.
//!
//! Python never runs here — this is the request path. The interchange is
//! HLO *text* (see aot.py for why), compiled once per artifact on the
//! PJRT CPU client at startup and cached.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactEntry, Manifest, ParamEntry};
pub use engine::{Engine, KvState};
