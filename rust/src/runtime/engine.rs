//! The inference engine: compiled prefill/decode executables + weights,
//! with the KV cache round-tripping between calls.
//!
//! Executables are lowered with `return_tuple=True` (the proven
//! interchange path — see /opt/xla-example/README.md), so each call
//! returns one tuple literal that we decompose into
//! (logits, kv_k, kv_v). The KV cache stays in host literals between
//! steps; see EXPERIMENTS.md §Perf for the measured cost and the
//! device-resident alternative.

use std::path::Path;

use anyhow::{bail, Context};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::Manifest;

/// KV cache state for the whole batch (owned by the coordinator).
pub struct KvState {
    /// Key cache literal.
    pub k: Literal,
    /// Value cache literal.
    pub v: Literal,
}

/// A loaded model: PJRT client, compiled executables, weights.
pub struct Engine {
    /// The parsed artifact manifest this engine was loaded from.
    pub manifest: Manifest,
    client: PjRtClient,
    /// (bucket_seq, executable), ascending by bucket.
    prefills: Vec<(usize, PjRtLoadedExecutable)>,
    decode: PjRtLoadedExecutable,
    /// Parameter literals in canonical order (re-fed every call).
    params: Vec<Literal>,
}

impl Engine {
    /// Load + compile everything in an artifact directory.
    pub fn load(dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("PJRT CPU client")?;

        let weights = manifest.load_weights()?;
        let mut params = Vec::with_capacity(weights.len());
        for (entry, data) in manifest.params.iter().zip(&weights) {
            let lit = Literal::vec1(data);
            let dims: Vec<i64> = entry.shape.iter().map(|&d| d as i64).collect();
            params.push(if dims.is_empty() { lit } else { lit.reshape(&dims)? });
        }

        let mut prefills = Vec::new();
        let mut decode = None;
        for art in &manifest.artifacts {
            let path = dir.join(&art.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", art.name))?;
            match art.kind.as_str() {
                "prefill" => prefills.push((art.seq, exe)),
                "decode" => decode = Some(exe),
                other => bail!("unknown artifact kind {other}"),
            }
        }
        prefills.sort_by_key(|&(s, _)| s);
        let decode = decode.context("no decode artifact")?;
        Ok(Engine { manifest, client, prefills, decode, params })
    }

    /// The PJRT client executables run on.
    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Fresh all-zero KV cache.
    pub fn empty_kv(&self) -> anyhow::Result<KvState> {
        let dims: Vec<i64> = self.manifest.kv_shape.iter().map(|&d| d as i64).collect();
        let zeros = vec![0f32; self.manifest.kv_elems()];
        Ok(KvState {
            k: Literal::vec1(&zeros).reshape(&dims)?,
            v: Literal::vec1(&zeros).reshape(&dims)?,
        })
    }

    /// Smallest compiled prompt bucket that fits `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefills.iter().map(|&(s, _)| s).find(|&s| s >= len)
    }

    /// All compiled prompt bucket lengths, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        self.prefills.iter().map(|&(s, _)| s).collect()
    }

    fn run_tuple3(
        &self,
        exe: &PjRtLoadedExecutable,
        args: &[&Literal],
    ) -> anyhow::Result<(Literal, Literal, Literal)> {
        let result = exe.execute::<&Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple3()?)
    }

    /// Run prefill for one request occupying `slot`; returns next-token
    /// logits `[vocab]` and the updated KV.
    pub fn prefill(
        &self,
        kv: KvState,
        tokens: &[i32],
        length: usize,
        slot: usize,
    ) -> anyhow::Result<(Vec<f32>, KvState)> {
        if length == 0 || length > tokens.len() {
            bail!("bad length {length} for {} tokens", tokens.len());
        }
        if slot >= self.manifest.model.batch_slots {
            bail!("slot {slot} out of range");
        }
        let bucket = self
            .bucket_for(tokens.len())
            .with_context(|| format!("prompt of {} tokens exceeds buckets", tokens.len()))?;
        let exe = &self.prefills.iter().find(|&&(s, _)| s == bucket).unwrap().1;
        // Pad tokens up to the bucket.
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);
        let tokens_lit = Literal::vec1(&padded);
        let len_lit = Literal::scalar(length as i32);
        let slot_lit = Literal::scalar(slot as i32);

        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&kv.k);
        args.push(&kv.v);
        args.push(&tokens_lit);
        args.push(&len_lit);
        args.push(&slot_lit);

        let (logits, k, v) = self.run_tuple3(exe, &args)?;
        Ok((logits.to_vec::<f32>()?, KvState { k, v }))
    }

    /// Run one decode step for all batch slots; `tokens[b]`/`pos[b]` are
    /// ignored garbage for inactive slots. Returns flat logits
    /// `[batch_slots * vocab]` and the updated KV.
    pub fn decode(
        &self,
        kv: KvState,
        tokens: &[i32],
        pos: &[i32],
    ) -> anyhow::Result<(Vec<f32>, KvState)> {
        let b = self.manifest.model.batch_slots;
        if tokens.len() != b || pos.len() != b {
            bail!("decode arrays must have {} slots", b);
        }
        let tokens_lit = Literal::vec1(tokens);
        let pos_lit = Literal::vec1(pos);
        let mut args: Vec<&Literal> = self.params.iter().collect();
        args.push(&kv.k);
        args.push(&kv.v);
        args.push(&tokens_lit);
        args.push(&pos_lit);
        let (logits, k, v) = self.run_tuple3(&self.decode, &args)?;
        Ok((logits.to_vec::<f32>()?, KvState { k, v }))
    }

    /// Argmax over one slot's logits slice.
    pub fn argmax_slot(&self, flat_logits: &[f32], slot: usize) -> i32 {
        let v = self.manifest.model.vocab;
        let slice = &flat_logits[slot * v..(slot + 1) * v];
        let mut best = 0usize;
        for (i, &x) in slice.iter().enumerate() {
            if x > slice[best] {
                best = i;
            }
        }
        best as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    /// Full cross-layer round trip: the Rust PJRT path must reproduce the
    /// JAX golden outputs (prefill logits, argmax, decode logits).
    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn golden_roundtrip() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        let golden_text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
        let g = json::parse(&golden_text).unwrap();

        let tokens: Vec<i32> = g
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as i32)
            .collect();
        let length = g.get("length").unwrap().as_usize().unwrap();
        let slot = g.get("slot").unwrap().as_usize().unwrap();

        let kv = engine.empty_kv().unwrap();
        let (logits, kv) = engine.prefill(kv, &tokens, length, slot).unwrap();

        let expect_head: Vec<f64> = g
            .get("prefill_logits_head")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        for (i, &e) in expect_head.iter().enumerate() {
            assert!(
                (logits[i] as f64 - e).abs() < 1e-3,
                "prefill logit {i}: got {} want {e}",
                logits[i]
            );
        }
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(argmax as i64, g.get("prefill_argmax").unwrap().as_i64().unwrap());

        // decode step
        let d_tokens: Vec<i32> = g
            .get("decode_tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as i32)
            .collect();
        let d_pos: Vec<i32> = g
            .get("decode_pos")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap() as i32)
            .collect();
        let (dlogits, _kv) = engine.decode(kv, &d_tokens, &d_pos).unwrap();
        let d_expect: Vec<f64> = g
            .get("decode_logits_head")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let vocab = engine.manifest.model.vocab;
        for (i, &e) in d_expect.iter().enumerate() {
            let got = dlogits[slot * vocab + i] as f64;
            assert!((got - e).abs() < 1e-3, "decode logit {i}: got {got} want {e}");
        }
        assert_eq!(
            engine.argmax_slot(&dlogits, slot) as i64,
            g.get("decode_argmax").unwrap().as_i64().unwrap()
        );
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        let buckets = engine.buckets();
        assert_eq!(buckets, vec![16, 64]);
        assert_eq!(engine.bucket_for(5), Some(16));
        assert_eq!(engine.bucket_for(16), Some(16));
        assert_eq!(engine.bucket_for(17), Some(64));
        assert_eq!(engine.bucket_for(65), None);
    }

    #[test]
    #[ignore = "environment-dependent: needs AOT artifacts and a real PJRT-backed `xla` crate (vendor/xla is a stub)"]
    fn prefill_rejects_bad_args() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let engine = Engine::load(&dir).unwrap();
        let kv = engine.empty_kv().unwrap();
        assert!(engine.prefill(kv, &[1, 2, 3], 0, 0).is_err());
        let kv = engine.empty_kv().unwrap();
        assert!(engine.prefill(kv, &[1, 2, 3], 2, 99).is_err());
    }
}
