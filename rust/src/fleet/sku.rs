//! GPU/server SKU registry: heterogeneous hardware generations layered
//! over [`GpuPowerCalib`] and [`ServerPowerModel`].
//!
//! The paper's testbed is homogeneous (DGX-A100-80GB). Real sites mix
//! generations — A100 rows bought in one budget cycle next to H100 rows
//! from the next ("Hybrid Heterogeneous Clusters Can Lower the Energy
//! Consumption of LLM Inference Workloads"). A [`SkuSpec`] captures what
//! changes between generations while reusing the paper's *shape*
//! calibration (prompt-spike vs token-plateau anchors are properties of
//! the model/workload, expressed as fractions of aggregate GPU TDP):
//!
//!   * aggregate GPU TDP (A100 SXM: 8×400 W; H100 SXM: 8×700 W),
//!   * max SM clock (A100: 1410 MHz; H100: 1980 MHz) — the policy's
//!     absolute cap setpoints (Table 3) scale with it,
//!   * a throughput multiplier vs the A100 latency anchors,
//!   * host power growth (denser CPUs/fans/PSUs on newer hosts),
//!   * idle fraction (newer parts idle slightly leaner).

use crate::config::PolicyConfig;
use crate::power::gpu::GpuPowerCalib;
use crate::power::server::ServerPowerModel;
use crate::power::training::{TrainingPowerModel, TrainingProfile};

/// The A100 max SM clock every Table-3 setpoint is expressed against.
pub const A100_MAX_FREQ_MHZ: f64 = 1410.0;

/// One server SKU (GPU generation + host).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SkuSpec {
    /// SKU name (registry key).
    pub name: &'static str,
    /// GPU part this SKU carries.
    pub gpu: &'static str,
    /// TDP per GPU, watts.
    pub gpu_tdp_each_w: f64,
    /// GPUs per server.
    pub n_gpus: usize,
    /// Max SM clock, MHz.
    pub max_freq_mhz: f64,
    /// Serving-throughput multiplier vs the A100 latency anchors.
    pub perf_mult: f64,
    /// Multiplier on the non-GPU component budget (Fig 2 rows).
    pub host_power_mult: f64,
    /// Idle draw as a fraction of aggregate GPU TDP.
    pub idle_frac: f64,
}

impl SkuSpec {
    /// Clock scale vs the A100 reference (policy setpoints multiply by this).
    pub fn freq_scale(&self) -> f64 {
        self.max_freq_mhz / A100_MAX_FREQ_MHZ
    }

    /// The SKU's power calibration: the workload's shape anchors with
    /// this generation's idle floor and clock ceiling.
    pub fn calib(&self, base: GpuPowerCalib) -> GpuPowerCalib {
        GpuPowerCalib { idle_frac: self.idle_frac, max_freq_mhz: self.max_freq_mhz, ..base }
    }

    /// Full server power model for this SKU.
    pub fn server_model(&self, base: GpuPowerCalib) -> ServerPowerModel {
        let mut m = ServerPowerModel::default();
        m.gpu_tdp_each_w = self.gpu_tdp_each_w;
        m.n_gpus = self.n_gpus;
        for c in &mut m.components {
            c.provisioned_w *= self.host_power_mult;
        }
        m.calib = self.calib(base);
        m
    }

    /// Provisioned (breaker-facing) watts per server of this SKU.
    pub fn provisioned_w(&self, base: GpuPowerCalib) -> f64 {
        self.server_model(base).provisioned_w()
    }

    /// Training power model for this SKU: the §2.4 iteration waveform
    /// driven through this generation's calibration, so cap setpoints
    /// (scaled by [`Self::scale_policy`]) reclaim the same *fraction*
    /// of training power on every SKU and iteration-time stretch stays
    /// ratio-consistent across a heterogeneous site.
    ///
    /// This is the standalone (offline-analysis) form of the binding
    /// the simulator performs itself: a mixed-row simulation attaches
    /// the waveform to its server model's calibration, which for fleet
    /// clusters *is* [`Self::calib`] via
    /// [`crate::fleet::site::ClusterSpec::sim_config`] — the
    /// calibration-equality invariant is pinned by this module's tests.
    pub fn training_model(
        &self,
        base: GpuPowerCalib,
        profile: TrainingProfile,
    ) -> TrainingPowerModel {
        TrainingPowerModel::with_calib(profile, self.calib(base))
    }

    /// Rescale a policy's absolute SM-clock setpoints (expressed for the
    /// A100 in Table 3) to this SKU's clock domain, preserving ratios —
    /// a 1110/1410 cap on an A100 row becomes 1559/1980 on an H100 row.
    pub fn scale_policy(&self, p: &mut PolicyConfig) {
        let s = self.freq_scale();
        p.lp_freq_t1_mhz *= s;
        p.lp_freq_t2_mhz *= s;
        p.hp_freq_t2_mhz *= s;
        p.brake_freq_mhz *= s;
        p.max_freq_mhz *= s;
    }
}

/// All known SKUs. `dgx-a100` reproduces the paper's testbed exactly;
/// `hgx-mixed` models a retrofit chassis carrying both generations
/// (homogenized per-GPU averages — coarse, but it keeps the row-level
/// power envelope right, which is what provisioning sees).
pub fn registry() -> Vec<SkuSpec> {
    vec![
        SkuSpec {
            name: "dgx-a100",
            gpu: "A100-SXM-80GB",
            gpu_tdp_each_w: 400.0,
            n_gpus: 8,
            max_freq_mhz: 1410.0,
            perf_mult: 1.0,
            host_power_mult: 1.0,
            idle_frac: 0.20,
        },
        SkuSpec {
            name: "hgx-h100",
            gpu: "H100-SXM",
            gpu_tdp_each_w: 700.0,
            n_gpus: 8,
            max_freq_mhz: 1980.0,
            perf_mult: 2.3,
            host_power_mult: 1.18,
            idle_frac: 0.17,
        },
        SkuSpec {
            name: "hgx-mixed",
            gpu: "4xA100 + 4xH100",
            gpu_tdp_each_w: 550.0,
            n_gpus: 8,
            max_freq_mhz: 1695.0,
            perf_mult: 1.6,
            host_power_mult: 1.10,
            idle_frac: 0.185,
        },
    ]
}

/// Look a SKU up by name.
pub fn find(name: &str) -> Option<SkuSpec> {
    registry().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::gpu::{CapMode, Phase};

    fn base() -> GpuPowerCalib {
        GpuPowerCalib::default()
    }

    #[test]
    fn registry_names_unique_and_findable() {
        let names: Vec<_> = registry().iter().map(|s| s.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        for n in names {
            assert!(find(n).is_some(), "{n}");
        }
        assert!(find("dgx-h200").is_none());
    }

    #[test]
    fn a100_sku_matches_paper_server_model() {
        // The reference SKU must reproduce the seed ServerPowerModel.
        let m = find("dgx-a100").unwrap().server_model(base());
        let d = ServerPowerModel::default();
        assert_eq!(m, d);
    }

    #[test]
    fn h100_draws_more_and_runs_faster() {
        let a = find("dgx-a100").unwrap();
        let h = find("hgx-h100").unwrap();
        assert!(h.provisioned_w(base()) > a.provisioned_w(base()) * 1.3);
        assert!(h.perf_mult > 2.0);
        // per-watt efficiency still improves: perf grows faster than power
        let power_ratio = h.provisioned_w(base()) / a.provisioned_w(base());
        assert!(h.perf_mult > power_ratio, "H100 must win on perf/W");
    }

    #[test]
    fn policy_scaling_preserves_cap_ratios() {
        let h = find("hgx-h100").unwrap();
        let mut p = PolicyConfig::default();
        let lp_t2_ratio = p.lp_freq_t2_mhz / p.max_freq_mhz;
        h.scale_policy(&mut p);
        assert_eq!(p.max_freq_mhz, h.max_freq_mhz);
        assert!((p.lp_freq_t2_mhz / p.max_freq_mhz - lp_t2_ratio).abs() < 1e-12);
    }

    #[test]
    fn scaled_cap_reclaims_same_power_fraction() {
        // A T2 LP cap must shave the same fraction of peak GPU power on
        // every SKU: the calibration is ratio-based, so capping to
        // 1110/1410 of max on H100 equals capping 1110 MHz on A100.
        let base_c = base();
        let phase_peak = base_c.prompt_peak_frac(8192.0);
        let mut reductions = Vec::new();
        for sku in registry() {
            let c = sku.calib(base_c);
            let mut p = PolicyConfig::default();
            sku.scale_policy(&mut p);
            let capped = c.apply_freq(phase_peak, p.lp_freq_t2_mhz);
            reductions.push(1.0 - capped / phase_peak);
        }
        for r in &reductions[1..] {
            // idle floors differ slightly between SKUs, so allow 2%
            assert!((r - reductions[0]).abs() < 0.02, "{reductions:?}");
        }
    }

    #[test]
    fn training_stretch_is_ratio_consistent_across_skus() {
        // A scaled T2 cap must stretch a training iteration by the same
        // factor on every generation (caps preserve clock ratios).
        let profile = TrainingProfile::large_llm();
        let mut stretches = Vec::new();
        for sku in registry() {
            let tm = sku.training_model(base(), profile);
            let mut p = PolicyConfig::default();
            sku.scale_policy(&mut p);
            let stretched = tm.iter_time_s(CapMode::FreqCap { mhz: p.lp_freq_t2_mhz });
            stretches.push(stretched / tm.iter_time_s(CapMode::None));
        }
        for s in &stretches[1..] {
            assert!((s - stretches[0]).abs() < 1e-9, "{stretches:?}");
        }
        assert!(stretches[0] > 1.1, "T2 cap must visibly stretch iterations");
    }

    #[test]
    fn training_model_calib_matches_simulator_binding() {
        // The simulator builds its training model from the cluster's
        // server-model calibration; training_model must be the same
        // binding, or offline analysis would diverge from simulation.
        let profile = TrainingProfile::large_llm();
        for sku in registry() {
            let tm = sku.training_model(base(), profile);
            assert_eq!(tm.calib, sku.server_model(base()).calib, "{}", sku.name);
            assert_eq!(tm.profile, profile);
        }
    }

    #[test]
    fn sku_server_power_ordering_holds() {
        for sku in registry() {
            let m = sku.server_model(base());
            let idle = m.server_power_w(Phase::Idle, CapMode::None, false);
            let prompt =
                m.server_power_w(Phase::Prompt { total_input: 4096.0 }, CapMode::None, false);
            assert!(idle < prompt, "{}", sku.name);
            assert!(prompt <= m.provisioned_w() * 1.02, "{}", sku.name);
        }
    }
}
