//! Fleet layer: from one cluster to a datacenter site.
//!
//! The paper evaluates POLCA one row (cluster) at a time; the deployment
//! decision providers face is site-level — many heterogeneous clusters
//! behind shared feeds, a UPS, and one substation. This subsystem
//! composes the existing per-cluster simulator into that picture:
//!
//! * [`sku`] — GPU/server SKU registry (A100/H100-class and a
//!   mixed-generation chassis) layered over
//!   [`crate::power::gpu::GpuPowerCalib`], so clusters can differ in
//!   silicon while sharing the paper's workload-shape calibration.
//! * [`site`] — site topology (clusters → feeds → UPS → substation) and
//!   compositional trace aggregation with per-cluster diurnal phase
//!   offsets (site trace == sum of cluster traces at zero offset).
//! * [`parallel`] — concurrent site evaluation on scoped threads with
//!   deterministic per-cluster seeds (bit-identical to serial).
//! * [`planner`] — per-policy binary search for the max deployable
//!   servers under the substation budget, reporting headroom, cap-event
//!   rates, and SLO impact via [`crate::metrics::ImpactSummary`].
//! * [`trace`] — first-class power traces ([`trace::PowerTrace`]) with
//!   closed-form composition operators (`sum`/`scale`/`shift_phase`/
//!   `mix`); [`site::compose`] is derived from them bit-identically.
//! * [`region`] — many sites under one shared grid budget: archetype
//!   simulation cache + analytic trace composition gives a planner
//!   whose cost is independent of site count, cross-validated against
//!   full simulation by [`region::validate_region`].
//!
//! Mixed workloads thread through every layer: a cluster can colocate a
//! training fraction ([`site::ClusterSpec::training_fraction`],
//! [`site::SiteSpec::with_training`]); the SKU's calibration reaches
//! the training waveform through the cluster's server model (the
//! simulator binds the waveform to `server_model.calib` —
//! [`sku::SkuSpec::training_model`] is the standalone form of that same
//! binding for offline analysis); and the planner answers "how many
//! servers fit if X% of the row is training?" via
//! [`planner::plan_site_with_training`].
//!
//! CLI: `polca fleet [plan|sweep|trace] --clusters N --policy polca
//! [--training FRAC]` and `polca fleet region [plan|trace|validate]
//! --sites N`.

pub mod parallel;
pub mod planner;
pub mod region;
pub mod site;
pub mod sku;
pub mod trace;

pub use parallel::{run_site, ClusterOutcome, SiteOutcome, SiteRunConfig};
pub use planner::{plan_all, plan_site, plan_site_with_training, PlannerConfig, PolicyPlan};
pub use region::{
    plan_region, validate_region, ArchetypeCache, RegionPlan, RegionPlanConfig, RegionSite,
    RegionSpec, RegionValidation,
};
pub use site::{compose, ClusterSpec, Feed, SiteSpec, SiteTrace};
pub use sku::SkuSpec;
pub use trace::{PowerTrace, TraceSummary};
