//! Parallel site execution: the per-cluster simulations of one site are
//! independent discrete-event runs, so a site evaluation fans them out
//! through the shared scenario executor ([`crate::exec::run_batch`]) —
//! near-linear speedup on the planner's inner loop (see
//! `benches/bench_fleet.rs`).
//!
//! Determinism contract: per-cluster seeds are derived *serially* from
//! the site seed with [`crate::util::rng::Rng::fork`] before any thread
//! is spawned, and the executor returns results in cluster order
//! regardless of scheduling — the result is bit-identical to the serial
//! path (tested in `tests/integration_fleet.rs`). This module is where
//! the executor's scoped-thread / pre-allocated-slot pattern was first
//! proven before `exec` generalized it to every batch surface.

use crate::config::SloConfig;
use crate::exec::{run_batch, ExecConfig};
use crate::faults::{ContainmentSlo, FaultPlan};
use crate::metrics::{ImpactSummary, RunReport};
use crate::policy::engine::PolicyKind;
use crate::simulation::run_with_impact;
use crate::util::rng::Rng;

use super::site::{compose, SiteSpec, SiteTrace};

/// How to execute one site evaluation.
#[derive(Debug, Clone)]
pub struct SiteRunConfig {
    /// Simulated horizon in weeks.
    pub weeks: f64,
    /// Site seed; per-cluster seeds derive via [`cluster_seeds`].
    pub seed: u64,
    /// Power-series sampling period for trace composition, seconds.
    pub sample_s: f64,
    /// Run clusters on scoped threads (false = serial reference path).
    pub parallel: bool,
    /// Fault plan replayed inside *every* cluster of the site (`None` =
    /// the clean control plane; see [`crate::faults`]).
    pub faults: Option<FaultPlan>,
    /// Containment-escalation setting forwarded to every cluster's
    /// policy engine (`None` = paper behavior).
    pub brake_escalation_s: Option<f64>,
}

impl Default for SiteRunConfig {
    fn default() -> Self {
        SiteRunConfig {
            weeks: 0.1,
            seed: 1,
            sample_s: 60.0,
            parallel: true,
            faults: None,
            brake_escalation_s: None,
        }
    }
}

/// One cluster's result within a site run.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    /// Cluster name (from its [`crate::fleet::site::ClusterSpec`]).
    pub name: String,
    /// The derived seed this cluster ran with.
    pub seed: u64,
    /// Breaker budget, watts.
    pub budget_w: f64,
    /// The cluster simulation's full report.
    pub report: RunReport,
    /// Latency/brake impact vs the cluster's unthrottled baseline.
    pub impact: ImpactSummary,
}

/// A full site evaluation: per-cluster outcomes + the composed trace.
#[derive(Debug, Clone)]
pub struct SiteOutcome {
    /// Per-cluster outcomes, in site order.
    pub clusters: Vec<ClusterOutcome>,
    /// The composed site power trace.
    pub trace: SiteTrace,
    /// Peak site draw seen at the substation (W), after UPS losses.
    pub substation_peak_w: f64,
    /// Substation budget (W).
    pub substation_budget_w: f64,
    /// Per feed: (name, peak draw W, capacity W).
    pub feed_peaks_w: Vec<(String, f64, f64)>,
}

impl SiteOutcome {
    /// Every electrical level within budget (feeds and substation).
    pub fn within_power_budget(&self) -> bool {
        self.substation_peak_w <= self.substation_budget_w
            && self.feed_peaks_w.iter().all(|(_, peak, cap)| peak <= cap)
    }

    /// Every cluster's latency/brake impact within the SLOs.
    pub fn meets_slos(&self, slo: &SloConfig) -> bool {
        self.clusters.iter().all(|c| c.impact.meets_slo(slo))
    }

    /// Deployable means both electrically safe and SLO-clean.
    pub fn feasible(&self, slo: &SloConfig) -> bool {
        self.within_power_budget() && self.meets_slos(slo)
    }

    /// Brake engagements summed across clusters.
    pub fn total_brakes(&self) -> u64 {
        self.clusters.iter().map(|c| c.report.brake_events).sum()
    }

    /// Slow-path cap engagements summed across clusters.
    pub fn total_cap_commands(&self) -> u64 {
        self.clusters.iter().map(|c| c.report.cap_commands).sum()
    }

    /// Worst per-cluster HP P99 latency impact.
    pub fn worst_hp_p99(&self) -> f64 {
        self.clusters.iter().map(|c| c.impact.hp_p99).fold(0.0, f64::max)
    }

    /// Worst per-cluster LP P99 latency impact.
    pub fn worst_lp_p99(&self) -> f64 {
        self.clusters.iter().map(|c| c.impact.lp_p99).fold(0.0, f64::max)
    }

    /// Worst per-cluster budget-violation seconds (ground truth).
    pub fn worst_violation_s(&self) -> f64 {
        self.clusters.iter().map(|c| c.report.resilience.violation_s).fold(0.0, f64::max)
    }

    /// Worst per-cluster incident time-to-contain (infinite if any
    /// cluster left any incident uncontained).
    pub fn worst_time_to_contain_s(&self) -> f64 {
        self.clusters
            .iter()
            .map(|c| c.report.resilience.worst_time_to_contain_s())
            .fold(0.0, f64::max)
    }

    /// Worst per-cluster peak overshoot as a fraction of that cluster's
    /// breaker budget.
    pub fn worst_overshoot_frac(&self) -> f64 {
        self.clusters
            .iter()
            .map(|c| c.report.resilience.peak_overshoot_w / c.budget_w)
            .fold(0.0, f64::max)
    }

    /// Whether every cluster's fault containment stays within the SLO
    /// (the fault-mode analogue of [`SiteOutcome::feasible`]).
    pub fn meets_containment(&self, cslo: &ContainmentSlo) -> bool {
        self.worst_violation_s() <= cslo.max_violation_s
            && self.worst_time_to_contain_s() <= cslo.max_time_to_contain_s
            && self.worst_overshoot_frac() <= cslo.max_overshoot_frac
    }

    /// Cap engagements per simulated day across the site.
    pub fn cap_events_per_day(&self) -> f64 {
        let dur_s = self.clusters.first().map(|c| c.report.duration_s).unwrap_or(0.0);
        if dur_s <= 0.0 {
            return 0.0;
        }
        self.total_cap_commands() as f64 / (dur_s / 86_400.0)
    }
}

/// Deterministic per-cluster seeds, derived serially from the site seed.
pub fn cluster_seeds(site_seed: u64, n: usize) -> Vec<u64> {
    let mut root = Rng::new(site_seed ^ 0xF1EE_7C1D_5EED_0001);
    (0..n).map(|i| root.fork(i as u64).next_u64()).collect()
}

/// Evaluate a site under one policy: run every cluster (concurrently if
/// asked), then compose the site trace and check the topology budgets.
pub fn run_site(site: &SiteSpec, policy: PolicyKind, rc: &SiteRunConfig) -> SiteOutcome {
    let n = site.clusters.len();
    let seeds = cluster_seeds(rc.seed, n);
    let sims: Vec<_> = site
        .clusters
        .iter()
        .zip(&seeds)
        .map(|(c, &seed)| {
            let mut cfg = c.sim_config(policy, rc.weeks, seed, rc.sample_s);
            cfg.faults = rc.faults.clone();
            cfg.brake_escalation_s = rc.brake_escalation_s;
            cfg
        })
        .collect();

    let results: Vec<(RunReport, ImpactSummary)> =
        run_batch(&sims, &ExecConfig::with_parallel(rc.parallel), |_, sim| run_with_impact(sim));

    let budgets: Vec<f64> = site.clusters.iter().map(|c| c.budget_w()).collect();
    // Phase offsets were realized inside each cluster's arrival process
    // (sim_config sets diurnal_phase_s), so the traces are already in
    // site time — compose without rotation.
    let offsets = vec![0.0; n];
    let mut clusters = Vec::with_capacity(n);
    let mut series = Vec::with_capacity(n);
    for (i, (report, impact)) in results.into_iter().enumerate() {
        series.push(report.power_series.clone());
        clusters.push(ClusterOutcome {
            name: site.clusters[i].name.clone(),
            seed: seeds[i],
            budget_w: budgets[i],
            report,
            impact,
        });
    }
    let trace = compose(&series, &budgets, &offsets, rc.sample_s);
    let substation_peak_w = trace.peak_w() / site.ups_efficiency;
    let feed_peaks_w = site
        .feeds
        .iter()
        .map(|f| (f.name.clone(), trace.peak_of(&f.clusters), f.capacity_w))
        .collect();
    SiteOutcome {
        clusters,
        trace,
        substation_peak_w,
        substation_budget_w: site.substation_budget_w,
        feed_peaks_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        let a = cluster_seeds(42, 8);
        let b = cluster_seeds(42, 8);
        assert_eq!(a, b);
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), a.len(), "colliding cluster seeds: {a:?}");
        // longer derivations share the common prefix
        let c = cluster_seeds(42, 4);
        assert_eq!(&a[..4], &c[..]);
        assert_ne!(cluster_seeds(43, 4), c);
    }
}
