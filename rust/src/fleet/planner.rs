//! Site-level capacity planner: how many servers fit under a shared
//! substation budget, per power-management policy?
//!
//! This is the operator-facing question POLCA answers ("30% more servers
//! in the same cluster") lifted to the deployment decision providers
//! actually face: a site of heterogeneous clusters behind one substation.
//! For each [`PolicyKind`] the planner binary-searches the largest
//! uniform added-server fraction for which the site is *deployable*:
//!
//!   * every cluster meets the Table-5 SLOs (incl. zero powerbrakes),
//!   * the composed site trace stays under every feed capacity and the
//!     substation budget (after UPS losses).
//!
//! Feasibility is monotone in load to numerical noise (more servers →
//! more power and more capping), which is what makes the binary search
//! sound; the step resolution bounds how much non-monotonicity at the
//! SLO edge can matter.
//!
//! Cost note: each probe pairs every cluster's policy run with its
//! unprotected baseline (`run_with_impact`), and the baseline depends
//! only on the load level, not the policy — so `plan_all` recomputes
//! identical baselines across policies at shared probe points (0 and
//! `max_added_pct` always). A cross-policy baseline memo would roughly
//! halve full-depth planning time; deferred to a perf pass.

use crate::config::SloConfig;
use crate::faults::{ContainmentSlo, FaultPlan};
use crate::policy::engine::PolicyKind;

use super::parallel::{run_site, SiteOutcome, SiteRunConfig};
use super::site::SiteSpec;

/// Planner search parameters.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Simulated horizon per probe, weeks.
    pub weeks: f64,
    /// Site seed (per-cluster seeds derive from it).
    pub seed: u64,
    /// Power-series sampling period for trace composition, seconds.
    pub sample_s: f64,
    /// Fan clusters out on scoped threads.
    pub parallel: bool,
    /// Search ceiling for the added fraction, in percent.
    pub max_added_pct: u32,
    /// Search resolution, in percentage points (≥ 1).
    pub step_pct: u32,
    /// SLOs each probe must hold to count as deployable.
    pub slo: SloConfig,
    /// Containment escalation forwarded to every probe's policy engines
    /// (`None` = paper behavior; fault-mode planning typically enables
    /// it so cap-ignore faults escalate to the brake).
    pub brake_escalation_s: Option<f64>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            weeks: 0.08,
            seed: 1,
            sample_s: 60.0,
            parallel: true,
            max_added_pct: 50,
            step_pct: 2,
            slo: SloConfig::default(),
            brake_escalation_s: None,
        }
    }
}

/// The planner's answer for one policy.
#[derive(Debug, Clone)]
pub struct PolicyPlan {
    /// The policy this plan was searched under.
    pub policy: PolicyKind,
    /// Largest added fraction (percent) found deployable; 0 with
    /// `feasible == false` means even the baseline failed.
    pub added_pct: u32,
    /// Whether any probed point was deployable at all.
    pub feasible: bool,
    /// Provisioned server count of the site.
    pub baseline_servers: usize,
    /// Deployed servers at the chosen point.
    pub deployable_servers: usize,
    /// Site peak at the substation at the chosen point (W).
    pub site_peak_w: f64,
    /// Substation budget (W).
    pub substation_budget_w: f64,
    /// Substation headroom remaining at the chosen point.
    pub headroom_frac: f64,
    /// Brake engagements across the site at the chosen point.
    pub brake_events: u64,
    /// Slow-path cap engagements per simulated day at the chosen point.
    pub cap_events_per_day: f64,
    /// Worst per-cluster HP P99 latency impact at the chosen point.
    pub worst_hp_p99: f64,
    /// Worst per-cluster LP P99 latency impact at the chosen point.
    pub worst_lp_p99: f64,
    /// The full evaluation at the chosen point.
    pub outcome: SiteOutcome,
}

/// Evaluate the site at one uniform added level (percent), optionally
/// replaying a fault plan inside every cluster.
pub fn evaluate_added_with_faults(
    site: &SiteSpec,
    policy: PolicyKind,
    added_pct: u32,
    pc: &PlannerConfig,
    faults: Option<&FaultPlan>,
) -> SiteOutcome {
    let scaled = site.with_added(added_pct as f64 / 100.0);
    let rc = SiteRunConfig {
        weeks: pc.weeks,
        seed: pc.seed,
        sample_s: pc.sample_s,
        parallel: pc.parallel,
        faults: faults.cloned(),
        brake_escalation_s: pc.brake_escalation_s,
    };
    run_site(&scaled, policy, &rc)
}

/// Evaluate the site at one uniform added level (percent).
pub fn evaluate_added(
    site: &SiteSpec,
    policy: PolicyKind,
    added_pct: u32,
    pc: &PlannerConfig,
) -> SiteOutcome {
    evaluate_added_with_faults(site, policy, added_pct, pc, None)
}

fn plan_from(
    site: &SiteSpec,
    policy: PolicyKind,
    added_pct: u32,
    feasible: bool,
    outcome: SiteOutcome,
) -> PolicyPlan {
    let scaled = site.with_added(added_pct as f64 / 100.0);
    PolicyPlan {
        policy,
        added_pct,
        feasible,
        baseline_servers: site.baseline_servers(),
        deployable_servers: scaled.deployed_servers(),
        site_peak_w: outcome.substation_peak_w,
        substation_budget_w: outcome.substation_budget_w,
        headroom_frac: 1.0 - outcome.substation_peak_w / outcome.substation_budget_w,
        brake_events: outcome.total_brakes(),
        cap_events_per_day: outcome.cap_events_per_day(),
        worst_hp_p99: outcome.worst_hp_p99(),
        worst_lp_p99: outcome.worst_lp_p99(),
        outcome,
    }
}

/// Binary-search the max deployable added fraction for one policy.
///
/// ```
/// use polca::fleet::planner::{plan_site, PlannerConfig};
/// use polca::fleet::site::SiteSpec;
/// use polca::policy::engine::PolicyKind;
///
/// let site = SiteSpec::demo(1);
/// let pc = PlannerConfig {
///     weeks: 0.005,
///     max_added_pct: 10,
///     step_pct: 10,
///     parallel: false,
///     ..Default::default()
/// };
/// let plan = plan_site(&site, PolicyKind::NoCap, &pc);
/// assert_eq!(plan.baseline_servers, site.baseline_servers());
/// assert!(plan.added_pct <= pc.max_added_pct);
/// ```
pub fn plan_site(site: &SiteSpec, policy: PolicyKind, pc: &PlannerConfig) -> PolicyPlan {
    let step = pc.step_pct.max(1);
    let o0 = evaluate_added(site, policy, 0, pc);
    if !o0.feasible(&pc.slo) {
        return plan_from(site, policy, 0, false, o0);
    }
    let o_hi = evaluate_added(site, policy, pc.max_added_pct, pc);
    if o_hi.feasible(&pc.slo) {
        return plan_from(site, policy, pc.max_added_pct, true, o_hi);
    }
    // Invariant: lo feasible (outcome kept), hi infeasible.
    let mut lo = 0u32;
    let mut lo_outcome = o0;
    let mut hi = pc.max_added_pct;
    while hi - lo > step {
        let mid = lo + (hi - lo) / 2;
        let o = evaluate_added(site, policy, mid, pc);
        if o.feasible(&pc.slo) {
            lo = mid;
            lo_outcome = o;
        } else {
            hi = mid;
        }
    }
    plan_from(site, policy, lo, true, lo_outcome)
}

/// Plan every policy (the Fig 17/18 comparison set, site-level).
pub fn plan_all(site: &SiteSpec, pc: &PlannerConfig) -> Vec<PolicyPlan> {
    PolicyKind::all().iter().map(|&p| plan_site(site, p, pc)).collect()
}

/// Plan a site where every cluster colocates `training_fraction` of its
/// servers as synchronized training jobs — the capacity-planning form
/// of "how many servers fit if X% of the row is training?" (§7).
/// Training rows idle near TDP with coordinated swings (§2.4), so
/// deployable oversubscription shrinks as the fraction rises; the
/// binary search itself is unchanged because training only *raises*
/// load, preserving the feasibility monotonicity the search relies on.
pub fn plan_site_with_training(
    site: &SiteSpec,
    training_fraction: f64,
    policy: PolicyKind,
    pc: &PlannerConfig,
) -> PolicyPlan {
    plan_site(&site.with_training(training_fraction), policy, pc)
}

/// A fault-derated site plan: the clean answer next to the largest
/// added fraction that also survives the fault plan within the
/// containment SLO.
#[derive(Debug, Clone)]
pub struct FaultedSitePlan {
    /// The clean (no-fault) plan the derating is anchored to.
    pub clean: PolicyPlan,
    /// Largest added fraction (percent) whose *faulted* evaluation
    /// stays within the containment SLO. Never exceeds
    /// `clean.added_pct` — a site must be deployable cleanly before it
    /// can be deployable under faults.
    pub derated_added_pct: u32,
    /// Deployed servers at the derated point (≤ the clean count).
    pub derated_servers: usize,
    /// Whether any probed point survived the fault plan at all (false
    /// means even the non-oversubscribed site loses containment).
    pub feasible: bool,
    /// Worst per-cluster violation seconds at the derated point.
    pub worst_violation_s: f64,
    /// Worst per-cluster time-to-contain at the derated point.
    pub worst_time_to_contain_s: f64,
    /// Worst per-cluster overshoot fraction at the derated point.
    pub worst_overshoot_frac: f64,
    /// The faulted evaluation at the derated point.
    pub outcome: SiteOutcome,
}

/// Derate the clean plan for a fault timeline: binary-search the
/// largest added fraction, *capped at the clean plan's answer*, whose
/// evaluation with `faults` replayed in every cluster stays within
/// `cslo`. The returned server count is therefore ≤ the clean
/// [`plan_site`] count by construction — faults can only cost capacity.
/// Containment worsens monotonically with load (more servers → more
/// power → deeper, longer excursions when a fault lands), which is what
/// keeps the binary search sound here too.
pub fn plan_site_under_faults(
    site: &SiteSpec,
    policy: PolicyKind,
    pc: &PlannerConfig,
    faults: &FaultPlan,
    cslo: &ContainmentSlo,
) -> FaultedSitePlan {
    let step = pc.step_pct.max(1);
    let clean = plan_site(site, policy, pc);
    let faulted =
        |added_pct: u32| evaluate_added_with_faults(site, policy, added_pct, pc, Some(faults));
    let from = |added_pct: u32, feasible: bool, outcome: SiteOutcome, clean: PolicyPlan| {
        let derated_servers = site.with_added(added_pct as f64 / 100.0).deployed_servers();
        FaultedSitePlan {
            clean,
            derated_added_pct: added_pct,
            derated_servers,
            feasible,
            worst_violation_s: outcome.worst_violation_s(),
            worst_time_to_contain_s: outcome.worst_time_to_contain_s(),
            worst_overshoot_frac: outcome.worst_overshoot_frac(),
            outcome,
        }
    };
    if !clean.feasible {
        let o0 = faulted(0);
        return from(0, false, o0, clean);
    }
    // Probe the clean answer first: by the load-monotonicity the search
    // relies on, it passing implies every lower point passes, so the
    // common no-derating case costs exactly one faulted evaluation.
    let o_hi = faulted(clean.added_pct);
    if o_hi.meets_containment(cslo) {
        let pct = clean.added_pct;
        return from(pct, true, o_hi, clean);
    }
    if clean.added_pct == 0 {
        // o_hi evaluated the baseline itself and it failed containment.
        return from(0, false, o_hi, clean);
    }
    let o0 = faulted(0);
    if !o0.meets_containment(cslo) {
        // Even the provisioned site loses containment under this plan.
        return from(0, false, o0, clean);
    }
    // Invariant: lo containment-feasible (outcome kept), hi infeasible.
    let mut lo = 0u32;
    let mut lo_outcome = o0;
    let mut hi = clean.added_pct;
    while hi - lo > step {
        let mid = lo + (hi - lo) / 2;
        let o = faulted(mid);
        if o.meets_containment(cslo) {
            lo = mid;
            lo_outcome = o;
        } else {
            hi = mid;
        }
    }
    from(lo, true, lo_outcome, clean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::site::{ClusterSpec, Feed, SiteSpec};
    use crate::fleet::sku;

    /// A one-cluster site small enough for unit-test budgets.
    fn tiny_site() -> SiteSpec {
        let c = ClusterSpec::new("c0", sku::find("dgx-a100").unwrap(), 12);
        let budget = c.budget_w();
        SiteSpec {
            name: "tiny".into(),
            clusters: vec![c],
            feeds: vec![Feed { name: "feed0".into(), clusters: vec![0], capacity_w: budget }],
            ups_efficiency: 0.94,
            substation_budget_w: budget / 0.94,
        }
    }

    fn tiny_pc() -> PlannerConfig {
        PlannerConfig {
            weeks: 0.02,
            seed: 3,
            sample_s: 120.0,
            parallel: false,
            max_added_pct: 20,
            step_pct: 10,
            ..Default::default()
        }
    }

    #[test]
    fn plan_is_structurally_consistent() {
        let site = tiny_site();
        let pc = tiny_pc();
        let plan = plan_site(&site, PolicyKind::Polca, &pc);
        assert!(plan.added_pct <= pc.max_added_pct);
        assert_eq!(plan.baseline_servers, 12);
        assert!(plan.deployable_servers >= 12 || !plan.feasible);
        assert!(plan.site_peak_w > 0.0);
        assert_eq!(plan.outcome.clusters.len(), 1);
        if plan.feasible {
            assert!(plan.outcome.feasible(&pc.slo));
            assert!(plan.headroom_frac >= 0.0, "headroom {}", plan.headroom_frac);
        }
    }

    #[test]
    fn training_rows_shrink_deployable_capacity() {
        // The §7 planning question: a site that is part training cannot
        // oversubscribe as far as a pure-inference site, because
        // training rows idle near TDP. Compare the planner's answers.
        let site = tiny_site();
        let pc = tiny_pc();
        let inference = plan_site(&site, PolicyKind::Polca, &pc);
        let mixed = plan_site_with_training(&site, 1.0, PolicyKind::Polca, &pc);
        assert!(
            mixed.added_pct <= inference.added_pct,
            "pure training ({}) must not out-deploy pure inference ({})",
            mixed.added_pct,
            inference.added_pct
        );
        if mixed.feasible {
            // The chosen point still reports a consistent evaluation.
            assert!(mixed.outcome.feasible(&pc.slo));
            assert!(mixed.outcome.clusters[0].report.train.iters > 0);
        }
    }

    #[test]
    fn fault_derated_plan_never_exceeds_the_clean_plan() {
        use crate::faults::{ContainmentSlo, FaultPlan};

        let site = tiny_site();
        let mut pc = tiny_pc();
        pc.brake_escalation_s = Some(120.0);
        let horizon_s = pc.weeks * 7.0 * 86_400.0;
        let faults = FaultPlan::scenario("feed-loss", horizon_s).unwrap();
        let cslo = ContainmentSlo::default();
        let plan = plan_site_under_faults(&site, PolicyKind::Polca, &pc, &faults, &cslo);
        assert!(plan.derated_added_pct <= plan.clean.added_pct);
        assert!(plan.derated_servers <= plan.clean.deployable_servers.max(site.baseline_servers()));
        assert_eq!(plan.outcome.clusters.len(), 1);
        // The faulted evaluation actually replayed the plan.
        assert_eq!(plan.outcome.clusters[0].report.resilience.incidents.len(), faults.len());
        if plan.feasible {
            assert!(plan.outcome.meets_containment(&cslo));
            assert!(plan.worst_time_to_contain_s.is_finite());
        }
    }

    #[test]
    fn evaluate_added_scales_deployment() {
        let site = tiny_site();
        let pc = tiny_pc();
        let o = evaluate_added(&site, PolicyKind::NoCap, 0, &pc);
        // baseline 12-server cluster must complete work and stay sane
        assert!(o.clusters[0].report.hp.completed + o.clusters[0].report.lp.completed > 0);
        assert!(o.substation_peak_w < o.substation_budget_w * 1.5);
        assert!(!o.trace.site_w.is_empty());
    }
}
