//! Region layer: many sites under one shared grid budget, planned
//! analytically through the trace algebra.
//!
//! The site planner ([`crate::fleet::planner`]) simulates every
//! candidate because one substation feeds a handful of clusters. A
//! *region* — tens to hundreds of sites behind a shared grid
//! interconnect — cannot afford a discrete-event run per candidate
//! allocation. This module makes planning closed-form instead:
//!
//! 1. **Archetypes** ([`ArchetypeCache`]): a cluster's normalized power
//!    trace depends only on (SKU, baseline servers, added %, training
//!    fraction) — not on which site it sits in or what diurnal phase it
//!    serves. Each distinct archetype is simulated *once* (fanned out
//!    through [`crate::exec::run_batch`]) and cached; a 50-site region
//!    of 3 SKUs probes a dozen sims total, independent of site count.
//! 2. **Composition** ([`site_trace`], [`region_trace`]): a site's
//!    trace is the [`PowerTrace`] sum of its clusters' archetypes,
//!    each rotated by the cluster's diurnal phase plus the site's
//!    time-zone offset and scaled to its breaker budget; the region
//!    trace is the sum of substation-side site traces. Evaluating a
//!    candidate allocation is O(sites × samples).
//! 3. **Planning** ([`plan_region`]): binary-search the largest
//!    *uniform* added level that keeps the (optionally price/carbon
//!    weighted) region peak under the grid budget and every site under
//!    its substation budget, then greedily bump individual sites by
//!    `step_pct` while feasibility holds.
//! 4. **Validation** ([`validate_region`]): the analytic path is only
//!    trustworthy against the event-driven truth, so the subsystem
//!    ships its own harness — full [`crate::fleet::parallel::run_site`]
//!    simulations of deterministically sampled sites, compared to the
//!    analytic composition, reporting mean/peak relative error against
//!    the pinned tolerances ([`MEAN_TOLERANCE`], [`PEAK_TOLERANCE`]).
//!
//! # Periodicity contract
//!
//! Phase rotation of an archetype is exact only when the trace spans
//! whole diurnal periods of like days: the arrival model's weekday
//! pattern repeats across days 0–4 (weekends differ), so validation
//! snaps its horizon to whole days and demo time-zone offsets stay
//! under a day. Planning at other horizons is self-consistent but its
//! wrap-around is an approximation — which is precisely what
//! `validate` measures.
//!
//! The plan allocates *power*; per-site SLO feasibility at the chosen
//! added levels remains the site planner's job
//! ([`crate::fleet::planner::plan_site`]).

use std::collections::{BTreeMap, BTreeSet};

use crate::exec::{run_batch, run_batch_profiled, ExecConfig};
use crate::obs::{emit_diag, DiagEvent, Span};
use crate::policy::engine::PolicyKind;
use crate::simulation;
use crate::util::rng::Rng;

use super::parallel::{run_site, SiteRunConfig};
use super::site::{ClusterSpec, Feed, SiteSpec};
use super::sku;
use super::trace::PowerTrace;

/// Validation tolerance on analytic-vs-simulated *mean* site power.
pub const MEAN_TOLERANCE: f64 = 0.01;
/// Validation tolerance on analytic-vs-simulated *peak* site power.
pub const PEAK_TOLERANCE: f64 = 0.03;

/// One site of a region: a full site topology plus the time-zone
/// offset of the demand it serves.
#[derive(Debug, Clone)]
pub struct RegionSite {
    /// The site topology (clusters → feeds → UPS → substation).
    pub site: SiteSpec,
    /// Time-zone offset of this site's demand vs region time, seconds.
    /// A site serving demand `h` hours east sees its diurnal peak `h`
    /// hours earlier in region time (same convention as
    /// [`ClusterSpec::phase_offset_s`]). Keep under a day so phase
    /// rotation stays within the weekday-periodic window.
    pub tz_offset_s: f64,
}

/// A region: sites sharing one grid interconnect budget.
#[derive(Debug, Clone)]
pub struct RegionSpec {
    /// Region name (for tables and traces).
    pub name: String,
    /// The sites drawing from the shared interconnect.
    pub sites: Vec<RegionSite>,
    /// Shared grid budget in watts, applied to the (weighted) peak of
    /// the composed region trace at the substation side.
    pub grid_budget_w: f64,
    /// Optional time-varying grid *price* weights (resampled to the
    /// trace length; 1.0 = neutral). The planner constrains
    /// `max_t weight(t) × draw(t) ≤ grid_budget_w`, so expensive hours
    /// bind tighter.
    pub price_weights: Option<Vec<f64>>,
    /// Optional time-varying *carbon intensity* weights, combined
    /// multiplicatively with the price weights.
    pub carbon_weights: Option<Vec<f64>>,
}

impl RegionSpec {
    /// A demo region: `n_sites` sites of `clusters_per_site` clusters
    /// each, cycling the SKU registry on 12-server baselines (a pinned
    /// calibration anchor, so no archetype triggers a calibration
    /// fit), cluster diurnal peaks staggered 3 h apart within a site,
    /// site time zones staggered 3 h apart across the region, and a
    /// shared grid budget of `grid_budget_frac` × the summed
    /// substation budgets.
    pub fn demo(n_sites: usize, clusters_per_site: usize, grid_budget_frac: f64) -> RegionSpec {
        let skus = sku::registry();
        let sites: Vec<RegionSite> = (0..n_sites)
            .map(|s| {
                let clusters: Vec<ClusterSpec> = (0..clusters_per_site)
                    .map(|i| {
                        let sk = skus[(s + i) % skus.len()];
                        let mut c =
                            ClusterSpec::new(&format!("s{s}c{i}-{}", sk.name), sk, 12);
                        c.phase_offset_s = i as f64 * 3.0 * 3600.0;
                        c
                    })
                    .collect();
                let feeds: Vec<Feed> = clusters
                    .chunks(2)
                    .enumerate()
                    .map(|(f, chunk)| {
                        let idxs: Vec<usize> = (f * 2..f * 2 + chunk.len()).collect();
                        let capacity_w: f64 = chunk.iter().map(|c| c.budget_w()).sum();
                        Feed { name: format!("feed{f}"), clusters: idxs, capacity_w }
                    })
                    .collect();
                let ups_efficiency = 0.94;
                let substation_budget_w =
                    clusters.iter().map(|c| c.budget_w()).sum::<f64>() / ups_efficiency;
                RegionSite {
                    site: SiteSpec {
                        name: format!("site{s}"),
                        clusters,
                        feeds,
                        ups_efficiency,
                        substation_budget_w,
                    },
                    tz_offset_s: (s % 5) as f64 * 3.0 * 3600.0,
                }
            })
            .collect();
        let grid_budget_w =
            grid_budget_frac * sites.iter().map(|r| r.site.substation_budget_w).sum::<f64>();
        RegionSpec {
            name: format!("demo-region-{n_sites}"),
            sites,
            grid_budget_w,
            price_weights: None,
            carbon_weights: None,
        }
    }

    /// Total provisioned server count across all sites.
    pub fn baseline_servers(&self) -> usize {
        self.sites.iter().map(|r| r.site.baseline_servers()).sum()
    }

    /// Total deployed server count at the given per-site added levels.
    pub fn deployed_at(&self, added_pct: &[u32]) -> usize {
        self.sites
            .iter()
            .zip(added_pct)
            .map(|(r, &a)| r.site.with_added(a as f64 / 100.0).deployed_servers())
            .sum()
    }

    /// The combined (price × carbon) weight profile, if any weights are
    /// configured; resampled pointwise to the longer of the two.
    pub fn effective_weights(&self) -> Option<Vec<f64>> {
        match (&self.price_weights, &self.carbon_weights) {
            (None, None) => None,
            (Some(p), None) => Some(p.clone()),
            (None, Some(c)) => Some(c.clone()),
            (Some(p), Some(c)) => {
                let n = p.len().max(c.len());
                Some(
                    (0..n)
                        .map(|j| p[(j * p.len()) / n] * c[(j * c.len()) / n])
                        .collect(),
                )
            }
        }
    }
}

/// How to run a region planning / validation pass.
#[derive(Debug, Clone)]
pub struct RegionPlanConfig {
    /// Capping policy every archetype and validation cluster runs.
    pub policy: PolicyKind,
    /// Archetype simulation horizon in weeks (default one day, the
    /// shortest whole diurnal period — see the module docs).
    pub weeks: f64,
    /// Region seed; archetype and validation seeds derive from it.
    pub seed: u64,
    /// Trace sampling period, seconds.
    pub sample_s: f64,
    /// Fan archetype/validation batches out on scoped threads.
    pub parallel: bool,
    /// Largest per-site added level probed, percent.
    pub max_added_pct: u32,
    /// Planning granularity, percent.
    pub step_pct: u32,
}

impl Default for RegionPlanConfig {
    fn default() -> Self {
        RegionPlanConfig {
            policy: PolicyKind::Polca,
            weeks: 1.0 / 7.0,
            seed: 1,
            sample_s: 300.0,
            parallel: true,
            max_added_pct: 50,
            step_pct: 5,
        }
    }
}

/// Archetype key: everything a cluster's *normalized* trace depends on.
/// (Phase is deliberately absent — archetypes are simulated at zero
/// phase and rotated analytically; training fraction is keyed in
/// permille.)
type ArchetypeKey = (String, usize, u32, u32);

fn archetype_key(c: &ClusterSpec, added_pct: u32) -> ArchetypeKey {
    (
        c.sku.name.to_string(),
        c.baseline_servers,
        added_pct,
        (c.training_fraction * 1000.0).round() as u32,
    )
}

/// Deterministic archetype seed: a pure function of the region seed and
/// the archetype key, domain-separated from every other seed derivation
/// in the tree ([`crate::fleet::parallel::cluster_seeds`],
/// [`crate::exec::item_seeds`]) by its own constant.
fn archetype_seed(region_seed: u64, key: &ArchetypeKey) -> u64 {
    // FNV-1a over the key, then one xoshiro squeeze for dispersion.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.0.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^= (key.1 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= ((key.2 as u64) << 32) | key.3 as u64;
    Rng::new(region_seed ^ 0xA2C7_E7F5_5EED_0003 ^ h).next_u64()
}

/// Deterministic per-site validation seeds (distinct domain from
/// archetype seeds, so the spot-check simulations are statistically
/// independent of the traces they check).
fn validation_seed(region_seed: u64, site_idx: usize) -> u64 {
    Rng::new(region_seed ^ 0x7A11_DA7E_5EED_0009).fork(site_idx as u64).next_u64()
}

/// Cache of simulated cluster archetypes: one normalized
/// [`PowerTrace`] per [`ArchetypeKey`], populated lazily in batches
/// through the scenario executor.
pub struct ArchetypeCache {
    policy: PolicyKind,
    weeks: f64,
    seed: u64,
    /// Trace sampling period of every archetype, seconds.
    pub sample_s: f64,
    exec: ExecConfig,
    traces: BTreeMap<ArchetypeKey, PowerTrace>,
    /// Discrete-event simulations actually run to fill the cache.
    pub sims_run: usize,
    /// Per-archetype execution spans from the profiled batches (for
    /// the region-plan trace surface).
    pub spans: Vec<Span>,
}

impl ArchetypeCache {
    /// An empty cache that will simulate with the given plan settings.
    pub fn new(pc: &RegionPlanConfig) -> ArchetypeCache {
        ArchetypeCache {
            policy: pc.policy,
            weeks: pc.weeks,
            seed: pc.seed,
            sample_s: pc.sample_s,
            exec: ExecConfig::with_parallel(pc.parallel),
            traces: BTreeMap::new(),
            sims_run: 0,
            spans: Vec::new(),
        }
    }

    /// Insert an externally supplied archetype (a measured trace, or a
    /// synthetic one in tests) so [`ArchetypeCache::ensure`] will not
    /// simulate that key.
    pub fn insert(&mut self, c: &ClusterSpec, added_pct: u32, trace: PowerTrace) {
        self.traces.insert(archetype_key(c, added_pct), trace);
    }

    /// Make sure every archetype needed to evaluate `region` at the
    /// given per-site added levels is present, simulating the missing
    /// ones as one batch through [`crate::exec::run_batch`].
    pub fn ensure(&mut self, region: &RegionSpec, added_pct: &[u32]) {
        let mut missing: BTreeMap<ArchetypeKey, ClusterSpec> = BTreeMap::new();
        let mut seen: BTreeSet<ArchetypeKey> = BTreeSet::new();
        for (rs, &level) in region.sites.iter().zip(added_pct) {
            for c in &rs.site.clusters {
                let key = archetype_key(c, level);
                if !self.traces.contains_key(&key) && seen.insert(key.clone()) {
                    let mut rep = c.clone();
                    rep.phase_offset_s = 0.0;
                    rep.added_frac = level as f64 / 100.0;
                    missing.insert(key, rep);
                }
            }
        }
        if missing.is_empty() {
            return;
        }
        let items: Vec<(ArchetypeKey, ClusterSpec)> = missing.into_iter().collect();
        let sims: Vec<_> = items
            .iter()
            .map(|(key, rep)| {
                rep.sim_config(self.policy, self.weeks, archetype_seed(self.seed, key), self.sample_s)
            })
            .collect();
        let (reports, spans) =
            run_batch_profiled(&sims, &self.exec, |_, cfg| simulation::run(cfg));
        self.sims_run += reports.len();
        self.spans.extend(spans);
        for ((key, _), report) in items.into_iter().zip(reports) {
            self.traces
                .insert(key, PowerTrace::from_series(&report.power_series, self.sample_s));
        }
    }

    /// The cached normalized archetype for a cluster at an added level.
    /// Panics if [`ArchetypeCache::ensure`] has not covered the key.
    pub fn get(&self, c: &ClusterSpec, added_pct: u32) -> &PowerTrace {
        self.traces
            .get(&archetype_key(c, added_pct))
            .expect("archetype not in cache — call ensure() first")
    }
}

/// Analytic *cluster-side* site trace (watts at the breakers): each
/// cluster's archetype rotated to its diurnal phase plus the site's
/// time zone, scaled to its breaker budget, and summed — the analytic
/// twin of the trace [`crate::fleet::parallel::run_site`] composes
/// from real simulations.
pub fn site_trace(rs: &RegionSite, added_pct: u32, cache: &ArchetypeCache) -> PowerTrace {
    let traces: Vec<PowerTrace> = rs
        .site
        .clusters
        .iter()
        .map(|c| {
            // A cluster whose arrival clock runs phi ahead sees its
            // features phi *earlier*, hence the backward rotation.
            cache
                .get(c, added_pct)
                .shift_phase(-(c.phase_offset_s + rs.tz_offset_s))
                .scale(c.budget_w())
        })
        .collect();
    PowerTrace::sum(cache.sample_s, &traces)
}

/// The composed region trace at the given per-site added levels.
#[derive(Debug, Clone)]
pub struct RegionTrace {
    /// Sampling period, seconds.
    pub period_s: f64,
    /// Per-site *substation-side* traces (after UPS losses), watts.
    pub site_w: Vec<PowerTrace>,
    /// Region total (sum of `site_w`), the grid's view.
    pub region_w: PowerTrace,
}

/// Compose the region trace analytically (no simulation beyond filling
/// the archetype cache).
pub fn region_trace(
    region: &RegionSpec,
    added_pct: &[u32],
    cache: &mut ArchetypeCache,
) -> RegionTrace {
    cache.ensure(region, added_pct);
    let site_w: Vec<PowerTrace> = region
        .sites
        .iter()
        .zip(added_pct)
        .map(|(rs, &a)| site_trace(rs, a, cache).scale(1.0 / rs.site.ups_efficiency))
        .collect();
    let region_w = PowerTrace::sum(cache.sample_s, &site_w);
    RegionTrace { period_s: cache.sample_s, site_w, region_w }
}

/// A region allocation plan.
#[derive(Debug, Clone)]
pub struct RegionPlan {
    /// Site names, in region order.
    pub site_names: Vec<String>,
    /// Planned added level per site, percent.
    pub added_pct: Vec<u32>,
    /// The uniform level the binary search settled on before the
    /// greedy per-site bumps.
    pub uniform_added_pct: u32,
    /// Total provisioned servers across the region.
    pub baseline_servers: usize,
    /// Total deployed servers under the plan.
    pub deployed_servers: usize,
    /// Shared grid budget, watts.
    pub grid_budget_w: f64,
    /// (Weighted) analytic region peak at the plan, watts.
    pub grid_peak_w: f64,
    /// Analytic substation-side peak per site at the plan, watts.
    pub site_peak_w: Vec<f64>,
    /// Substation budget per site, watts.
    pub site_budget_w: Vec<f64>,
    /// False only when the region breaks its budgets with zero added
    /// servers (over-provisioned vs the grid interconnect).
    pub feasible: bool,
    /// Discrete-event simulations run to fill the archetype cache —
    /// the whole point: independent of site count and candidate count.
    pub archetype_sims: usize,
    /// Closed-form candidate evaluations performed.
    pub candidate_evals: usize,
    /// Execution spans of the archetype simulation batches.
    pub spans: Vec<Span>,
}

impl RegionPlan {
    /// Extra servers deployed over baseline, percent.
    pub fn headroom_pct(&self) -> f64 {
        if self.baseline_servers == 0 {
            return 0.0;
        }
        100.0 * (self.deployed_servers as f64 - self.baseline_servers as f64)
            / self.baseline_servers as f64
    }
}

struct CandidateEval {
    ok: bool,
    grid_peak_w: f64,
    site_peak_w: Vec<f64>,
}

/// Evaluate one candidate allocation closed-form, memoizing per-site
/// substation-side traces by (site index, level).
fn eval_candidate(
    region: &RegionSpec,
    added_pct: &[u32],
    cache: &mut ArchetypeCache,
    memo: &mut BTreeMap<(usize, u32), PowerTrace>,
    evals: &mut usize,
) -> CandidateEval {
    cache.ensure(region, added_pct);
    *evals += 1;
    let sample_s = cache.sample_s;
    let site_traces: Vec<PowerTrace> = region
        .sites
        .iter()
        .enumerate()
        .zip(added_pct)
        .map(|((i, rs), &a)| {
            memo.entry((i, a))
                .or_insert_with(|| {
                    site_trace(rs, a, cache).scale(1.0 / rs.site.ups_efficiency)
                })
                .clone()
        })
        .collect();
    let region_w = PowerTrace::sum(sample_s, &site_traces);
    let weights = region.effective_weights();
    let grid_peak_w = match &weights {
        Some(w) => region_w.weighted_peak_w(w),
        None => region_w.peak_w(),
    };
    let site_peak_w: Vec<f64> = site_traces.iter().map(|t| t.peak_w()).collect();
    let ok = grid_peak_w <= region.grid_budget_w
        && site_peak_w
            .iter()
            .zip(&region.sites)
            .all(|(&p, rs)| p <= rs.site.substation_budget_w);
    CandidateEval { ok, grid_peak_w, site_peak_w }
}

/// Plan a region with a caller-supplied archetype cache (lets tests and
/// external-trace users pre-seed archetypes; [`plan_region`] is the
/// plain entry point).
pub fn plan_region_with_cache(
    region: &RegionSpec,
    pc: &RegionPlanConfig,
    cache: &mut ArchetypeCache,
) -> RegionPlan {
    let n_sites = region.sites.len();
    let step = pc.step_pct.max(1);
    let max_units = pc.max_added_pct / step;
    let mut memo: BTreeMap<(usize, u32), PowerTrace> = BTreeMap::new();
    let mut evals = 0usize;

    // Binary-search the largest feasible *uniform* level, in step units.
    let at = |units: u32| vec![units * step; n_sites];
    let feasible = eval_candidate(region, &at(0), cache, &mut memo, &mut evals).ok;
    let mut lo_u = 0u32;
    if feasible && max_units > 0 {
        if eval_candidate(region, &at(max_units), cache, &mut memo, &mut evals).ok {
            lo_u = max_units;
        } else {
            let mut hi_u = max_units; // invariant: lo feasible, hi not
            while hi_u - lo_u > 1 {
                let mid_u = lo_u + (hi_u - lo_u) / 2;
                if eval_candidate(region, &at(mid_u), cache, &mut memo, &mut evals).ok {
                    lo_u = mid_u;
                } else {
                    hi_u = mid_u;
                }
            }
        }
    }
    let uniform = lo_u * step;
    let mut added = vec![uniform; n_sites];

    // Greedy refinement: bump one site at a time by `step` while the
    // region stays feasible; passes repeat until no bump lands. Each
    // probe is a closed-form evaluation — no simulation.
    if feasible {
        loop {
            let mut improved = false;
            for s in 0..n_sites {
                if added[s] + step > pc.max_added_pct {
                    continue;
                }
                let mut cand = added.clone();
                cand[s] += step;
                if eval_candidate(region, &cand, cache, &mut memo, &mut evals).ok {
                    added = cand;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
    }

    let fin = eval_candidate(region, &added, cache, &mut memo, &mut evals);
    let plan = RegionPlan {
        site_names: region.sites.iter().map(|r| r.site.name.clone()).collect(),
        added_pct: added.clone(),
        uniform_added_pct: uniform,
        baseline_servers: region.baseline_servers(),
        deployed_servers: region.deployed_at(&added),
        grid_budget_w: region.grid_budget_w,
        grid_peak_w: fin.grid_peak_w,
        site_peak_w: fin.site_peak_w,
        site_budget_w: region.sites.iter().map(|r| r.site.substation_budget_w).collect(),
        feasible,
        archetype_sims: cache.sims_run,
        candidate_evals: evals,
        spans: cache.spans.clone(),
    };
    emit_diag(DiagEvent::RegionPlanned {
        sites: n_sites,
        archetype_sims: cache.sims_run,
        candidate_evals: evals,
    });
    plan
}

/// Plan a region: joint binary-search + greedy allocation of added
/// servers across sites under the shared grid budget, entirely
/// closed-form on top of the archetype cache.
pub fn plan_region(region: &RegionSpec, pc: &RegionPlanConfig) -> RegionPlan {
    let mut cache = ArchetypeCache::new(pc);
    plan_region_with_cache(region, pc, &mut cache)
}

/// One site's analytic-vs-simulated comparison.
#[derive(Debug, Clone)]
pub struct SiteValidation {
    /// Site name.
    pub site: String,
    /// Added level the site was validated at, percent.
    pub added_pct: u32,
    /// Analytic mean site power (cluster side), watts.
    pub analytic_mean_w: f64,
    /// Fully simulated mean site power, watts.
    pub simulated_mean_w: f64,
    /// Analytic peak site power, watts.
    pub analytic_peak_w: f64,
    /// Fully simulated peak site power, watts.
    pub simulated_peak_w: f64,
    /// |analytic − simulated| / simulated, means.
    pub mean_rel_err: f64,
    /// |analytic − simulated| / simulated, peaks.
    pub peak_rel_err: f64,
}

/// The region validation report: per-site errors vs the pinned bounds.
#[derive(Debug, Clone)]
pub struct RegionValidation {
    /// Per sampled site, in sample order.
    pub sites: Vec<SiteValidation>,
    /// Largest per-site mean relative error.
    pub worst_mean_rel_err: f64,
    /// Largest per-site peak relative error.
    pub worst_peak_rel_err: f64,
    /// Mean tolerance the run was held to.
    pub mean_tolerance: f64,
    /// Peak tolerance the run was held to.
    pub peak_tolerance: f64,
    /// Full-simulation horizon used, weeks (snapped to whole days).
    pub weeks: f64,
}

impl RegionValidation {
    /// Whether every sampled site is inside both tolerances.
    pub fn passed(&self) -> bool {
        self.worst_mean_rel_err <= self.mean_tolerance
            && self.worst_peak_rel_err <= self.peak_tolerance
    }

    /// The worst-offending site (largest tolerance-normalized error) —
    /// what a failing run should print for triage.
    pub fn worst_site(&self) -> Option<&SiteValidation> {
        self.sites.iter().max_by(|a, b| {
            let ka = (a.mean_rel_err / self.mean_tolerance)
                .max(a.peak_rel_err / self.peak_tolerance);
            let kb = (b.mean_rel_err / self.mean_tolerance)
                .max(b.peak_rel_err / self.peak_tolerance);
            ka.total_cmp(&kb)
        })
    }
}

fn rel_err(analytic: f64, simulated: f64) -> f64 {
    if simulated == 0.0 {
        return if analytic == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (analytic - simulated).abs() / simulated
}

/// Cross-validate the analytic composition against full simulation on
/// `n_sites` deterministically sampled sites (evenly spaced across the
/// region), at the plan's added levels.
///
/// The full-simulation horizon is snapped to whole days so the phase
/// rotation the analytic path relies on is exact on the arrival
/// pattern (see the module docs); the comparison is cluster-side
/// (before UPS losses — relative errors are invariant to that constant
/// scale). Validation seeds are domain-separated from archetype seeds,
/// so the two paths share no randomness: the reported error includes
/// both approximation and Monte-Carlo noise, which is the honest bound
/// a planner consumer cares about.
pub fn validate_region(
    region: &RegionSpec,
    plan: &RegionPlan,
    pc: &RegionPlanConfig,
    n_sites: usize,
) -> RegionValidation {
    let days = (pc.weeks * 7.0).round().max(1.0);
    let weeks = days / 7.0;
    let mut vcfg = pc.clone();
    vcfg.weeks = weeks;
    let mut cache = ArchetypeCache::new(&vcfg);
    cache.ensure(region, &plan.added_pct);

    let k = n_sites.clamp(1, region.sites.len().max(1)).min(region.sites.len());
    let idxs: Vec<usize> = (0..k).map(|i| i * region.sites.len() / k).collect();

    // Full-simulation twins: the added level applied, the site's time
    // zone folded into every cluster's arrival clock (the simulator
    // realizes phase physically; the analytic path rotates instead).
    let items: Vec<(usize, SiteSpec)> = idxs
        .iter()
        .map(|&i| {
            let rs = &region.sites[i];
            let mut site = rs.site.with_added(plan.added_pct[i] as f64 / 100.0);
            for c in &mut site.clusters {
                c.phase_offset_s += rs.tz_offset_s;
            }
            (i, site)
        })
        .collect();
    let outcomes = run_batch(&items, &ExecConfig::with_parallel(pc.parallel), |_, (i, site)| {
        let rc = SiteRunConfig {
            weeks,
            seed: validation_seed(pc.seed, *i),
            sample_s: pc.sample_s,
            parallel: false, // the site batch is already fanned out
            faults: None,
            brake_escalation_s: None,
        };
        run_site(site, pc.policy, &rc)
    });

    let mut sites = Vec::with_capacity(k);
    for (&i, outcome) in idxs.iter().zip(&outcomes) {
        let rs = &region.sites[i];
        let analytic = site_trace(rs, plan.added_pct[i], &cache);
        let sim = PowerTrace::from_samples(outcome.trace.site_w.clone(), pc.sample_s);
        sites.push(SiteValidation {
            site: rs.site.name.clone(),
            added_pct: plan.added_pct[i],
            analytic_mean_w: analytic.mean_w(),
            simulated_mean_w: sim.mean_w(),
            analytic_peak_w: analytic.peak_w(),
            simulated_peak_w: sim.peak_w(),
            mean_rel_err: rel_err(analytic.mean_w(), sim.mean_w()),
            peak_rel_err: rel_err(analytic.peak_w(), sim.peak_w()),
        });
    }
    let worst_mean_rel_err = sites.iter().map(|s| s.mean_rel_err).fold(0.0, f64::max);
    let worst_peak_rel_err = sites.iter().map(|s| s.peak_rel_err).fold(0.0, f64::max);
    RegionValidation {
        sites,
        worst_mean_rel_err,
        worst_peak_rel_err,
        mean_tolerance: MEAN_TOLERANCE,
        peak_tolerance: PEAK_TOLERANCE,
        weeks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny homogeneous two-site region whose archetypes are
    /// injected synthetically, so the full planner logic runs with
    /// zero simulations: a flat normalized draw of `0.5 + 0.01·level`.
    fn synthetic_region() -> (RegionSpec, RegionPlanConfig, ArchetypeCache) {
        let mut region = RegionSpec::demo(2, 1, 1.0);
        let sk = sku::find("dgx-a100").unwrap();
        for (s, rs) in region.sites.iter_mut().enumerate() {
            rs.tz_offset_s = 0.0;
            let c = ClusterSpec::new(&format!("s{s}c0"), sk, 12);
            let b = c.budget_w();
            rs.site.feeds =
                vec![Feed { name: "feed0".to_string(), clusters: vec![0], capacity_w: b }];
            rs.site.substation_budget_w = b / 0.94;
            rs.site.clusters = vec![c];
        }
        let pc = RegionPlanConfig { step_pct: 10, max_added_pct: 50, ..Default::default() };
        let mut cache = ArchetypeCache::new(&pc);
        for level in (0..=50).step_by(10) {
            let v = 0.5 + 0.01 * level as f64;
            for rs in &region.sites {
                cache.insert(
                    &rs.site.clusters[0],
                    level,
                    PowerTrace::from_samples(vec![v; 8], pc.sample_s),
                );
            }
        }
        (region, pc, cache)
    }

    /// Grid budget that admits a uniform 20% plus exactly one greedy
    /// 30% bump: the per-site substation draw at level L is
    /// `(0.5 + 0.01L)·b`, so pick the midpoint of (v20+v30)·b and
    /// (v30+v30)·b.
    fn one_bump_budget(region: &RegionSpec) -> f64 {
        let b = region.sites[0].site.clusters[0].budget_w() / 0.94;
        ((0.70 + 0.80) + (0.80 + 0.80)) / 2.0 * b
    }

    #[test]
    fn planner_logic_runs_simulation_free_on_injected_archetypes() {
        let (mut region, pc, mut cache) = synthetic_region();
        region.grid_budget_w = one_bump_budget(&region);
        let plan = plan_region_with_cache(&region, &pc, &mut cache);
        assert!(plan.feasible);
        assert_eq!(plan.uniform_added_pct, 20);
        assert_eq!(plan.added_pct, vec![30, 20], "greedy bumps the first site once");
        assert_eq!(plan.archetype_sims, 0, "all archetypes were injected");
        assert!(plan.candidate_evals > 0);
        assert_eq!(plan.baseline_servers, 24);
        // deployed: round(12·1.3) + round(12·1.2)
        assert_eq!(plan.deployed_servers, 16 + 14);
        assert!(plan.grid_peak_w <= region.grid_budget_w);
        assert!(plan.headroom_pct() > 0.0);
    }

    #[test]
    fn infeasible_at_zero_is_reported_not_planned() {
        let (mut region, pc, mut cache) = synthetic_region();
        region.grid_budget_w = 1.0; // no region fits a 1 W interconnect
        let plan = plan_region_with_cache(&region, &pc, &mut cache);
        assert!(!plan.feasible);
        assert_eq!(plan.added_pct, vec![0, 0]);
        assert_eq!(plan.deployed_servers, plan.baseline_servers);
    }

    #[test]
    fn weights_tighten_the_plan() {
        let (mut region, pc, mut cache) = synthetic_region();
        region.grid_budget_w = one_bump_budget(&region);
        let unweighted = plan_region_with_cache(&region, &pc, &mut cache).deployed_servers;
        // A 1.5× price spike makes the same budget bind 1.5× tighter.
        region.price_weights = Some(vec![1.5]);
        let weighted = plan_region_with_cache(&region, &pc, &mut cache).deployed_servers;
        assert!(weighted < unweighted, "{weighted} !< {unweighted}");
    }

    #[test]
    fn seeds_are_deterministic_and_domain_separated() {
        let key = ("dgx-a100".to_string(), 12usize, 20u32, 0u32);
        assert_eq!(archetype_seed(1, &key), archetype_seed(1, &key));
        assert_ne!(archetype_seed(1, &key), archetype_seed(2, &key));
        let other = ("hgx-h100".to_string(), 12usize, 20u32, 0u32);
        assert_ne!(archetype_seed(1, &key), archetype_seed(1, &other));
        assert_eq!(validation_seed(1, 3), validation_seed(1, 3));
        assert_ne!(validation_seed(1, 3), validation_seed(1, 4));
        assert_ne!(validation_seed(1, 3), archetype_seed(1, &key));
    }

    #[test]
    fn demo_region_is_well_formed() {
        let region = RegionSpec::demo(7, 3, 0.85);
        assert_eq!(region.sites.len(), 7);
        assert!(region.sites.iter().all(|r| r.site.clusters.len() == 3));
        assert!(region.sites.iter().all(|r| r.tz_offset_s < 86_400.0));
        assert!(region.grid_budget_w > 0.0);
        let sum: f64 = region.sites.iter().map(|r| r.site.substation_budget_w).sum();
        assert!((region.grid_budget_w / sum - 0.85).abs() < 1e-9);
        assert_eq!(region.baseline_servers(), 7 * 3 * 12);
        // weights combine multiplicatively under resampling
        let mut r2 = region.clone();
        r2.price_weights = Some(vec![1.0, 2.0]);
        r2.carbon_weights = Some(vec![3.0]);
        assert_eq!(r2.effective_weights().unwrap(), vec![3.0, 6.0]);
    }
}
