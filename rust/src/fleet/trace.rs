//! First-class power traces with closed-form composition operators.
//!
//! A [`PowerTrace`] is a fixed-period sampled power signal (watts, or
//! normalized watts-per-budget-watt) plus the summary statistics the
//! planner reasons about: mean, peak, variance, and inter-trace
//! covariance / phase-offset structure. The point of making traces
//! values is that a site's — and then a region's — aggregate trace can
//! be *computed* from per-cluster summaries instead of re-simulated:
//! [`PowerTrace::sum`], [`PowerTrace::scale`],
//! [`PowerTrace::shift_phase`] and [`PowerTrace::mix`] are closed-form,
//! so evaluating a candidate allocation is O(samples), not O(events).
//!
//! # Float contract (bit-identity with [`crate::fleet::site::compose`])
//!
//! `compose` predates this module and its output is pinned by tests at
//! full bit precision, so the operators here reproduce its exact float
//! order:
//!
//! * `shift_phase` rotates by whole samples via
//!   `((offset_s / period_s).round() as i64).rem_euclid(n)` — no
//!   arithmetic on the sample values at all;
//! * `scale` performs exactly one multiply per sample;
//! * `sum` left-folds `+=` into a zero-initialized accumulator in
//!   argument order (IEEE addition is commutative pairwise and
//!   `0.0 + x == x`, so prefix regrouping is bit-exact; general
//!   reassociation is not, which is why the order is part of the
//!   contract).
//!
//! These guarantees are what the trace-algebra property tests in
//! `tests/integration_region.rs` pin: `sum` commutative/associative
//! (bit-exact on summaries), `peak(sum) ≤ sum(peaks)` always (with
//! equality at zero phase offsets), and linearity of means under
//! `scale`/`mix` (to float rounding).

/// Summary statistics of one trace — the closed-form "shape" of a
/// cluster's power draw that region planning composes without
/// re-simulating.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSummary {
    /// Number of samples.
    pub len: usize,
    /// Sampling period, seconds.
    pub period_s: f64,
    /// Mean draw over the trace.
    pub mean_w: f64,
    /// Peak draw over the trace.
    pub peak_w: f64,
    /// Population variance of the draw (W²).
    pub variance_w2: f64,
}

/// A fixed-period sampled power trace.
///
/// Samples are in watts when the trace is budget-scaled, or in
/// normalized watts-per-budget-watt when it comes straight from a
/// cluster simulation's `power_series` (see
/// [`crate::metrics::RunReport`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerTrace {
    /// Sampling period, seconds.
    pub period_s: f64,
    /// The sampled signal.
    pub samples: Vec<f64>,
}

impl PowerTrace {
    /// A trace from raw samples at a fixed period.
    pub fn from_samples(samples: Vec<f64>, period_s: f64) -> PowerTrace {
        PowerTrace { period_s, samples }
    }

    /// A trace from a `(t, value)` series (the simulator's
    /// `power_series` shape); timestamps are dropped, the fixed period
    /// is taken on faith from the caller.
    pub fn from_series(series: &[(f64, f64)], period_s: f64) -> PowerTrace {
        PowerTrace { period_s, samples: series.iter().map(|&(_, v)| v).collect() }
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// Whether the trace has no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Total covered time, seconds.
    pub fn duration_s(&self) -> f64 {
        self.samples.len() as f64 * self.period_s
    }

    /// Mean draw (0.0 for an empty trace).
    pub fn mean_w(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Peak draw (0.0 for an empty trace; same fold as
    /// [`crate::fleet::site::SiteTrace::peak_w`]).
    pub fn peak_w(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }

    /// Population variance of the draw, W² (0.0 for an empty trace).
    pub fn variance_w2(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mean = self.mean_w();
        self.samples.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>()
            / self.samples.len() as f64
    }

    /// All summary statistics at once.
    pub fn summary(&self) -> TraceSummary {
        TraceSummary {
            len: self.samples.len(),
            period_s: self.period_s,
            mean_w: self.mean_w(),
            peak_w: self.peak_w(),
            variance_w2: self.variance_w2(),
        }
    }

    /// A copy truncated to the first `n` samples.
    pub fn truncated(&self, n: usize) -> PowerTrace {
        let n = n.min(self.samples.len());
        PowerTrace { period_s: self.period_s, samples: self.samples[..n].to_vec() }
    }

    /// The trace scaled by `factor` — exactly one multiply per sample,
    /// so `normalized.scale(budget_w)` is bit-identical to the watt
    /// conversion [`crate::fleet::site::compose`] performs.
    pub fn scale(&self, factor: f64) -> PowerTrace {
        PowerTrace {
            period_s: self.period_s,
            samples: self.samples.iter().map(|&x| x * factor).collect(),
        }
    }

    /// The trace rotated forward in time by `offset_s` (rounded to
    /// whole samples, wrapping circularly): a feature at sample `j`
    /// moves to sample `j + offset`. Negative offsets rotate backward —
    /// `shift_phase(-phi)` of a zero-phase trace models a cluster whose
    /// arrival clock runs `phi` seconds ahead (its peaks happen
    /// *earlier*, the [`crate::fleet::site::ClusterSpec::phase_offset_s`]
    /// convention).
    ///
    /// Circular wrap is only physically meaningful when the trace spans
    /// whole diurnal periods of like days (the arrival model's weekday
    /// pattern repeats across days 0–4; weekends differ).
    pub fn shift_phase(&self, offset_s: f64) -> PowerTrace {
        let n = self.samples.len();
        if n == 0 {
            return self.clone();
        }
        let shift = ((offset_s / self.period_s).round() as i64).rem_euclid(n as i64) as usize;
        let samples =
            (0..n).map(|j| self.samples[(j + n - shift) % n]).collect();
        PowerTrace { period_s: self.period_s, samples }
    }

    /// Sample-wise sum of `traces`, truncated to the shortest: a
    /// zero-initialized accumulator left-folded with `+=` in argument
    /// order (the [`crate::fleet::site::compose`] float order — see the
    /// module docs for why the order is part of the contract).
    ///
    /// `period_s` is passed explicitly so the sum of zero traces is
    /// still a well-formed empty trace.
    pub fn sum(period_s: f64, traces: &[PowerTrace]) -> PowerTrace {
        let n = traces.iter().map(|t| t.samples.len()).min().unwrap_or(0);
        let mut acc = vec![0.0; n];
        for t in traces {
            for (j, slot) in acc.iter_mut().enumerate() {
                *slot += t.samples[j];
            }
        }
        PowerTrace { period_s, samples: acc }
    }

    /// Weighted sum: each trace scaled by its weight, then summed in
    /// order (`mix(p, ts, ws) == sum(p, [t.scale(w) ...])`, bit-exactly,
    /// because that is literally how it is computed).
    pub fn mix(period_s: f64, traces: &[PowerTrace], weights: &[f64]) -> PowerTrace {
        assert_eq!(traces.len(), weights.len());
        let scaled: Vec<PowerTrace> =
            traces.iter().zip(weights).map(|(t, &w)| t.scale(w)).collect();
        PowerTrace::sum(period_s, &scaled)
    }

    /// Population covariance with another trace over their common
    /// prefix, W² (0.0 when the overlap is empty). Aligned traces of
    /// like shape covary positively; phase-staggered traces covary
    /// less — exactly the diversity a site planner sells.
    pub fn covariance_w2(&self, other: &PowerTrace) -> f64 {
        let n = self.samples.len().min(other.samples.len());
        if n == 0 {
            return 0.0;
        }
        let ma = self.samples[..n].iter().sum::<f64>() / n as f64;
        let mb = other.samples[..n].iter().sum::<f64>() / n as f64;
        self.samples[..n]
            .iter()
            .zip(&other.samples[..n])
            .map(|(&a, &b)| (a - ma) * (b - mb))
            .sum::<f64>()
            / n as f64
    }

    /// The forward rotation of `other` (in whole samples) that
    /// maximizes its cross-correlation with `self` — the empirical
    /// phase offset between two cluster traces. O(n²); a diagnostic,
    /// not a planner hot path. Ties break toward the smallest shift;
    /// 0 for empty overlap.
    pub fn phase_lag_samples(&self, other: &PowerTrace) -> usize {
        let n = self.samples.len().min(other.samples.len());
        if n == 0 {
            return 0;
        }
        let mut best = (0usize, f64::NEG_INFINITY);
        for shift in 0..n {
            let score: f64 = (0..n)
                .map(|j| self.samples[j] * other.samples[(j + n - shift) % n])
                .sum();
            if score > best.1 {
                best = (shift, score);
            }
        }
        best.0
    }

    /// Peak of the trace under a per-sample weight profile (e.g.
    /// time-varying grid price or carbon intensity), `max_j w_j · x_j`.
    /// The weight vector is resampled to the trace length by index
    /// scaling, so callers can supply e.g. 24 hourly weights against a
    /// 288-sample day.
    pub fn weighted_peak_w(&self, weights: &[f64]) -> f64 {
        let n = self.samples.len();
        if weights.is_empty() {
            return self.peak_w();
        }
        let mut peak = 0.0f64;
        for (j, &x) in self.samples.iter().enumerate() {
            let w = weights[(j * weights.len()) / n];
            peak = peak.max(w * x);
        }
        peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(vals: &[f64]) -> PowerTrace {
        PowerTrace::from_samples(vals.to_vec(), 60.0)
    }

    #[test]
    fn summaries_match_hand_computation() {
        let t = tr(&[1.0, 3.0, 2.0, 2.0]);
        let s = t.summary();
        assert_eq!(s.len, 4);
        assert_eq!(s.mean_w, 2.0);
        assert_eq!(s.peak_w, 3.0);
        assert!((s.variance_w2 - 0.5).abs() < 1e-12);
        assert_eq!(t.duration_s(), 240.0);
        assert_eq!(tr(&[]).summary().mean_w, 0.0);
        assert_eq!(tr(&[]).variance_w2(), 0.0);
    }

    #[test]
    fn shift_rotates_forward_and_wraps() {
        let t = tr(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.shift_phase(60.0).samples, vec![4.0, 1.0, 2.0, 3.0]);
        // negative offset rotates backward (peaks earlier)
        assert_eq!(t.shift_phase(-60.0).samples, vec![2.0, 3.0, 4.0, 1.0]);
        // offsets wrap modulo the trace duration
        assert_eq!(t.shift_phase(5.0 * 60.0).samples, t.shift_phase(60.0).samples);
        assert!(tr(&[]).shift_phase(60.0).is_empty());
    }

    #[test]
    fn sum_and_mix_agree_with_manual_fold() {
        let a = tr(&[1.0, 2.0]);
        let b = tr(&[10.0, 20.0, 30.0]);
        let s = PowerTrace::sum(60.0, &[a.clone(), b.clone()]);
        assert_eq!(s.samples, vec![11.0, 22.0]); // truncated to shortest
        let m = PowerTrace::mix(60.0, &[a.clone(), b.clone()], &[2.0, 0.5]);
        assert_eq!(m.samples, vec![1.0 * 2.0 + 10.0 * 0.5, 2.0 * 2.0 + 20.0 * 0.5]);
        assert!(PowerTrace::sum(60.0, &[]).is_empty());
    }

    #[test]
    fn covariance_sees_alignment() {
        let a = tr(&[0.0, 1.0, 0.0, 1.0]);
        let aligned = a.covariance_w2(&a);
        let opposed = a.covariance_w2(&a.shift_phase(60.0));
        assert!(aligned > 0.0);
        assert!(opposed < 0.0);
        assert_eq!(tr(&[]).covariance_w2(&a), 0.0);
    }

    #[test]
    fn phase_lag_recovers_a_known_shift() {
        let base = tr(&[0.1, 0.2, 1.0, 0.3, 0.1, 0.1]);
        let shifted = base.shift_phase(2.0 * 60.0);
        assert_eq!(shifted.phase_lag_samples(&base), 2);
        assert_eq!(base.phase_lag_samples(&base), 0);
    }

    #[test]
    fn weighted_peak_resamples_the_weight_profile() {
        let t = tr(&[1.0, 1.0, 4.0, 1.0]);
        assert_eq!(t.weighted_peak_w(&[]), 4.0);
        // 2 weights over 4 samples: first half ×1, second half ×0.5
        assert_eq!(t.weighted_peak_w(&[1.0, 0.5]), 2.0);
        // pricier second half can move the binding sample
        assert_eq!(t.weighted_peak_w(&[1.0, 3.0]), 12.0);
    }
}
