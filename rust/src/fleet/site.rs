//! Site topology and compositional power-trace aggregation.
//!
//! Extends [`crate::cluster::hierarchy`] *upward*: the paper provisions
//! power at the row (cluster) breaker; a site feeds many clusters from
//! shared feeds, a UPS, and one substation ("From Servers to Sites":
//! infrastructure planning needs the *composed* trace, not per-cluster
//! maxima). The key physical effect this captures is diversity: cluster
//! peaks that do not align in time sum to less than the sum of peaks,
//! which is exactly the headroom a site-level planner can sell.
//!
//! Composition model: each cluster produces a fixed-period normalized
//! power series from its own simulation (`power_series`); the site trace
//! converts each to watts against the cluster's breaker budget and sums
//! sample-wise — the site trace is exactly the sample-wise sum of the
//! cluster traces (tested invariant). Diurnal phase offsets between
//! clusters (time-zone / tenant-mix shifts) are *physical*: a cluster's
//! [`ClusterSpec::phase_offset_s`] shifts its arrival-process clock
//! ([`crate::workload::arrivals::ArrivalProcess::with_phase`]), so the
//! staggered peaks the planner exploits come out of the simulation, not
//! from post-hoc trace surgery. [`compose`] additionally supports
//! rotating externally supplied traces, which is only meaningful when a
//! trace covers whole diurnal periods.

use crate::characterize::catalog;
use crate::policy::engine::PolicyKind;
use crate::simulation::{power_scale_for_row, SimConfig};

use super::sku::{self, SkuSpec};
use super::trace::PowerTrace;

/// One cluster (a paper "row"): a breaker-budgeted pool of one SKU.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    /// Cluster name (for tables and traces).
    pub name: String,
    /// Server SKU every slot in this cluster runs.
    pub sku: SkuSpec,
    /// Servers the breaker budget was provisioned for.
    pub baseline_servers: usize,
    /// Oversubscription: deployed = baseline × (1 + added_frac).
    pub added_frac: f64,
    /// Fraction of deployed servers running synchronized training jobs
    /// (§7 colocation; 0.0 = the paper's inference-only row). Flows
    /// into [`crate::simulation::MixedRowConfig`] per cluster.
    pub training_fraction: f64,
    /// Diurnal phase offset of this cluster's load vs site time, seconds
    /// (e.g. a cluster serving a region 6 h east sees its afternoon peak
    /// 6 h earlier). Applied to the cluster's arrival-process clock.
    pub phase_offset_s: f64,
    /// Override the low-priority share (None = Table-4 mix).
    pub lp_fraction_override: Option<f64>,
    /// Row-power calibration factor; small rows multiplex fewer prompt
    /// spikes and need a smaller scale (see [`crate::simulation`] docs).
    pub power_scale: f64,
    /// Catalog model every server is dedicated to.
    pub model_name: String,
}

impl ClusterSpec {
    /// A cluster of `baseline_servers` slots of `sku`, inference-only,
    /// with the row-size-appropriate power calibration.
    pub fn new(name: &str, sku: SkuSpec, baseline_servers: usize) -> ClusterSpec {
        let power_scale = power_scale_for_row(baseline_servers);
        ClusterSpec {
            name: name.to_string(),
            sku,
            baseline_servers,
            added_frac: 0.0,
            training_fraction: 0.0,
            phase_offset_s: 0.0,
            lp_fraction_override: None,
            power_scale,
            model_name: "BLOOM-176B".to_string(),
        }
    }

    /// Servers actually deployed at the current oversubscription level.
    pub fn deployed(&self) -> usize {
        (self.baseline_servers as f64 * (1.0 + self.added_frac)).round() as usize
    }

    /// Breaker budget in watts (baseline × per-server provisioned power).
    pub fn budget_w(&self) -> f64 {
        let base = catalog::find(&self.model_name).expect("model not in catalog").power;
        self.baseline_servers as f64 * self.sku.provisioned_w(base)
    }

    /// Build the per-cluster simulation config for one site run.
    pub fn sim_config(
        &self,
        policy: PolicyKind,
        weeks: f64,
        seed: u64,
        sample_s: f64,
    ) -> SimConfig {
        let base = catalog::find(&self.model_name).expect("model not in catalog").power;
        let mut cfg = SimConfig::default();
        cfg.policy_kind = policy;
        cfg.weeks = weeks;
        cfg.exp.seed = seed;
        cfg.exp.row.num_servers = self.baseline_servers;
        cfg.deployed_servers = self.deployed();
        cfg.model_name = self.model_name.clone();
        cfg.lp_fraction_override = self.lp_fraction_override;
        cfg.power_scale = self.power_scale;
        cfg.series_sample_s = sample_s;
        cfg.server_model = Some(self.sku.server_model(base));
        cfg.perf_mult = self.sku.perf_mult;
        cfg.diurnal_phase_s = self.phase_offset_s;
        // Mixed rows: keep `None` at zero training so the inference-only
        // fast path stays literally the paper's configuration.
        if self.training_fraction > 0.0 {
            cfg.mixed = Some(crate::simulation::MixedRowConfig {
                training_fraction: self.training_fraction,
                ..Default::default()
            });
        }
        self.sku.scale_policy(&mut cfg.exp.policy);
        cfg
    }
}

/// A feed: a shared distribution branch carrying a subset of clusters.
#[derive(Debug, Clone)]
pub struct Feed {
    /// Feed name (for budget-violation reporting).
    pub name: String,
    /// Indices into `SiteSpec::clusters`.
    pub clusters: Vec<usize>,
    /// Branch capacity in watts.
    pub capacity_w: f64,
}

/// A site: clusters → feeds → UPS → substation.
#[derive(Debug, Clone)]
pub struct SiteSpec {
    /// Site name.
    pub name: String,
    /// The clusters sharing this site's infrastructure.
    pub clusters: Vec<ClusterSpec>,
    /// Distribution branches (each cluster on exactly one feed).
    pub feeds: Vec<Feed>,
    /// UPS/distribution efficiency: substation draw = cluster sum / eff.
    pub ups_efficiency: f64,
    /// Substation budget in watts.
    pub substation_budget_w: f64,
}

impl SiteSpec {
    /// Sum of cluster breaker budgets (the provisioned load).
    pub fn baseline_budget_w(&self) -> f64 {
        self.clusters.iter().map(|c| c.budget_w()).sum()
    }

    /// Total provisioned server count across clusters.
    pub fn baseline_servers(&self) -> usize {
        self.clusters.iter().map(|c| c.baseline_servers).sum()
    }

    /// Total deployed server count at current oversubscription levels.
    pub fn deployed_servers(&self) -> usize {
        self.clusters.iter().map(|c| c.deployed()).sum()
    }

    /// Site-level oversubscription: deployed provisioned power / budget.
    pub fn oversubscription(&self) -> f64 {
        let base_w = self.baseline_budget_w();
        let deployed_w: f64 = self
            .clusters
            .iter()
            .map(|c| c.budget_w() * c.deployed() as f64 / c.baseline_servers.max(1) as f64)
            .sum();
        deployed_w / base_w
    }

    /// A copy of the site with every cluster at the given added fraction
    /// (the planner's uniform-scaling knob).
    pub fn with_added(&self, added_frac: f64) -> SiteSpec {
        let mut s = self.clone();
        for c in &mut s.clusters {
            c.added_frac = added_frac;
        }
        s
    }

    /// A copy of the site with every cluster colocating the given
    /// fraction of its servers as synchronized training jobs — the
    /// knob behind "how many servers fit if X% of the row is training?"
    /// (plan the returned site, e.g. via
    /// [`crate::fleet::planner::plan_site`]).
    pub fn with_training(&self, training_fraction: f64) -> SiteSpec {
        let mut s = self.clone();
        for c in &mut s.clusters {
            c.training_fraction = training_fraction.clamp(0.0, 1.0);
        }
        s
    }

    /// A demo heterogeneous site: `n` clusters cycling through the SKU
    /// registry, 16-server baselines, diurnal peaks staggered 3 h apart,
    /// paired onto feeds, substation provisioned exactly for the
    /// baseline load through the UPS.
    pub fn demo(n: usize) -> SiteSpec {
        let skus = sku::registry();
        let clusters: Vec<ClusterSpec> = (0..n)
            .map(|i| {
                let sku = skus[i % skus.len()];
                let mut c = ClusterSpec::new(&format!("c{i}-{}", sku.name), sku, 16);
                c.phase_offset_s = i as f64 * 3.0 * 3600.0;
                c
            })
            .collect();
        let feeds: Vec<Feed> = clusters
            .chunks(2)
            .enumerate()
            .map(|(f, chunk)| {
                let idxs: Vec<usize> = (f * 2..f * 2 + chunk.len()).collect();
                let capacity_w: f64 = chunk.iter().map(|c| c.budget_w()).sum();
                Feed { name: format!("feed{f}"), clusters: idxs, capacity_w }
            })
            .collect();
        let ups_efficiency = 0.94;
        let substation_budget_w =
            clusters.iter().map(|c| c.budget_w()).sum::<f64>() / ups_efficiency;
        SiteSpec {
            name: format!("demo-site-{n}"),
            clusters,
            feeds,
            ups_efficiency,
            substation_budget_w,
        }
    }
}

/// A composed site power trace, aligned to site time.
#[derive(Debug, Clone)]
pub struct SiteTrace {
    /// Sampling period, seconds.
    pub period_s: f64,
    /// Per-cluster power in watts per sample (offset-aligned).
    pub cluster_w: Vec<Vec<f64>>,
    /// Site total per sample (= sample-wise sum of `cluster_w`).
    pub site_w: Vec<f64>,
}

impl SiteTrace {
    /// Peak site draw over the trace, watts.
    pub fn peak_w(&self) -> f64 {
        self.site_w.iter().cloned().fold(0.0, f64::max)
    }

    /// Mean site draw over the trace, watts.
    pub fn mean_w(&self) -> f64 {
        if self.site_w.is_empty() {
            return 0.0;
        }
        self.site_w.iter().sum::<f64>() / self.site_w.len() as f64
    }

    /// Peak of a subset of clusters (a feed's view of the trace).
    pub fn peak_of(&self, cluster_idxs: &[usize]) -> f64 {
        let n = self.site_w.len();
        let mut peak = 0.0f64;
        for j in 0..n {
            let s: f64 = cluster_idxs.iter().map(|&i| self.cluster_w[i][j]).sum();
            peak = peak.max(s);
        }
        peak
    }
}

/// Compose per-cluster normalized series into a site trace.
///
/// `series[i]` is cluster `i`'s `(t, normalized_power)` samples at a
/// fixed `period_s`; `budgets_w[i]` converts to watts; `offsets_s[i]`
/// rotates the trace forward in site time by a whole number of samples.
/// All series are truncated to the shortest.
///
/// Rotation is for composing *externally supplied* periodic traces
/// (what-if alignment studies) and is only physically meaningful when a
/// trace spans whole diurnal periods; simulated site runs realize phase
/// offsets in the arrival process instead and pass zero offsets here.
pub fn compose(
    series: &[Vec<(f64, f64)>],
    budgets_w: &[f64],
    offsets_s: &[f64],
    period_s: f64,
) -> SiteTrace {
    assert_eq!(series.len(), budgets_w.len());
    assert_eq!(series.len(), offsets_s.len());
    // Derived from the trace algebra: truncate → rotate → budget-scale
    // → left-fold sum. Each operator reproduces the original float
    // order exactly (one multiply per sample, `+=` into a zeroed
    // accumulator in cluster order), so this stays bit-identical to the
    // pre-algebra implementation — the invariant the pinned tests below
    // and `tests/integration_fleet.rs` enforce.
    let n = series.iter().map(|s| s.len()).min().unwrap_or(0);
    let clusters: Vec<PowerTrace> = series
        .iter()
        .enumerate()
        .map(|(i, s)| {
            PowerTrace::from_series(&s[..n], period_s)
                .shift_phase(offsets_s[i])
                .scale(budgets_w[i])
        })
        .collect();
    let site = PowerTrace::sum(period_s, &clusters);
    SiteTrace {
        period_s,
        cluster_w: clusters.into_iter().map(|t| t.samples).collect(),
        site_w: site.samples,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series_of(vals: &[f64], period: f64) -> Vec<(f64, f64)> {
        vals.iter().enumerate().map(|(i, &v)| (i as f64 * period, v)).collect()
    }

    #[test]
    fn zero_offsets_site_is_exact_sum() {
        let a = series_of(&[0.5, 0.6, 0.7, 0.6], 60.0);
        let b = series_of(&[0.2, 0.3, 0.2, 0.1], 60.0);
        let t = compose(&[a.clone(), b.clone()], &[100.0, 200.0], &[0.0, 0.0], 60.0);
        for j in 0..4 {
            let expect = a[j].1 * 100.0 + b[j].1 * 200.0;
            assert_eq!(t.site_w[j], expect, "sample {j}");
        }
    }

    #[test]
    fn offset_rotates_and_preserves_mean() {
        let a = series_of(&[1.0, 2.0, 3.0, 4.0], 60.0);
        let t0 = compose(&[a.clone()], &[1.0], &[0.0], 60.0);
        let t1 = compose(&[a.clone()], &[1.0], &[60.0], 60.0);
        // one-sample forward rotation
        assert_eq!(t1.site_w, vec![4.0, 1.0, 2.0, 3.0]);
        assert!((t0.mean_w() - t1.mean_w()).abs() < 1e-12);
        // offsets wrap modulo the series length
        let t5 = compose(&[a], &[1.0], &[5.0 * 60.0], 60.0);
        assert_eq!(t5.site_w, t1.site_w);
    }

    #[test]
    fn staggered_peaks_reduce_site_peak() {
        // Two identical single-peak traces: aligned they stack, staggered
        // they don't — the diversity effect the site planner exploits.
        let peaky = series_of(&[0.2, 1.0, 0.2, 0.2], 60.0);
        let aligned =
            compose(&[peaky.clone(), peaky.clone()], &[1.0, 1.0], &[0.0, 0.0], 60.0);
        let staggered =
            compose(&[peaky.clone(), peaky], &[1.0, 1.0], &[0.0, 120.0], 60.0);
        assert!((aligned.peak_w() - 2.0).abs() < 1e-12);
        assert!((staggered.peak_w() - 1.2).abs() < 1e-12);
        assert!((aligned.mean_w() - staggered.mean_w()).abs() < 1e-12);
    }

    #[test]
    fn feed_peak_never_exceeds_site_peak_sum() {
        let a = series_of(&[0.5, 0.9, 0.4], 60.0);
        let b = series_of(&[0.7, 0.2, 0.8], 60.0);
        let t = compose(&[a, b], &[10.0, 10.0], &[0.0, 0.0], 60.0);
        assert!(t.peak_of(&[0]) <= t.peak_w() + 1e-12);
        assert!(t.peak_of(&[1]) <= t.peak_w() + 1e-12);
        assert!((t.peak_of(&[0, 1]) - t.peak_w()).abs() < 1e-12);
    }

    #[test]
    fn truncates_to_shortest_series() {
        let a = series_of(&[1.0, 1.0, 1.0, 1.0, 1.0], 60.0);
        let b = series_of(&[2.0, 2.0, 2.0], 60.0);
        let t = compose(&[a, b], &[1.0, 1.0], &[0.0, 0.0], 60.0);
        assert_eq!(t.site_w.len(), 3);
        assert_eq!(t.site_w, vec![3.0, 3.0, 3.0]);
    }

    #[test]
    fn cluster_spec_budget_and_deploy() {
        let sku = sku::find("dgx-a100").unwrap();
        let mut c = ClusterSpec::new("c0", sku, 40);
        assert_eq!(c.deployed(), 40);
        c.added_frac = 0.30;
        assert_eq!(c.deployed(), 52);
        // 40 DGX-A100 ≈ 40 × 6.5 kW
        assert!((250_000.0..270_000.0).contains(&c.budget_w()), "{}", c.budget_w());
    }

    #[test]
    fn with_training_flows_into_sim_config() {
        use crate::policy::engine::PolicyKind;
        let site = SiteSpec::demo(2).with_training(0.25);
        assert!(site.clusters.iter().all(|c| c.training_fraction == 0.25));
        let cfg = site.clusters[0].sim_config(PolicyKind::Polca, 0.01, 1, 60.0);
        let mixed = cfg.mixed.expect("training fraction must produce a mixed config");
        assert_eq!(mixed.training_fraction, 0.25);
        // Zero training keeps the inference-only fast path (mixed: None).
        let plain = SiteSpec::demo(2).clusters[0].sim_config(PolicyKind::Polca, 0.01, 1, 60.0);
        assert!(plain.mixed.is_none());
        // The knob clamps to a sane fraction.
        assert_eq!(site.with_training(1.7).clusters[0].training_fraction, 1.0);
    }

    #[test]
    fn demo_site_is_heterogeneous_and_feed_covered() {
        let site = SiteSpec::demo(4);
        assert_eq!(site.clusters.len(), 4);
        // at least two distinct SKUs
        let mut names: Vec<_> = site.clusters.iter().map(|c| c.sku.name).collect();
        names.sort();
        names.dedup();
        assert!(names.len() >= 2);
        // every cluster appears on exactly one feed
        let mut covered = vec![0u32; 4];
        for f in &site.feeds {
            for &i in &f.clusters {
                covered[i] += 1;
            }
        }
        assert_eq!(covered, vec![1, 1, 1, 1]);
        assert!(site.substation_budget_w > site.baseline_budget_w());
        // uniform scaling knob
        let over = site.with_added(0.25);
        assert!(over.deployed_servers() > site.deployed_servers());
        assert!((over.oversubscription() - 1.25).abs() < 0.01);
    }
}
