//! Discrete-event simulation engine.
//!
//! The paper evaluates POLCA with a discrete event simulator (§6.1); this
//! module is that substrate: a deterministic event queue with stable
//! ordering (ties broken by insertion sequence), microsecond integer time,
//! and zero allocation per pop beyond the heap itself.
//!
//! The engine is generic over the event payload `E`; the domain loop lives
//! in [`crate::simulation`].
//!
//! # Queue implementation
//!
//! [`EventQueue`] is a **4-ary implicit min-heap** over one packed
//! `u128` key per entry — `(time << 64) | insertion_seq` — so every
//! heap comparison is a single integer compare and the (time, seq) tie
//! order is baked into the key itself. Against the previous
//! `BinaryHeap<Reverse<Entry>>` this halves tree depth (the dominant
//! cost of `pop` on the near-future Arrival/PhaseEnd traffic that
//! dominates a run), keeps parent/child entries on the same cache line
//! (keys are 16 bytes, four children span one line), and drops the
//! three-field lexicographic comparator for a `u128` compare.
//!
//! Because `(time, seq)` is unique per entry (the insertion sequence
//! never repeats), the ordering is *total* and any correct heap pops
//! the exact same sequence — the rewrite is order-identical to the old
//! binary heap by construction, and [`reference`] keeps that old heap
//! alive as the differential-test oracle
//! (`tests/integration_queue.rs` drives both through randomized
//! interleaved schedule/pop workloads and asserts element-wise
//! equality).

/// Simulation time in integer microseconds (deterministic; no float drift).
pub type SimTime = u64;

/// One microsecond of [`SimTime`].
pub const MICROS: u64 = 1;
/// One millisecond of [`SimTime`].
pub const MILLIS: u64 = 1_000;
/// One second of [`SimTime`].
pub const SECONDS: u64 = 1_000_000;

/// Convert seconds (f64) to SimTime.
#[inline]
pub fn secs(s: f64) -> SimTime {
    debug_assert!(s >= 0.0);
    (s * SECONDS as f64).round() as SimTime
}

/// Convert SimTime to seconds (f64).
#[inline]
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / SECONDS as f64
}

/// Pack an event's total order into one integer: time in the high 64
/// bits, insertion sequence in the low 64 — `u128` comparison is then
/// exactly the lexicographic (time, seq) order the engine guarantees.
#[inline]
fn pack(time: SimTime, seq: u64) -> u128 {
    ((time as u128) << 64) | seq as u128
}

/// Time component of a packed key.
#[inline]
fn key_time(key: u128) -> SimTime {
    (key >> 64) as u64
}

/// Deterministic time-ordered event queue (4-ary implicit min-heap;
/// see the module docs for the layout and the order-identity argument).
#[derive(Debug, Clone)]
pub struct EventQueue<E> {
    /// Implicit 4-ary heap: children of `i` live at `4i+1 ..= 4i+4`.
    heap: Vec<(u128, E)>,
    seq: u64,
    now: SimTime,
    popped: u64,
}

impl<E> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue { heap: Vec::new(), seq: 0, now: 0, popped: 0 }
    }

    /// Empty queue with pre-allocated heap capacity.
    pub fn with_capacity(n: usize) -> Self {
        EventQueue { heap: Vec::with_capacity(n), seq: 0, now: 0, popped: 0 }
    }

    /// Current simulation time (the timestamp of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events processed so far (for the §Perf events/s metric).
    #[inline]
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Total events ever scheduled (the insertion sequence counter; an
    /// [`crate::obs`] hot-path counter).
    #[inline]
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// Pending event count.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule at an absolute time. Scheduling in the past is clamped to
    /// `now` (events fire immediately, preserving causal order).
    pub fn schedule_at(&mut self, time: SimTime, event: E) {
        let time = time.max(self.now);
        self.heap.push((pack(time, self.seq), event));
        self.seq += 1;
        self.sift_up(self.heap.len() - 1);
    }

    /// Schedule `delay` after the current time.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.first().map(|&(key, _)| key_time(key))
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let (key, event) = self.heap.pop().expect("heap non-empty");
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        let time = key_time(key);
        debug_assert!(time >= self.now, "time went backwards");
        self.now = time;
        self.popped += 1;
        Some((time, event))
    }

    /// Drop every pending event (used when ending a run at a horizon).
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Restore the heap property upward from `pos` after a push.
    #[inline]
    fn sift_up(&mut self, mut pos: usize) {
        while pos > 0 {
            let parent = (pos - 1) >> 2;
            if self.heap[parent].0 <= self.heap[pos].0 {
                break;
            }
            self.heap.swap(pos, parent);
            pos = parent;
        }
    }

    /// Restore the heap property downward from `pos` after a pop.
    #[inline]
    fn sift_down(&mut self, mut pos: usize) {
        let n = self.heap.len();
        loop {
            let first = (pos << 2) + 1;
            if first >= n {
                break;
            }
            // Smallest of the (up to four) children.
            let mut best = first;
            let end = (first + 4).min(n);
            for c in first + 1..end {
                if self.heap[c].0 < self.heap[best].0 {
                    best = c;
                }
            }
            if self.heap[pos].0 <= self.heap[best].0 {
                break;
            }
            self.heap.swap(pos, best);
            pos = best;
        }
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

pub mod reference {
    //! The pre-rewrite event queue, kept verbatim as the differential
    //! oracle.
    //!
    //! This is the `BinaryHeap<Reverse<Entry>>` implementation exactly
    //! as it shipped before the 4-ary rewrite of [`EventQueue`]
    //! (ISSUE 10). Its value is that it is the *old* ordering logic,
    //! byte for byte of behavior: `tests/integration_queue.rs` runs
    //! randomized interleaved schedule/pop workloads through both
    //! queues and asserts element-wise identical pop sequences and
    //! counter parity. Do not "improve" this module.

    use super::SimTime;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
    struct Entry<E> {
        time: SimTime,
        seq: u64,
        event: E,
    }

    /// The old binary-heap event queue (differential-test reference).
    #[derive(Debug, Clone)]
    pub struct ReferenceQueue<E> {
        heap: BinaryHeap<Reverse<Entry<E>>>,
        seq: u64,
        now: SimTime,
        popped: u64,
    }

    impl<E: Ord> ReferenceQueue<E> {
        /// Empty queue at time zero.
        pub fn new() -> Self {
            ReferenceQueue { heap: BinaryHeap::new(), seq: 0, now: 0, popped: 0 }
        }

        /// Current simulation time (timestamp of the last popped event).
        #[inline]
        pub fn now(&self) -> SimTime {
            self.now
        }

        /// Total events processed so far.
        #[inline]
        pub fn popped(&self) -> u64 {
            self.popped
        }

        /// Total events ever scheduled.
        #[inline]
        pub fn scheduled(&self) -> u64 {
            self.seq
        }

        /// Pending event count.
        #[inline]
        pub fn len(&self) -> usize {
            self.heap.len()
        }

        /// Whether no events are pending.
        #[inline]
        pub fn is_empty(&self) -> bool {
            self.heap.is_empty()
        }

        /// Schedule at an absolute time (past times clamp to `now`).
        pub fn schedule_at(&mut self, time: SimTime, event: E) {
            let time = time.max(self.now);
            self.heap.push(Reverse(Entry { time, seq: self.seq, event }));
            self.seq += 1;
        }

        /// Schedule `delay` after the current time.
        pub fn schedule_in(&mut self, delay: SimTime, event: E) {
            self.schedule_at(self.now.saturating_add(delay), event);
        }

        /// Time of the next event without popping it.
        pub fn peek_time(&self) -> Option<SimTime> {
            self.heap.peek().map(|Reverse(e)| e.time)
        }

        /// Pop the next event, advancing `now`.
        pub fn pop(&mut self) -> Option<(SimTime, E)> {
            let Reverse(entry) = self.heap.pop()?;
            debug_assert!(entry.time >= self.now, "time went backwards");
            self.now = entry.time;
            self.popped += 1;
            Some((entry.time, entry.event))
        }
    }

    impl<E: Ord> Default for ReferenceQueue<E> {
        fn default() -> Self {
            Self::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.schedule_at(5, i);
        }
        let popped: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(popped, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_and_schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u8);
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.now(), 100);
        q.schedule_in(50, 2);
        assert_eq!(q.pop(), Some((150, 2)));
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u8);
        q.pop();
        q.schedule_at(10, 2); // in the past
        assert_eq!(q.pop(), Some((100, 2)));
    }

    #[test]
    fn secs_roundtrip() {
        assert_eq!(secs(2.0), 2 * SECONDS);
        assert_eq!(secs(0.0001), 100);
        assert!((to_secs(secs(1234.5678)) - 1234.5678).abs() < 1e-6);
    }

    #[test]
    fn popped_counter() {
        let mut q = EventQueue::new();
        for i in 0..10u8 {
            q.schedule_at(i as u64, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.popped(), 10);
        assert_eq!(q.scheduled(), 10);
    }

    #[test]
    fn key_packing_orders_time_then_seq() {
        assert!(pack(1, u64::MAX) < pack(2, 0));
        assert!(pack(5, 3) < pack(5, 4));
        assert_eq!(key_time(pack(123, 456)), 123);
    }

    #[test]
    fn matches_reference_on_interleaved_workload() {
        // A deterministic interleave (the randomized suite lives in
        // tests/integration_queue.rs): schedule bursts, drain halfway,
        // schedule more during the drain, drain fully.
        let mut q = EventQueue::new();
        let mut r = reference::ReferenceQueue::new();
        for i in 0..200u64 {
            let t = (i * 37) % 53;
            q.schedule_at(t, i);
            r.schedule_at(t, i);
        }
        for _ in 0..100 {
            assert_eq!(q.pop(), r.pop());
        }
        for i in 200..300u64 {
            let t = q.now() + (i * 11) % 17;
            q.schedule_at(t, i);
            r.schedule_at(t, i);
        }
        loop {
            let (a, b) = (q.pop(), r.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(q.popped(), r.popped());
        assert_eq!(q.scheduled(), r.scheduled());
    }
}
