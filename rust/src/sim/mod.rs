//! Discrete-event simulation engine.
//!
//! The paper evaluates POLCA with a discrete event simulator (§6.1); this
//! module is that substrate: a deterministic event queue with stable
//! ordering (ties broken by insertion sequence), microsecond integer time,
//! and zero allocation per pop beyond the heap itself.
//!
//! The engine is generic over the event payload `E`; the domain loop lives
//! in [`crate::simulation`].

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation time in integer microseconds (deterministic; no float drift).
pub type SimTime = u64;

/// One microsecond of [`SimTime`].
pub const MICROS: u64 = 1;
/// One millisecond of [`SimTime`].
pub const MILLIS: u64 = 1_000;
/// One second of [`SimTime`].
pub const SECONDS: u64 = 1_000_000;

/// Convert seconds (f64) to SimTime.
#[inline]
pub fn secs(s: f64) -> SimTime {
    debug_assert!(s >= 0.0);
    (s * SECONDS as f64).round() as SimTime
}

/// Convert SimTime to seconds (f64).
#[inline]
pub fn to_secs(t: SimTime) -> f64 {
    t as f64 / SECONDS as f64
}

#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Entry<E> {
    time: SimTime,
    seq: u64,
    event: E,
}

/// Deterministic time-ordered event queue.
#[derive(Debug, Clone)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: SimTime,
    popped: u64,
}

impl<E: Ord> EventQueue<E> {
    /// Empty queue at time zero.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0, popped: 0 }
    }

    /// Empty queue with pre-allocated heap capacity.
    pub fn with_capacity(n: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(n), seq: 0, now: 0, popped: 0 }
    }

    /// Current simulation time (the timestamp of the last popped event).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events processed so far (for the §Perf events/s metric).
    #[inline]
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Total events ever scheduled (the insertion sequence counter; an
    /// [`crate::obs`] hot-path counter).
    #[inline]
    pub fn scheduled(&self) -> u64 {
        self.seq
    }

    /// Pending event count.
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are pending.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule at an absolute time. Scheduling in the past is clamped to
    /// `now` (events fire immediately, preserving causal order).
    pub fn schedule_at(&mut self, time: SimTime, event: E) {
        let time = time.max(self.now);
        self.heap.push(Reverse(Entry { time, seq: self.seq, event }));
        self.seq += 1;
    }

    /// Schedule `delay` after the current time.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now.saturating_add(delay), event);
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let Reverse(entry) = self.heap.pop()?;
        debug_assert!(entry.time >= self.now, "time went backwards");
        self.now = entry.time;
        self.popped += 1;
        Some((entry.time, entry.event))
    }

    /// Drop every pending event (used when ending a run at a horizon).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

impl<E: Ord> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![(10, "a"), (20, "b"), (30, "c")]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.schedule_at(5, i);
        }
        let popped: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(popped, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_and_schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u8);
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.now(), 100);
        q.schedule_in(50, 2);
        assert_eq!(q.pop(), Some((150, 2)));
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(100, 1u8);
        q.pop();
        q.schedule_at(10, 2); // in the past
        assert_eq!(q.pop(), Some((100, 2)));
    }

    #[test]
    fn secs_roundtrip() {
        assert_eq!(secs(2.0), 2 * SECONDS);
        assert_eq!(secs(0.0001), 100);
        assert!((to_secs(secs(1234.5678)) - 1234.5678).abs() < 1e-6);
    }

    #[test]
    fn popped_counter() {
        let mut q = EventQueue::new();
        for i in 0..10u8 {
            q.schedule_at(i as u64, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.popped(), 10);
        assert_eq!(q.scheduled(), 10);
    }
}
